"""Model-execution inspection (the paper's Figure-8 "zoom-in" case study).

Runs a FRAMEWORK+SYSTEM traced evaluation, then walks the aggregated
timeline: per-level time, the top-5 layers (Table 3), and the critical
path from the evaluation span down to the hottest layer.

    PYTHONPATH=src python examples/inspect_trace.py
"""
from repro.core import EvaluationRequest, ScenarioSpec, Span
from repro.core.analysis import critical_path, level_breakdown, top_layers
from repro.core.platform import LocalPlatform

platform = LocalPlatform(backends=("ref",))
try:
    (result,) = platform.evaluate(
        EvaluationRequest(
            model="zamba2-2.7b",           # hybrid: mamba + shared-attention layers
            backend="ref",
            scenario=ScenarioSpec(kind="online", num_requests=2, rate_hz=1000.0, warmup=1),
            trace_level="FULL",
            seq_len=32,
        )
    )
    spans = [Span.from_dict(d) for d in platform.evaldb.spans(result["eval_id"])]
    print(f"{len(spans)} spans in the aggregated timeline\n")

    print("== time per stack level ==")
    for level, seconds in sorted(level_breakdown(spans).items()):
        print(f"  {level:12s} {seconds * 1e3:9.2f} ms")

    print("\n== top-5 layers (Table 3 style) ==")
    for stat in top_layers(spans, k=5):
        print(f"  {stat.name:28s} count={stat.count:3d} total={stat.total_s*1e3:8.2f} ms")

    print("\n== critical path (Figure 8 zoom-in) ==")
    for depth, span in enumerate(critical_path(spans)):
        print(f"  {'  ' * depth}{span.name}  ({span.duration * 1e3:.2f} ms)")

    print("\n== SYSTEM-level events (XLA cost analysis = the CUPTI stand-in) ==")
    for s in spans:
        if s.name == "system:xla_cost":
            print(f"  flops={s.tags.get('flops', 0):.3g} bytes={s.tags.get('bytes_accessed', 0):.3g}")
finally:
    platform.shutdown()
