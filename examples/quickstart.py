"""Quickstart: evaluate a model through the platform in ~20 lines.

The paper's evaluation workflow end to end: start a local MLModelScope
instance (registry + server + agent + middleware), submit an online
benchmarking scenario for a built-in model, and print the automated report.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import EvaluationRequest, ScenarioSpec
from repro.core.platform import LocalPlatform

platform = LocalPlatform(backends=("ref",))
try:
    request = EvaluationRequest(
        model="glm4-9b",                 # any of the 10 assigned archs (+resnet50)
        backend="ref",
        scenario=ScenarioSpec(kind="online", num_requests=5, rate_hz=100.0, warmup=2),
        trace_level="MODEL",
        seq_len=32,
    )
    (result,) = platform.evaluate(request)
    print(f"evaluated on agent {result['agent_id']}")
    for key, value in sorted(result["metrics"].items()):
        if isinstance(value, (int, float)):
            print(f"  {key:24s} {value:.3f}")
    print()
    print(platform.report(model="glm4-9b"))
finally:
    platform.shutdown()
