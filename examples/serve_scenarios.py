"""End-to-end serving driver example (this paper's kind of e2e app).

Part 1 sweeps all six benchmarking scenario kinds (online / batched / trace
/ single_stream / server / offline) over an engine-backed predict function
through `run_scenario` — every kind flows scenario -> RequestScheduler ->
ServingEngine -> tracer.  Part 2 runs the serving driver in both executor
modes (static micro-batching and slot-based continuous batching).

    PYTHONPATH=src python examples/serve_scenarios.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core.scenarios import ScenarioSpec, run_scenario, scenario_kinds
from repro.core.tracing import Tracer, TracingServer
from repro.launch.serve import main
from repro.models import build_model
from repro.serve.engine import ServingEngine
from repro.serve.scheduler import SchedulerConfig

ARCH = "glm4-9b"
PROMPT_LEN = 8

# -- part 1: the six scenario kinds over one engine-backed predict fn --------
cfg = get_config(ARCH, reduced=True)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
engine = ServingEngine(model, params, max_batch=8, max_seq=32)
rng = np.random.default_rng(0)


def predict(batch_size: int) -> None:
    prompts = [
        rng.integers(0, cfg.vocab_size, (PROMPT_LEN,)).astype(np.int32)
        for _ in range(batch_size)
    ]
    engine.generate(prompts, max_new_tokens=2)


server = TracingServer()
specs = {
    "online": ScenarioSpec(kind="online", num_requests=4, rate_hz=50.0, warmup=1),
    "batched": ScenarioSpec(kind="batched", num_requests=3, batch_sizes=[1, 4], warmup=1),
    "trace": ScenarioSpec(kind="trace", num_requests=3, arrivals=[0.0, 0.05, 0.1], warmup=0),
    "single_stream": ScenarioSpec(kind="single_stream", num_requests=4, warmup=1),
    "server": ScenarioSpec(kind="server", num_requests=6, rate_hz=40.0, warmup=1, slo_ms=250.0),
    "offline": ScenarioSpec(kind="offline", num_requests=8, warmup=1),
}
assert sorted(specs) == scenario_kinds()
for kind, spec in specs.items():
    tracer = Tracer(f"demo-{kind}", server)
    m = run_scenario(
        spec, predict, tracer,
        scheduler=SchedulerConfig(max_batch=4, batch_timeout_ms=5.0)
        if kind in ("server", "offline") else None,
    )
    keys = [
        k for k in (
            "trimmed_mean_ms", "p90_ms", "p99_ms", "max_throughput_ips",
            "throughput_ips", "achieved_qps", "slo_attainment",
            "sched_mean_batch_occupancy",
        ) if k in m
    ]
    print(f"[scenario:{kind:13s}] " + "  ".join(f"{k}={m[k]:.2f}" for k in keys))

# -- part 2: the serving driver, both executor modes -------------------------
for mode in ("static", "continuous"):
    print(f"\n--- serve driver mode={mode} ---")
    rc = main([
        "--arch", ARCH,
        "--mode", mode,
        "--requests", "8",
        "--rate-hz", "50",
        "--engine-batch", "4",
        "--prompt-len", "12",
        "--max-new-tokens", "6",
        "--max-seq", "32",
    ])
    assert rc == 0
raise SystemExit(0)
