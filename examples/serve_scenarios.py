"""End-to-end serving driver example (this paper's kind of e2e app).

Serves a small model with batched requests via the ServingEngine under a
Poisson arrival process — the cloud-serving deployment scenario of §4.

    PYTHONPATH=src python examples/serve_scenarios.py
"""
from repro.launch.serve import main

raise SystemExit(
    main([
        "--arch", "glm4-9b",
        "--requests", "8",
        "--rate-hz", "50",
        "--engine-batch", "4",
        "--prompt-len", "12",
        "--max-new-tokens", "6",
        "--max-seq", "32",
    ])
)
