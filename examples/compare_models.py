"""Model comparison (the paper's Table-2 use case).

Evaluates several zoo models under the same scenarios and renders the
accuracy/latency/throughput-style comparison table from the evaluation
database — the "which model should I deploy?" workflow.

    PYTHONPATH=src python examples/compare_models.py
"""
from repro.core import EvaluationRequest, ScenarioSpec
from repro.core.analysis import comparison_table
from repro.core.platform import LocalPlatform

MODELS = ["mamba2-130m", "zamba2-2.7b", "glm4-9b", "gemma2-27b"]

platform = LocalPlatform(backends=("ref",))
try:
    rows = []
    for model in MODELS:
        online = platform.evaluate(
            EvaluationRequest(
                model=model, backend="ref",
                scenario=ScenarioSpec(kind="online", num_requests=4, rate_hz=1000.0, warmup=1),
                trace_level="NONE", seq_len=32,
            )
        )[0]["metrics"]
        batched = platform.evaluate(
            EvaluationRequest(
                model=model, backend="ref",
                scenario=ScenarioSpec(kind="batched", num_requests=2, batch_sizes=[1, 4], warmup=1),
                trace_level="NONE", seq_len=32,
            )
        )[0]["metrics"]
        rows.append(
            {
                "model": model,
                "online_tm_ms": online["trimmed_mean_ms"],
                "online_p90_ms": online["p90_ms"],
                "max_tput_ips": batched["max_throughput_ips"],
                "opt_batch": batched["optimal_batch_size"],
            }
        )
    print(
        comparison_table(
            rows,
            ["model", "online_tm_ms", "online_p90_ms", "max_tput_ips", "opt_batch"],
            sort_by="max_tput_ips",
        )
    )
finally:
    platform.shutdown()
