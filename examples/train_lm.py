"""Training example: train a reduced LM for a few hundred steps with
checkpoint/restart (kill it mid-run and re-run with --resume: it continues
from the last atomic checkpoint and the exact data cursor).

    PYTHONPATH=src python examples/train_lm.py
"""
from repro.launch.train import main

raise SystemExit(
    main([
        "--arch", "mamba2-130m",
        "--reduced",
        "--steps", "60",
        "--batch", "8",
        "--seq", "64",
        "--ckpt-dir", "/tmp/repro-ckpt",
        "--ckpt-every", "20",
        "--resume",
    ])
)
