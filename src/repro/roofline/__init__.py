from .hlo import HloCostModel, parse_hlo
from .model import HardwareSpec, RooflineReport, TPU_V5E, roofline_from_compiled

__all__ = [
    "HardwareSpec",
    "HloCostModel",
    "RooflineReport",
    "TPU_V5E",
    "parse_hlo",
    "roofline_from_compiled",
]
