"""Mini HLO cost model over optimized (post-SPMD) HLO text.

XLA's ``cost_analysis()`` counts each ``while`` body ONCE (verified in
tests), which undercounts scan-over-layers models by ~L×. This parser walks
the optimized per-device HLO module, multiplies loop bodies by their trip
counts (extracted from the loop-condition constant), and accounts:

* ``flops``            — dot/convolution FLOPs (2·out·contraction)
* ``memory_bytes``     — HBM traffic: per materialized instruction, output
                         bytes + operand bytes, with two fusion refinements:
                         a fusion parameter consumed by ``dynamic-slice``
                         counts the slice (scan reads one layer's weights per
                         step, not the stack); a fusion rooted in
                         ``dynamic-update-slice`` counts the update (cache
                         writes one token, not the cache)
* ``collective_bytes`` — per collective op, link-bytes-moved estimate:
                         all-reduce 2·(g-1)/g·size, all-gather/reduce-scatter
                         (g-1)/g·size, all-to-all (g-1)/g·size,
                         collective-permute 1·size
* per-collective-op breakdown for bottleneck attribution

All numbers are per-device (the SPMD module is a per-device program).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COMP_HDR_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^{]*\))?\s*(?:->[^{]*)?\{\s*$")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_V1_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
)
_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "while", "conditional",
    "call", "custom-call", "get-dimension-size", "domain", "opt-barrier",
    "all-reduce-done", "all-gather-done", "collective-permute-done",
}


def _parse_shape(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """All (dtype, dims) found in a shape string (handles tuples)."""
    out = []
    for m in _SHAPE_RE.finditer(text):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append((dtype, shape))
    return out


def _shape_bytes(text: str) -> int:
    return sum(
        _DTYPE_BYTES[dt] * int(math.prod(shape) if shape else 1)
        for dt, shape in _parse_shape(text)
    )


def _first_shape(text: str) -> Optional[Tuple[str, Tuple[int, ...]]]:
    shapes = _parse_shape(text)
    return shapes[0] if shapes else None


@dataclass
class Instruction:
    name: str
    shape: str          # result shape text
    opcode: str
    operands: List[str]
    attrs: str


@dataclass
class Computation:
    name: str
    instructions: List[Instruction] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)  # name -> shape text


@dataclass
class Costs:
    flops: float = 0.0
    memory_bytes: float = 0.0
    collective_bytes: float = 0.0
    by_collective: Dict[str, float] = field(default_factory=dict)
    collective_count: Dict[str, int] = field(default_factory=dict)

    def __iadd__(self, other: "Costs") -> "Costs":
        self.flops += other.flops
        self.memory_bytes += other.memory_bytes
        self.collective_bytes += other.collective_bytes
        for k, v in other.by_collective.items():
            self.by_collective[k] = self.by_collective.get(k, 0.0) + v
        for k, v in other.collective_count.items():
            self.collective_count[k] = self.collective_count.get(k, 0) + v
        return self

    def scaled(self, factor: float) -> "Costs":
        return Costs(
            flops=self.flops * factor,
            memory_bytes=self.memory_bytes * factor,
            collective_bytes=self.collective_bytes * factor,
            by_collective={k: v * factor for k, v in self.by_collective.items()},
            collective_count={k: int(v * factor) for k, v in self.collective_count.items()},
        )


def _balanced(text: str, start: int) -> int:
    """Index just past the paren that closes text[start] == '('."""
    depth = 0
    for i in range(start, len(text)):
        c = text[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                return i + 1
    return len(text)


def _parse_instruction(line: str) -> Optional[Instruction]:
    line = _COMMENT_RE.sub("", line)
    m = _NAME_RE.match(line)
    if m is None:
        return None
    name = m.group(1)
    rest = line[m.end():]
    # shape: either a balanced (tuple...) or a token up to whitespace
    if rest.startswith("("):
        end = _balanced(rest, 0)
        shape = rest[:end]
        rest = rest[end:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape = rest[:sp]
        rest = rest[sp + 1:].lstrip()
    om = re.match(r"([\w\-]+)\(", rest)
    if om is None:
        return None
    opcode = om.group(1)
    op_start = om.end() - 1
    op_end = _balanced(rest, op_start)
    operands_text = rest[op_start + 1 : op_end - 1]
    attrs = rest[op_end:]
    # split operands at top-level commas only
    operands: List[str] = []
    depth = 0
    cur_tok = []
    for c in operands_text:
        if c in "({[":
            depth += 1
        elif c in ")}]":
            depth -= 1
        if c == "," and depth == 0:
            operands.append("".join(cur_tok).strip())
            cur_tok = []
        else:
            cur_tok.append(c)
    if cur_tok:
        operands.append("".join(cur_tok).strip())
    clean_ops = []
    for o in operands:
        o = o.strip()
        # newer XLA prints operands with an inline shape ("f32[8]{0} %name");
        # take the trailing %-token when present, else the bare token
        pm = re.search(r"%([\w\.\-]+)$", o)
        if pm:
            clean_ops.append(pm.group(1))
        elif o.startswith("%"):
            clean_ops.append(o.lstrip("%"))
        elif re.fullmatch(r"-?\d+", o):
            clean_ops.append(o)
        elif re.fullmatch(r"[\w\.\-]+", o):
            clean_ops.append(o)
    return Instruction(
        name=name, shape=shape.strip(), opcode=opcode,
        operands=clean_ops, attrs=attrs,
    )


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name: Optional[str] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m and ("->" in line or line.lstrip().startswith(("ENTRY", "%"))):
                cur = Computation(name=m.group(1))
                if line.lstrip().startswith("ENTRY"):
                    entry_name = m.group(1)
                continue
        else:
            if stripped == "}":
                comps[cur.name] = cur
                cur = None
                continue
            instr = _parse_instruction(line)
            if instr is not None:
                cur.instructions.append(instr)
                cur.symbols[instr.name] = instr.shape
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


class HloCostModel:
    def __init__(self, text: str) -> None:
        self.comps = parse_hlo(text)
        self._cache: Dict[str, Costs] = {}

    # -- helpers -----------------------------------------------------------------
    def _comp(self, name: str) -> Optional[Computation]:
        return self.comps.get(name)

    def _trip_count(self, instr: Instruction, cond_name: Optional[str]) -> float:
        """Trip count from backend_config, else the condition constant."""
        m = _TRIP_RE.search(instr.attrs)
        if m:
            return float(m.group(1))
        if cond_name is None:
            return 1.0
        comp = self._comp(cond_name)
        if comp is None:
            return 1.0
        for ci in comp.instructions:
            if ci.opcode == "constant" and ci.shape.startswith("s32"):
                for op in ci.operands:
                    if re.fullmatch(r"-?\d+", op):
                        return float(op)
        return 1.0

    def _dot_flops(self, instr: Instruction, comp: Computation) -> float:
        out = _first_shape(instr.shape)
        if out is None:
            return 0.0
        out_elems = math.prod(out[1]) if out[1] else 1
        lhs_shape = None
        if instr.operands:
            lhs_shape_text = comp.symbols.get(instr.operands[0])
            if lhs_shape_text:
                lhs_shape = _first_shape(lhs_shape_text)
        contraction = 1
        m = _CONTRACT_RE.search(instr.attrs)
        if m and lhs_shape:
            for d in m.group(1).split(","):
                if d:
                    contraction *= lhs_shape[1][int(d)]
        return 2.0 * out_elems * contraction

    def _conv_flops(self, instr: Instruction, comp: Computation) -> float:
        out = _first_shape(instr.shape)
        if out is None or not instr.operands:
            return 0.0
        rhs_text = comp.symbols.get(instr.operands[1]) if len(instr.operands) > 1 else None
        if not rhs_text:
            return 0.0
        rhs = _first_shape(rhs_text)
        out_elems = math.prod(out[1]) if out[1] else 1
        kernel_elems = math.prod(rhs[1]) if rhs and rhs[1] else 1
        # flops ~= 2 * out_elems * (kernel elems / out_channels)
        oc = rhs[1][-1] if rhs and rhs[1] else 1
        return 2.0 * out_elems * (kernel_elems / max(oc, 1))

    def _fusion_operand_bytes(self, instr: Instruction, comp: Computation) -> float:
        """Operand read bytes with the dynamic-slice refinement."""
        called = None
        m = _CALLS_RE.search(instr.attrs)
        if m:
            called = self._comp(m.group(1))
        # map called-computation parameter index -> dynamic-slice output shape
        ds_param_shapes: Dict[int, str] = {}
        dus_root_update: Optional[str] = None
        if called is not None:
            param_names: Dict[str, int] = {}
            producers: Dict[str, Instruction] = {}
            for ci in called.instructions:
                producers[ci.name] = ci
                if ci.opcode == "parameter":
                    idx = int(ci.operands[0]) if ci.operands and ci.operands[0].isdigit() else None
                    if idx is not None:
                        param_names[ci.name] = idx

            def trace_to_param(name: str, hops: int = 4) -> Optional[str]:
                """Walk back through convert/bitcast/copy/reshape to a param."""
                while hops > 0:
                    if name in param_names:
                        return name
                    prod = producers.get(name)
                    if prod is None or prod.opcode not in (
                        "convert", "bitcast", "copy", "reshape", "transpose"
                    ) or not prod.operands:
                        return None
                    name = prod.operands[0]
                    hops -= 1
                return None

            for ci in called.instructions:
                if ci.opcode == "dynamic-slice" and ci.operands:
                    src = trace_to_param(ci.operands[0])
                    if src is not None:
                        ds_param_shapes[param_names[src]] = ci.shape
            root = called.instructions[-1] if called.instructions else None
            if root is not None and root.opcode == "dynamic-update-slice" and len(root.operands) >= 2:
                upd = root.operands[1]
                dus_root_update = called.symbols.get(upd)
        total = 0.0
        for i, op in enumerate(instr.operands):
            shape_text = comp.symbols.get(op)
            if shape_text is None:
                continue
            if i in ds_param_shapes:
                shape_text = ds_param_shapes[i]
            total += _shape_bytes(shape_text)
        out_bytes = _shape_bytes(dus_root_update) if dus_root_update else _shape_bytes(instr.shape)
        return total + out_bytes

    def _group_size(self, instr: Instruction) -> int:
        # v2 iota format: replica_groups=[G,S]<=[...] -> group size S
        m = _GROUPS_RE.search(instr.attrs)
        if m:
            return max(int(m.group(2)), 1)
        # v1 explicit format: replica_groups={{0,1},{2,3}} -> first group's size
        m = re.search(r"replica_groups=\{\{([^}]*)\}", instr.attrs)
        if m:
            return max(len(re.findall(r"\d+", m.group(1))), 1)
        m = _GROUPS_V1_RE.search(instr.attrs)
        if m:
            return max(len(re.findall(r"\d+", m.group(1))), 1)
        return 1

    def _collective_bytes(self, instr: Instruction, comp: Computation) -> float:
        g = self._group_size(instr)
        out_bytes = _shape_bytes(instr.shape)
        op = instr.opcode.replace("-start", "")
        if op == "collective-permute":
            # pairs, not groups: every payload crosses a link once
            return float(out_bytes)
        if g <= 1:
            return 0.0
        if op == "all-reduce":
            return 2.0 * (g - 1) / g * out_bytes
        if op == "all-gather":
            return (g - 1) / g * out_bytes
        if op == "reduce-scatter":
            in_bytes = sum(
                _shape_bytes(comp.symbols.get(o, "")) for o in instr.operands
            )
            return (g - 1) / g * max(in_bytes, out_bytes)
        if op == "all-to-all":
            return (g - 1) / g * out_bytes
        if op == "collective-permute":
            return float(out_bytes)
        return float(out_bytes)

    # -- main recursion --------------------------------------------------------------
    def cost_of(self, comp_name: str) -> Costs:
        if comp_name in self._cache:
            return self._cache[comp_name]
        comp = self._comp(comp_name)
        total = Costs()
        if comp is None:
            return total
        self._cache[comp_name] = total  # guard cycles
        for instr in comp.instructions:
            op = instr.opcode
            if op == "while":
                body = _BODY_RE.search(instr.attrs)
                cond = _COND_RE.search(instr.attrs)
                trips = self._trip_count(instr, cond.group(1) if cond else None)
                if body:
                    total += self.cost_of(body.group(1)).scaled(trips)
            elif op == "conditional":
                m = _BRANCHES_RE.search(instr.attrs)
                if m:
                    branches = [b.strip().lstrip("%") for b in m.group(1).split(",")]
                    costs = [self.cost_of(b) for b in branches]
                    if costs:
                        # execute one branch; take the max for a bound
                        best = max(costs, key=lambda c: c.flops + c.memory_bytes)
                        total += best
            elif op == "call":
                m = re.search(r"to_apply=%?([\w\.\-]+)", instr.attrs)
                if m:
                    total += self.cost_of(m.group(1))
            elif op in COLLECTIVES:
                cb = self._collective_bytes(instr, comp)
                key = op.replace("-start", "")
                total.collective_bytes += cb
                total.by_collective[key] = total.by_collective.get(key, 0.0) + cb
                total.collective_count[key] = total.collective_count.get(key, 0) + 1
                # local HBM read+write of the payload
                total.memory_bytes += 2 * _shape_bytes(instr.shape)
            elif op == "fusion":
                total.memory_bytes += self._fusion_operand_bytes(instr, comp)
                # fusions wrapping a dot (rare) — look inside for dots
                m = _CALLS_RE.search(instr.attrs)
                if m:
                    called = self._comp(m.group(1))
                    if called:
                        for ci in called.instructions:
                            if ci.opcode == "dot":
                                total.flops += self._dot_flops(ci, called)
            elif op == "dot":
                total.flops += self._dot_flops(instr, comp)
                total.memory_bytes += _shape_bytes(instr.shape) + sum(
                    _shape_bytes(comp.symbols.get(o, "")) for o in instr.operands
                )
            elif op == "convolution":
                total.flops += self._conv_flops(instr, comp)
                total.memory_bytes += _shape_bytes(instr.shape) + sum(
                    _shape_bytes(comp.symbols.get(o, "")) for o in instr.operands
                )
            elif op == "dynamic-slice":
                # reads only the slice, not the sliced operand
                total.memory_bytes += 2 * _shape_bytes(instr.shape)
            elif op == "dynamic-update-slice":
                # in-place read-modify-write of the update region only
                upd = (
                    comp.symbols.get(instr.operands[1], "")
                    if len(instr.operands) > 1
                    else instr.shape
                )
                total.memory_bytes += 2 * _shape_bytes(upd)
            elif op in _NO_TRAFFIC:
                continue
            else:
                # generic materializing op (copy, reduce, sort, gather, ...)
                total.memory_bytes += _shape_bytes(instr.shape) + sum(
                    _shape_bytes(comp.symbols.get(o, "")) for o in instr.operands
                )
        self._cache[comp_name] = total
        return total

    def entry_costs(self) -> Costs:
        return self.cost_of("__entry__")
