"""Three-term roofline from a compiled (dry-run) artifact.

    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

All terms in seconds for ONE step of the lowered function. The HLO module
is a per-device program, so per-device numbers × chips = totals; both give
the same term values (peak is per-chip). Dominant term = the bottleneck.

``MODEL_FLOPS`` = 6·N·D for training (fwd+bwd), 2·N·D for inference, with
N = active params — the "useful work" yardstick; MODEL_FLOPS/HLO_FLOPs
exposes remat/redundancy waste.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Any, Dict, Optional

from .hlo import Costs, HloCostModel


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float          # per chip, bf16
    hbm_bw: float              # bytes/s per chip
    link_bw: float             # bytes/s per link (ICI)
    hbm_bytes: float           # capacity per chip


TPU_V5E = HardwareSpec(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    link_bw=50e9,
    hbm_bytes=16e9,
)


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device accounting from the parsed HLO (trip-count corrected)
    flops_per_device: float
    memory_bytes_per_device: float
    collective_bytes_per_device: float
    by_collective: Dict[str, float]
    collective_count: Dict[str, int]
    # XLA's own (once-per-while-body) numbers, for reference
    xla_flops: float
    xla_bytes: float
    # memory analysis
    peak_memory_bytes: float
    argument_bytes: float
    output_bytes: float
    temp_bytes: float
    # terms (seconds)
    compute_term_s: float = 0.0
    memory_term_s: float = 0.0
    collective_term_s: float = 0.0
    dominant: str = ""
    # useful-work accounting
    model_flops: float = 0.0
    model_flops_ratio: float = 0.0
    step_time_bound_s: float = 0.0
    roofline_fraction: float = 0.0
    note: str = ""

    def to_dict(self) -> Dict[str, Any]:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RooflineReport":
        return cls(**d)


def model_flops_for(
    param_count_active: int, tokens: int, kind: str
) -> float:
    """6·N·D for training, 2·N·D for inference forward passes."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * param_count_active * tokens


def roofline_from_compiled(
    compiled,
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    hw: HardwareSpec = TPU_V5E,
    model_flops: float = 0.0,
    note: str = "",
) -> RooflineReport:
    """Build the report from a jax ``compiled`` object."""
    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    text = compiled.as_text()
    cm = HloCostModel(text)
    costs = cm.entry_costs()

    compute_term = costs.flops / hw.peak_flops
    memory_term = costs.memory_bytes / hw.hbm_bw
    collective_term = costs.collective_bytes / hw.link_bw
    terms = {
        "compute": compute_term,
        "memory": memory_term,
        "collective": collective_term,
    }
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    total_flops = costs.flops * chips
    ratio = model_flops / total_flops if total_flops else 0.0
    # roofline fraction: useful-model-FLOPs time at peak vs the bound time
    ideal_s = (model_flops / chips) / hw.peak_flops if chips else 0.0
    fraction = ideal_s / bound if bound > 0 else 0.0

    return RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        flops_per_device=costs.flops,
        memory_bytes_per_device=costs.memory_bytes,
        collective_bytes_per_device=costs.collective_bytes,
        by_collective=dict(costs.by_collective),
        collective_count=dict(costs.collective_count),
        xla_flops=float(cost.get("flops", 0.0)),
        xla_bytes=float(cost.get("bytes accessed", 0.0)),
        peak_memory_bytes=float(
            getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0)
        ),
        argument_bytes=float(getattr(mem, "argument_size_in_bytes", 0)),
        output_bytes=float(getattr(mem, "output_size_in_bytes", 0)),
        temp_bytes=float(getattr(mem, "temp_size_in_bytes", 0)),
        compute_term_s=compute_term,
        memory_term_s=memory_term,
        collective_term_s=collective_term,
        dominant=dominant,
        model_flops=model_flops,
        model_flops_ratio=ratio,
        step_time_bound_s=bound,
        roofline_fraction=fraction,
        note=note,
    )
