"""Logical-axis -> mesh-axis sharding rules.

Models annotate parameters (:class:`repro.models.params.P.axes`) and
activations with *logical* axis names; this module maps them onto the mesh
axes of :func:`repro.launch.mesh.make_production_mesh`:

    single-pod:  ("data", "model")
    multi-pod:   ("pod", "data", "model")

Batch-like logical axes shard over ("pod","data"); tensor-parallel axes
(heads / ffn / vocab / experts / inner) shard over "model". FSDP mode
additionally shards the "embed" axis of weights over the data axes (used by
the ≥400B training configs) and ZeRO-1 shards optimizer state the same way.

A *non-divisible* logical dim falls back to replication (e.g. mamba2-130m's
24 SSD heads on a 16-way model axis, or whisper's 51866 vocab).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field, replace
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class ShardingRules:
    """One arch×mode sharding policy: logical axis -> mesh axes.

    ``opts`` gates beyond-baseline optimizations (the §Perf hillclimb
    levers) so baseline and optimized lowerings are both reproducible:

    * ``gather_kv_once``     — all-gather seq-sharded K/V once per layer
                               instead of once per flash KV-block
    * ``rs_block_outputs``   — constrain attention/MLP outputs seq-sharded
                               so TP partial sums reduce-scatter instead of
                               all-reduce
    * ``ssd_shard_p``        — shard the SSD head_dim (p) over "model" when
                               the head count can't split it
    * ``moe_decode_gather``  — single-token MoE path computes only the
                               selected experts
    """

    mesh: Mesh
    fsdp: bool = False          # shard weight "embed" dims over data axes
    rules: Dict[str, MeshAxes] = field(default_factory=dict)
    opts: Dict[str, bool] = field(default_factory=dict)

    def axis_size(self, axes: MeshAxes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        size = 1
        for a in axes:
            size *= self.mesh.shape[a]
        return size

    def mesh_axes_for(self, logical: Optional[str], dim: int) -> MeshAxes:
        """Resolve a logical axis to mesh axes, honouring divisibility."""
        if logical is None:
            return None
        axes = self.rules.get(logical)
        if axes is None:
            return None
        if isinstance(axes, str):
            axes = (axes,)
        # drop trailing axes until the dim divides evenly
        cur: Tuple[str, ...] = tuple(a for a in axes if a in self.mesh.shape)
        while cur:
            size = 1
            for a in cur:
                size *= self.mesh.shape[a]
            if dim % size == 0:
                return cur if len(cur) > 1 else cur[0]
            cur = cur[:-1]
        return None


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes a batch dimension shards over (pod composes with data)."""
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def default_rules(mesh: Mesh, fsdp: bool = False) -> ShardingRules:
    b = batch_axes(mesh)
    rules: Dict[str, MeshAxes] = {
        # --- weights ---
        "vocab": "model",
        "heads": "model",
        "kv": "model",
        "ffn": "model",
        "experts": "model",
        "expert_ffn": None,
        "inner": "model",
        "inner_all": "model",
        "conv_dim": "model",
        "ssm_heads": "model",
        "embed": b if fsdp else None,   # FSDP: weight embed dims over data
        "head_dim": None,
        "layer": None,
        "group": None,
        # --- activations ---
        "batch": b,
        "act_embed": None,
        "act_heads": "model",
        "act_kv": "model",
        "act_ffn": "model",
        "seq": None,
        "kv_seq": None,                 # overridden to "model" when kv heads don't shard
        "act_experts": "model",
        "act_vocab": "model",
        "ssm_p": "model",
        "state": None,
    }
    return ShardingRules(mesh=mesh, fsdp=fsdp, rules=rules)


def serve_rules(mesh: Mesh, *, rs_block_outputs: bool = False) -> ShardingRules:
    """Tensor-parallel rules for the paged serving stack.

    The default rules already map heads / kv / ffn / vocab onto "model";
    serving adds one lever: with ``rs_block_outputs`` the block outputs are
    constrained seq-sharded (the packed-prefill token axis joins "model"),
    so the attention/MLP partial sums lower to reduce-scatter instead of
    all-reduce.  Decode launches have seq == 1, which can't shard — they
    fall back to the plain psum either way."""
    rules = default_rules(mesh)
    if rs_block_outputs:
        rules = replace(
            rules,
            rules={**rules.rules, "seq": "model"},
            opts={**rules.opts, "rs_block_outputs": True},
        )
    return rules


def heads_shard_axis(heads: int, kv_heads: int):
    """(mesh, axis) the serving attention kernels shard their head dims
    over, or ``None`` when the current activation rules don't tensor-
    parallelize this head layout.

    Head-parallel attention needs the query-head AND kv-head counts to
    resolve to the SAME single mesh axis (GQA groups must not straddle
    shards); either count failing divisibility falls back to replication —
    the same fallback :func:`ShardingRules.mesh_axes_for` applies to the
    page-pool and weight dims, so kernels and operands always agree."""
    rules = activation_rules()
    if rules is None:
        return None
    ah = rules.mesh_axes_for("act_heads", heads)
    ak = rules.mesh_axes_for("act_kv", kv_heads)
    if not isinstance(ah, str) or ah != ak:
        return None
    if rules.axis_size(ah) <= 1:
        return None
    return rules.mesh, ah


def tp_degree(rules: Optional[ShardingRules], heads: int, kv_heads: int) -> int:
    """Effective tensor-parallel degree for one head layout: the "model"
    axis size when heads genuinely split, else 1 (replication fallback)."""
    if rules is None:
        return 1
    with set_activation_rules(rules):
        info = heads_shard_axis(heads, kv_heads)
    return rules.axis_size(info[1]) if info else 1


def _dedup(dims):
    """Drop mesh axes already claimed by an earlier dim (earlier dim wins)."""
    used = set()
    out = []
    for d in dims:
        axes = (d,) if isinstance(d, str) else tuple(d or ())
        keep = tuple(a for a in axes if a not in used)
        used.update(keep)
        if not keep:
            out.append(None)
        elif len(keep) == 1:
            out.append(keep[0])
        else:
            out.append(keep)
    return out


def param_pspecs(defs, rules: ShardingRules):
    """PartitionSpec tree matching a parameter def tree."""
    # lazy: models.params ends up importing this module back through the
    # models package, so a module-level import would cycle when sharding
    # loads first
    from ..models.params import P, tree_map_defs

    def make(path: str, p: P) -> PartitionSpec:
        axes = p.axes if p.axes is not None else (None,) * len(p.shape)
        if len(axes) != len(p.shape):
            raise ValueError(f"{path}: axes {axes} rank != shape {p.shape}")
        return PartitionSpec(
            *_dedup([rules.mesh_axes_for(a, d) for a, d in zip(axes, p.shape)])
        )

    return tree_map_defs(make, defs)


def logical_pspec(rules: ShardingRules, axes: Sequence[Optional[str]], shape: Sequence[int]) -> PartitionSpec:
    return PartitionSpec(
        *_dedup([rules.mesh_axes_for(a, d) for a, d in zip(axes, shape)])
    )


def cache_pspec(rules: ShardingRules, axes: Sequence[Optional[str]], shape: Sequence[int]) -> NamedSharding:
    return NamedSharding(rules.mesh, logical_pspec(rules, axes, shape))


# ---------------------------------------------------------------------------
# Activation sharding constraints inside model code
# ---------------------------------------------------------------------------
_ctx = threading.local()


def set_activation_rules(rules: Optional[ShardingRules]):
    """Context manager enabling ``shard_act`` constraints inside jit."""

    class _Ctx:
        def __enter__(self):
            self.prev = getattr(_ctx, "rules", None)
            _ctx.rules = rules
            return rules

        def __exit__(self, *exc):
            _ctx.rules = self.prev

    return _Ctx()


def activation_rules() -> Optional[ShardingRules]:
    return getattr(_ctx, "rules", None)


def shard_act(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """Apply a with_sharding_constraint from logical activation axes.

    No-op when no rules are active (single-host tests) or rank mismatches.
    """
    rules = activation_rules()
    if rules is None or len(axes) != x.ndim:
        return x
    spec = logical_pspec(rules, axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


def opt_enabled(name: str) -> bool:
    """Whether a beyond-baseline optimization is active (see ShardingRules)."""
    rules = activation_rules()
    return bool(rules is not None and rules.opts.get(name))
