from .specs import (
    ShardingRules,
    opt_enabled,
    activation_rules,
    batch_axes,
    cache_pspec,
    param_pspecs,
    set_activation_rules,
    shard_act,
)

__all__ = [
    "ShardingRules",
    "opt_enabled",
    "activation_rules",
    "batch_axes",
    "cache_pspec",
    "param_pspecs",
    "set_activation_rules",
    "shard_act",
]
