"""Batched + continuous-batching + paged serving engine.

This is the platform's "cloud scenario" executor (the paper deploys models
either for cloud serving or edge inference). Three generate paths share the
prefill/decode jits:

* ``generate``          — static fixed-batch: requests grouped into padded
  batches, prefilled once, decoded token-by-token with cache donation so
  decode is allocation-free at steady state.
* ``serve_continuous``  — slot-based continuous batching: a fixed pool of
  dense KV-cache slots; finished sequences free their slot and queued
  prompts are admitted at decode-step boundaries (batch-1 prefill scattered
  into the pooled cache).  Uses the model's masked per-row cache-update path
  (``uniform_pos=False``) because slots sit at different sequence positions.
* ``serve_paged``       — paged KV cache: a global pool of ``page_size``-
  token pages plus per-request page tables; admission is keyed on free
  pages, prompts prefill interleaved at decode-step boundaries, and the
  pool preempts the youngest request when pages run out.  HBM scales with
  live tokens instead of ``num_slots * max_seq``.  Two prefill pipelines
  (``prefill_mode``): ``packed`` (default) coalesces every admissible
  prompt chunk into ONE token-packed varlen launch per boundary — a fixed
  packed-buffer size (``prefill_budget`` tokens, the knob that bounds
  decode latency) writing straight into the page pool, one compile
  regardless of how prompt lengths mix; ``chunked`` is the legacy
  one-chunk-per-slot-per-boundary path (one jit variant per chunk
  length × offset).  ``spec_k > 0`` adds self-speculative decoding: a
  host-side prompt-lookup drafter (n-gram match against the request's
  prompt + output) proposes up to ``spec_k`` tokens per slot and one
  paged multi-token verification launch scores every slot's window —
  greedy exact-match acceptance keeps tokens bit-identical, rejected
  suffixes roll back by rewinding lengths (append-only pages).  The
  decode loop keeps page tables / positions device-resident (patched only
  for slots that changed) and fuses argmax + acceptance into the launch,
  so a steady-state boundary costs one small int32 fetch.

Two shape disciplines keep XLA compile counts bounded (tracked per engine
instance in ``compile_stats``; each ``serve_paged`` run reports only its
own delta): prompts are RIGHT-padded to power-of-two length buckets
(floored at ``page_size``) — causal attention never reads trailing pads, so
bucketing is numerically exact for attention families — and decode passes a
bucketed static ``kv_bound`` so attention streams only the live prefix of
the cache rather than all of padded ``max_seq``.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import NamedSharding, PartitionSpec

from ..core.analysis import percentile
from ..kernels import kvquant, ops
from ..models.lm import BaseModel
from ..models.params import tree_map_defs
from ..sharding.specs import (
    ShardingRules, param_pspecs, set_activation_rules, tp_degree,
)
from .faults import FaultContext, WorkerCrash, WorkerDrain
from .page_table import (
    PagePool, PageSnapshot, PageTable, PrefixCache, page_checksums,
    pages_needed,
)
from .scheduler import (
    PagedSlotPool, PrefillBudget, SlotPool, SpecLedger, TenantLedger,
    TenantSpec,
)


def _named_shardings(mesh, pspecs):
    """PartitionSpec tree -> NamedSharding tree (PartitionSpec subclasses
    tuple, so plain tree_map would descend into it)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )


def bucket_pow2(n: int, floor: int = 1, cap: Optional[int] = None) -> int:
    """Smallest power-of-two multiple of ``floor`` that is >= ``n``, clipped
    to ``cap``.  Callers guarantee ``n <= cap``; the clip keeps the top
    bucket from overshooting the cache."""
    b = max(floor, 1)
    while b < n:
        b *= 2
    return min(b, cap) if cap is not None else b


def ngram_propose(context: np.ndarray, ngram: int, max_tokens: int) -> List[int]:
    """Prompt-lookup drafting: match the last ``ngram`` tokens of ``context``
    (prompt + everything committed so far, ending at the pending next token)
    against earlier context; the tokens that FOLLOWED the match become the
    draft.  No second model — summarization/extraction-style continuations
    repeat their source, so the continuation of an earlier occurrence is a
    cheap, often-right guess.  Scanning from the most recent match backwards,
    the first one with a FULL ``max_tokens`` continuation wins (a short
    repetition period would otherwise cap every draft at the period length:
    the most recent occurrence sits so close to the end that only a couple
    of continuation tokens exist); if none has a full continuation the most
    recent match is used.  Returns up to ``max_tokens`` draft ids (empty
    when nothing matches — the engine then falls back to a plain decode
    step, so adversarial text pays only this O(len * ngram) host scan)."""
    n = len(context)
    if max_tokens <= 0 or ngram < 1 or n < ngram + 1:
        return []
    pat = context[-ngram:]
    # vectorized sliding-window match (the scan runs per slot per decode
    # boundary, so the no-match case must stay cheap)
    windows = np.lib.stride_tricks.sliding_window_view(context, ngram)
    hits = np.nonzero((windows == pat).all(axis=1))[0]
    hits = hits[hits < n - ngram]          # drop the suffix occurrence itself
    if hits.size == 0:
        return []
    full = hits[hits + ngram + max_tokens <= n]
    best = int(full[-1]) if full.size else int(hits[-1])
    cont = context[best + ngram : best + ngram + max_tokens]
    return [int(t) for t in cont]


@dataclass
class GenerationResult:
    tokens: np.ndarray          # (b, new_tokens)
    prefill_s: float
    decode_s: float
    tokens_per_s: float


@dataclass
class ServeRequest:
    """One prompt for the continuous-batching / paged loops."""

    request_id: int
    prompt: np.ndarray
    max_new_tokens: int
    # multi-tenant serving: tenant identity, priority tier and latency SLO
    # (defaults keep single-tenant callers unchanged)
    tenant: str = "default"
    priority: int = 1
    slo_ms: float = 0.0


@dataclass
class RequestResult:
    """Per-request serving metrics (continuous batching)."""

    request_id: int
    tokens: np.ndarray          # (max_new_tokens,)
    slot: int
    admit_step: int             # decode-step boundary at which it was admitted
    finish_step: int
    ttft_s: float               # submit -> first token (prefill argmax)
    latency_s: float            # submit -> last token
    tokens_per_s: float
    # -- inter-token latency (paged engine): gaps between consecutive token
    # emissions; a speculative boundary emits several tokens at one instant,
    # so accepted drafts show up as (near-)zero gaps pulling p50 down -------
    itl_p50_s: float = 0.0
    itl_p99_s: float = 0.0
    # -- speculative-decoding ledger (0s when spec_k == 0) ------------------
    draft_proposed: int = 0
    draft_accepted: int = 0
    # -- terminal status (fleet-parity semantics): every request ends
    # "completed" or "rejected"; a completed request past the run deadline
    # stays completed but falls out of goodput (within_deadline=False) ------
    status: str = "completed"
    reason: str = ""
    tenant: str = "default"
    priority: int = 1
    within_deadline: bool = True


@dataclass
class ContinuousStats:
    """Aggregate output of one ``serve_continuous`` run."""

    results: List[RequestResult]
    steps: int                  # decode steps executed
    wall_s: float
    total_tokens: int
    throughput_tps: float
    mean_slot_occupancy: float  # active slots per decode step


@dataclass
class PagedStats:
    """Aggregate output of one ``serve_paged`` run."""

    results: List[RequestResult]
    steps: int                  # decode steps executed
    wall_s: float
    total_tokens: int
    throughput_tps: float
    mean_slot_occupancy: float  # active slots per decode step
    peak_slot_occupancy: int    # max concurrent requests observed
    page_size: int
    num_pages: int              # allocatable pages in the pool
    mean_pages_in_use: float
    peak_pages_in_use: int
    preemptions: int
    prefill_chunks: int         # prompt chunks prefilled (spans in packed mode)
    compile_stats: Dict[str, int] = field(default_factory=dict)
    # -- prefill pipeline (packed varlen launches) --------------------------
    prefill_mode: str = "packed"
    prefill_launches: int = 0   # packed launches (== prefill_chunks if chunked)
    prefill_s: float = 0.0      # wall time spent inside prefill calls
    prefill_tokens: int = 0     # real prompt tokens COMPUTED by prefill
    prefill_padded_tokens: int = 0  # packed-buffer slots spent on padding
    prefill_budget: int = 0     # packed-buffer tokens per boundary (0 = chunked)
    prefill_budget_stats: Dict[str, float] = field(default_factory=dict)
    # -- prompt-token ledger: admitted tokens split exactly into computed
    # (prefill_tokens above), served from the prefix cache, and abandoned by
    # preemption before they were ever prefilled.  Invariant (asserted in
    # tests) over any completed run:
    #   prompt_tokens_admitted ==
    #       prefill_tokens + saved_prefill_tokens + prefill_tokens_dropped
    prompt_tokens_admitted: int = 0   # per admission (re-admissions count again)
    saved_prefill_tokens: int = 0     # prompt tokens served from cached pages
    prefill_tokens_dropped: int = 0   # admitted but preempted before prefill
    # -- automatic prefix caching -------------------------------------------
    prefix_cache: bool = False
    cow_copies: int = 0         # shared pages split by copy-on-write
    cache_evictions: int = 0    # cached-unreferenced pages reclaimed
    prefix_stats: Dict[str, float] = field(default_factory=dict)
    # -- decode loop / speculative decoding ---------------------------------
    decode_s: float = 0.0       # wall time spent inside decode/verify launches
    spec_k: int = 0             # draft depth (0 = speculation disabled)
    spec_stats: Dict[str, float] = field(default_factory=dict)  # SpecLedger
    itl_p50_ms: float = 0.0     # inter-token latency over every gap in the run
    itl_p99_ms: float = 0.0
    # -- tensor parallelism -------------------------------------------------
    tp: int = 1                 # effective model-axis degree (1 = unsharded)
    # -- quantized KV pages -------------------------------------------------
    kv_dtype: str = "float32"   # pool storage mode (int8/fp8 = quantized)
    kv_bytes_per_token: float = 0.0  # pool bytes per token incl. scales
    # -- SLO / multi-tenant admission ---------------------------------------
    completed: int = 0          # terminal completed (== len(results) w/o TTL)
    rejected: int = 0           # terminal rejected (deadline / SLO shed)
    deferred: int = 0           # tenant-boundary deferrals (bucket ran dry)
    goodput: float = 1.0        # completed within deadline / submitted
    deadline_ms: float = 0.0    # run TTL handed to serve_paged (0 = none)
    # -- live KV migration (checkpoint / restore) ---------------------------
    checkpoints_saved: int = 0  # slot snapshots taken this run
    checkpoint_bytes: int = 0   # bytes gathered into snapshots
    restored_requests: int = 0  # requests resumed from a snapshot
    restored_tokens: int = 0    # KV positions restored without recompute
    restore_bytes: int = 0      # bytes scattered back into the pool
    checksum_failures: int = 0  # snapshots rejected by verify -> replayed


class ServingEngine:
    def __init__(
        self,
        model: BaseModel,
        params,
        max_batch: int,
        max_seq: int,
        cache_dtype: str = "float32",
        page_size: int = 16,
        rules: Optional[ShardingRules] = None,
        kv_dtype: Optional[str] = None,
    ) -> None:
        self.model = model
        # tensor parallelism: ``rules`` maps the existing logical axes
        # (heads/kv/ffn/vocab + activations) onto a device mesh.  Weights are
        # placed once here; every jit body runs under the rules (see
        # ``_ruled``) so shard_act constraints and the kernels' shard_map
        # head splits activate at trace time.  ``tp`` is the EFFECTIVE
        # degree: 1 when the head counts don't divide the model axis (the
        # specs.py replication fallback).
        self.rules = rules
        cfg = getattr(model, "cfg", None)
        self.tp = tp_degree(
            rules,
            int(getattr(cfg, "num_heads", 1) or 1),
            int(getattr(cfg, "num_kv_heads", 1) or 1),
        )
        if rules is not None:
            params = jax.device_put(
                params,
                _named_shardings(
                    rules.mesh, param_pspecs(model.param_defs(), rules)
                ),
            )
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.cache_dtype = cache_dtype
        # quantized KV pages: ``kv_dtype`` in {"int8", "fp8"} stores the
        # paged pool quantized (parallel f32 scale pool, dequant fused into
        # the serving kernels); None keeps the full-precision pool and every
        # code path bit-identical to an engine without the argument
        self._kv_quantized = kvquant.is_quantized(kv_dtype)  # validates too
        self.kv_dtype = kv_dtype
        # tokens per KV page (paged engine) — doubles as the prefill length-
        # bucket floor so admission shapes snap to page boundaries
        self.page_size = page_size
        self._prefill = jax.jit(self._ruled(model.prefill))
        # decode jits keyed by (uniform_pos, kv_bound): the kv bound is a
        # static power-of-two bucket, so short contexts stop streaming the
        # whole padded cache and compile count stays logarithmic
        self._decode_fns: Dict[Tuple[bool, Optional[int]], Callable] = {}
        self._paged_decode_fns: Dict[int, Callable] = {}
        self._spec_decode_fns: Dict[Tuple[int, int], Callable] = {}
        # jitted slot-level patch of the device-resident decode mirrors
        # (page-table rows / positions / next tokens / active mask): one
        # donated scatter call per dirty boundary instead of eager .at[]
        # updates, whose per-call dispatch cost dwarfs the transfer itself.
        # Dirty counts are pow2-bucketed (padded with repeats of the last
        # dirty slot) so the scatter compiles log2(num_slots) variants, not
        # one per distinct count; the bucket set is compile-accounted
        self._mirror_patch = jax.jit(
            lambda table, pos, nxt, mask, idx, rows, p, n, m: (
                table.at[idx].set(rows),
                pos.at[idx].set(p),
                nxt.at[idx].set(n),
                mask.at[idx].set(m),
            ),
            donate_argnums=(0, 1, 2, 3),
        )
        self._mirror_patch_shapes: set = set()
        # copy-on-write page duplication (prefix caching): one donated
        # gather/scatter over the pools per shared page about to be written
        # (the quantized variant donates and copies the scale pools too)
        self._cow_copy = jax.jit(ops.copy_pages, donate_argnums=(0, 1))
        self._cow_copy_q = jax.jit(ops.copy_pages, donate_argnums=(0, 1, 4, 5))
        self._cow_shapes: set = set()
        # live KV migration: checkpoint gathers a request's pages into a
        # contiguous snapshot (no donation — the pool stays live), restore
        # scatters a snapshot into freshly allocated pages (donated pools,
        # like COW).  The quantized variants move the scale pools too.
        self._export = jax.jit(ops.export_pages)
        self._import = jax.jit(ops.import_pages, donate_argnums=(0, 1))
        self._import_q = jax.jit(ops.import_pages, donate_argnums=(0, 1, 5, 6))
        self._xfer_shapes: set = set()
        self._paged_prefill_fns: Dict[Tuple[int, int], Callable] = {}
        self._packed_prefill_fns: Dict[Tuple[int, int, int, int], Callable] = {}
        self._slot_writers: Dict[int, Callable] = {}
        self._prefill_shapes: set = set()
        fam = getattr(model.cfg, "family", "")
        # right-padded ragged prefill (and kv-bounded decode) is exact only
        # for pure-attention caches; ssm/hybrid state scans absorb pads and
        # the hybrid ring cache wraps, so those keep exact-length shapes
        self._ragged_ok = fam in ("dense", "moe", "encdec")

    def _ruled(self, fn: Callable) -> Callable:
        """Run ``fn`` under this engine's activation sharding rules.

        jit traces the wrapped body on first call, so entering the context
        inside the wrapper is what makes ``shard_act`` constraints and the
        serving kernels' shard_map head splits visible to GSPMD.  Identity
        when the engine has no rules (single-device)."""
        if self.rules is None:
            return fn
        rules = self.rules

        def wrapped(*args, **kwargs):
            with set_activation_rules(rules):
                return fn(*args, **kwargs)

        return wrapped

    # -- compile accounting --------------------------------------------------
    def compile_stats(self) -> Dict[str, int]:
        """Distinct jitted variants per path (the engine's compile budget).

        Counts are per-ENGINE-INSTANCE (every variant cache lives on
        ``self``), cumulative over the instance's lifetime; engines built in
        the same process never see each other's counts.  Per-run reporting
        (``PagedStats.compile_stats``) uses :meth:`_compile_delta` so a run's
        numbers aren't inflated by warmups or other serve modes that shared
        the instance.
        """
        return {
            "prefill": len(self._prefill_shapes),
            "decode": len(self._decode_fns),
            "paged_prefill": len(self._paged_prefill_fns),
            "packed_prefill": len(self._packed_prefill_fns),
            "paged_decode": len(self._paged_decode_fns),
            "spec_decode": len(self._spec_decode_fns),
            "mirror_patch": len(self._mirror_patch_shapes),
            "cow_copy": len(self._cow_shapes),
            "page_xfer": len(self._xfer_shapes),
        }

    def _compile_delta(self, before: Dict[str, int]) -> Dict[str, int]:
        """Jit variants added since a ``compile_stats()`` snapshot."""
        return {k: v - before.get(k, 0) for k, v in self.compile_stats().items()}

    def _decode_step_fn(self, uniform: bool, kv_bound: Optional[int]) -> Callable:
        key = (uniform, kv_bound)
        fn = self._decode_fns.get(key)
        if fn is None:
            fn = jax.jit(
                self._ruled(
                    partial(self.model.decode, uniform_pos=uniform,
                            kv_bound=kv_bound)
                ),
                donate_argnums=(2,),
            )
            self._decode_fns[key] = fn
        return fn

    def _kv_bucket(self, live_len: int) -> Optional[int]:
        if not self._ragged_ok:
            return None
        return bucket_pow2(live_len, floor=min(self.page_size, self.max_seq),
                           cap=self.max_seq)

    # -- prompt padding ------------------------------------------------------
    def _pad_prompts(
        self, prompts: List[np.ndarray], max_new_tokens: int = 0
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Pad a prompt batch to one prefill shape.

        Attention families RIGHT-pad to a power-of-two bucket (floored at
        ``page_size``): causal attention never reads trailing pads and the
        model gathers logits at ``lengths - 1``, so every distinct prompt
        length no longer costs a fresh XLA compile.  SSM/hybrid keep the
        exact batch max (left-padded) since their state scans the full row.
        """
        b = len(prompts)
        if b > self.max_batch:
            raise ValueError(f"batch {b} > max_batch {self.max_batch}")
        lens = np.asarray([len(p) for p in prompts], np.int32)
        max_len = int(lens.max())
        if max_len + max_new_tokens > self.max_seq:
            raise ValueError("prompt + generation exceeds max_seq")
        if self._ragged_ok:
            padded = bucket_pow2(
                max_len,
                floor=min(self.page_size, self.max_seq),
                cap=max(self.max_seq - max_new_tokens, max_len),
            )
            out = np.zeros((b, padded), np.int32)
            for i, p in enumerate(prompts):
                out[i, : len(p)] = p
            return out, lens
        out = np.zeros((b, max_len), np.int32)
        for i, p in enumerate(prompts):
            # left-pad so every prompt's last token sits at max_len-1; the
            # causal mask plus identical suffix alignment keeps decode simple
            out[i, max_len - len(p):] = p
        return out, lens

    def generate(
        self,
        prompts: List[np.ndarray],
        max_new_tokens: int,
        extra_inputs: Optional[Dict[str, Any]] = None,
        greedy: bool = True,
    ) -> GenerationResult:
        tokens, lens = self._pad_prompts(prompts, max_new_tokens)
        b, s = tokens.shape
        max_len = int(lens.max())
        cache = self.model.init_cache(b, self.max_seq, dtype=self.cache_dtype)
        batch = {"tokens": jnp.asarray(tokens)}
        if self._ragged_ok:
            batch["lengths"] = jnp.asarray(lens)
        if extra_inputs:
            batch.update({k: jnp.asarray(v) for k, v in extra_inputs.items()})
        t0 = time.perf_counter()
        logits, cache = jax.block_until_ready(self._prefill(self.params, batch, cache))
        self._prefill_shapes.add((b, s))
        t1 = time.perf_counter()
        out = np.zeros((b, max_new_tokens), np.int32)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        # left-padded families sit at one common position; right-padded ragged
        # batches decode at per-row positions via the masked-update path
        uniform = (not self._ragged_ok) or bool((lens == lens[0]).all())
        for i in range(max_new_tokens):
            out[:, i] = np.asarray(nxt)
            decode = self._decode_step_fn(uniform, self._kv_bucket(max_len + i + 1))
            logits, cache = decode(self.params, nxt, cache)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(logits)
        t2 = time.perf_counter()
        decode_s = t2 - t1
        return GenerationResult(
            tokens=out,
            prefill_s=t1 - t0,
            decode_s=decode_s,
            tokens_per_s=b * max_new_tokens / decode_s if decode_s > 0 else float("inf"),
        )

    # -- continuous batching -------------------------------------------------
    def _slot_writer(self, num_slots: int) -> Callable:
        """Jitted scatter of a batch-1 cache into slot ``i`` of the pool.

        The batch axis of each cache leaf comes from the model's own P-tree
        axis names, so this works for every cache layout (dense/MoE KV,
        interleaved pairs, SSM state, hybrid, enc-dec cross caches).
        """
        writer = self._slot_writers.get(num_slots)
        if writer is not None:
            return writer
        defs = self.model.cache_defs(num_slots, self.max_seq, dtype=self.cache_dtype)
        axis_tree = tree_map_defs(lambda path, p: p.axes.index("batch"), defs)

        def write(pool, one, slot):
            def w(dst, src, ax):
                starts = tuple(slot if i == ax else 0 for i in range(dst.ndim))
                return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), starts)

            return jax.tree.map(w, pool, one, axis_tree)

        writer = jax.jit(write, donate_argnums=(0,))
        self._slot_writers[num_slots] = writer
        return writer

    def serve_continuous(
        self,
        requests: List[ServeRequest],
        num_slots: Optional[int] = None,
        clock: Callable[[], float] = time.perf_counter,
    ) -> ContinuousStats:
        """Slot-based continuous-batching generate loop.

        All prompts are padded to a common (bucketed) prefill length — one
        compile; admission runs a batch-1 prefill and scatters its cache into
        the free slot, then every decode step advances all active slots
        together.  ``clock`` is injectable so tests measure deterministic
        timings.
        """
        if not requests:
            return ContinuousStats([], 0, 0.0, 0, 0.0, 0.0)
        if getattr(self.model.cfg, "family", "") == "encdec":
            raise NotImplementedError(
                "continuous batching does not support encoder-decoder models: "
                "admission prefill would need per-request encoder frames"
            )
        num_slots = num_slots or self.max_batch
        max_prompt = max(len(r.prompt) for r in requests)
        if self._ragged_ok:
            prefill_len = bucket_pow2(
                max_prompt, floor=min(self.page_size, self.max_seq), cap=self.max_seq
            )
        else:
            prefill_len = max_prompt
        for r in requests:
            # left-padded families start every slot at prefill_len, so their
            # decode budget is measured from the padded length, not the
            # prompt's own; right-padded ragged slots start at len(prompt)
            start = len(r.prompt) if self._ragged_ok else prefill_len
            if start + r.max_new_tokens > self.max_seq:
                raise ValueError(
                    f"request {r.request_id}: prompt + generation exceeds max_seq"
                )
        pool = SlotPool(num_slots)
        cache = self.model.init_cache(num_slots, self.max_seq, dtype=self.cache_dtype)
        write = self._slot_writer(num_slots)
        # one reusable batch-1 cache for admission prefills (prefill is
        # functional: it returns a fresh tree, the zeros base is never mutated)
        cache1 = self.model.init_cache(1, self.max_seq, dtype=self.cache_dtype)
        queue = deque(requests)
        nxt = np.zeros((num_slots,), np.int32)
        # slot -> [generated tokens]; slot -> live length (prompt + generated)
        slot_tokens: Dict[int, List[int]] = {}
        slot_len: Dict[int, int] = {}
        finished: Dict[int, RequestResult] = {}
        t_start = clock()
        submit_s = {r.request_id: t_start for r in requests}
        step = 0
        occupancy_sum = 0
        while queue or pool.num_active:
            # retire sequences that already hold all their tokens, so their
            # slots are free for admission at this same step boundary
            for slot in list(pool.active):
                req = pool.active[slot]
                if len(slot_tokens[slot]) >= req.max_new_tokens:
                    now = clock()
                    finished[req.request_id] = RequestResult(
                        request_id=req.request_id,
                        tokens=np.asarray(slot_tokens.pop(slot), np.int32),
                        slot=slot,
                        admit_step=req._admit_step,  # type: ignore[attr-defined]
                        finish_step=step,
                        ttft_s=req._ttft_s,          # type: ignore[attr-defined]
                        latency_s=now - submit_s[req.request_id],
                        tokens_per_s=(
                            req.max_new_tokens / (now - submit_s[req.request_id])
                            if now > submit_s[req.request_id] else float("inf")
                        ),
                    )
                    pool.release(slot)
                    slot_len.pop(slot, None)
            # admission at the decode-step boundary: fill every free slot
            while queue and pool.num_free:
                req = queue.popleft()
                slot = pool.admit(req, step=step)
                padded = np.zeros((prefill_len,), np.int32)
                batch1 = {}
                if self._ragged_ok:
                    padded[: len(req.prompt)] = req.prompt
                    batch1["lengths"] = jnp.asarray([len(req.prompt)], jnp.int32)
                else:
                    padded[prefill_len - len(req.prompt):] = req.prompt
                batch1["tokens"] = jnp.asarray(padded[None])
                logits1, filled = self._prefill(self.params, batch1, cache1)
                self._prefill_shapes.add((1, prefill_len))
                tok0 = int(jnp.argmax(logits1[0]))
                cache = write(cache, filled, jnp.int32(slot))
                nxt[slot] = tok0
                slot_tokens[slot] = [tok0]
                slot_len[slot] = (
                    len(req.prompt) if self._ragged_ok else prefill_len
                )
                req._admit_step = step          # type: ignore[attr-defined]
                req._ttft_s = clock() - submit_s[req.request_id]  # type: ignore
            if not pool.num_active:
                if queue:
                    continue            # freshly-retired slots admit the queue
                break
            if all(
                len(slot_tokens[s]) >= pool.active[s].max_new_tokens
                for s in pool.active
            ):
                continue  # every active slot is at budget: retire, don't decode
            # one decode step for the whole pool (inactive slots are ignored);
            # the kv bound tracks the longest live slot, not padded max_seq
            decode = self._decode_step_fn(
                False, self._kv_bucket(max(slot_len.values()) + 1)
            )
            logits, cache = decode(self.params, jnp.asarray(nxt), cache)
            tokens_all = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
            step += 1
            occupancy_sum += pool.num_active
            for slot in pool.active:
                if len(slot_tokens[slot]) < pool.active[slot].max_new_tokens:
                    slot_tokens[slot].append(int(tokens_all[slot]))
                    nxt[slot] = tokens_all[slot]
                    slot_len[slot] += 1
        jax.block_until_ready(cache["pos"])
        wall = clock() - t_start
        results = [finished[r.request_id] for r in requests]
        total_tokens = sum(len(r.tokens) for r in results)
        return ContinuousStats(
            results=results,
            steps=step,
            wall_s=wall,
            total_tokens=total_tokens,
            throughput_tps=total_tokens / wall if wall > 0 else float("inf"),
            mean_slot_occupancy=occupancy_sum / step if step else float(num_slots),
        )

    # -- paged serving -------------------------------------------------------
    def _paged_decode_fn(self, pages_bound: int) -> Callable:
        """One fused paged decode step: attention + on-device argmax + the
        device-resident next-token/position bump for masked rows.  Fetching
        the returned ``tok`` array is the boundary's only host sync — no
        separate argmax dispatch, no per-step table/position re-upload."""
        fn = self._paged_decode_fns.get(pages_bound)
        if fn is None:

            def step(params, nxt, cache, table, pos, mask):
                logits, cache = self.model.decode_paged(
                    params, nxt, cache, table, pos, pages_bound=pages_bound
                )
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                new_nxt = jnp.where(mask, tok, nxt)
                new_pos = jnp.where(mask, pos + 1, pos)
                return tok, new_nxt, new_pos, cache

            fn = jax.jit(self._ruled(step), donate_argnums=(1, 2, 4))
            self._paged_decode_fns[pages_bound] = fn
        return fn

    def _spec_decode_fn(self, pages_bound: int, W: int) -> Callable:
        """One fused verify step: multi-token paged attention over each
        slot's ``[next_token, draft_1..draft_k]`` window + on-device greedy
        argmax + exact-match draft acceptance + the position bump by
        ``accepted + 1``.  One jit variant per (pages bucket, window size)
        — draft depth is a config knob, not a per-step shape.  Returns
        ``(greedy (b, W), n_accept (b,), new_pos, new_nxt, cache)``; greedy
        row ``w`` is the model's next token after consuming the window's
        first ``w + 1`` tokens, so the emitted tokens
        ``greedy[:, :n_accept + 1]`` are bit-identical to the
        non-speculative decode sequence.  Positions and the next-token
        mirror advance on device, so a verify boundary leaves nothing to
        re-upload before the next launch."""
        key = (pages_bound, W)
        fn = self._spec_decode_fns.get(key)
        if fn is None:

            def step(params, win, cache, table, pos, wlens, nxt):
                logits, cache = self.model.decode_spec(
                    params, win, cache, table, pos, wlens,
                    pages_bound=pages_bound,
                )
                greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                if W > 1:
                    # draft j survives iff it equals the model's own greedy
                    # choice at the previous position AND every earlier
                    # draft survived (cumprod); pad columns never match
                    m = (win[:, 1:] == greedy[:, :-1]) & (
                        jnp.arange(1, W, dtype=jnp.int32)[None, :]
                        < wlens[:, None]
                    )
                    n_accept = (
                        jnp.cumprod(m.astype(jnp.int32), axis=1)
                        .sum(axis=1)
                        .astype(jnp.int32)
                    )
                else:
                    n_accept = jnp.zeros(win.shape[:1], jnp.int32)
                active = wlens > 0
                new_pos = jnp.where(active, pos + n_accept + 1, pos)
                # last emitted token = greedy at the last accepted position:
                # advancing the next-token mirror on device leaves a verify
                # boundary with nothing to re-upload before the next launch
                last = jnp.take_along_axis(greedy, n_accept[:, None], axis=1)
                new_nxt = jnp.where(active, last[:, 0], nxt)
                return greedy, n_accept, new_pos, new_nxt, cache

            fn = jax.jit(self._ruled(step), donate_argnums=(2, 4, 6))
            self._spec_decode_fns[key] = fn
        return fn

    def _paged_prefill_fn(self, chunk_len: int, pos0: int) -> Callable:
        """Chunk shapes are page-bucketed, so variants are keyed by
        (chunk_len, pos0) with at most ``prefill_chunk / page_size`` chunk
        lengths and ``max_seq / prefill_chunk`` offsets (the context-gather
        shape is exactly ``pos0`` tokens — garbage-free, at the price of one
        variant per chunk offset, shared across all requests)."""
        key = (chunk_len, pos0)
        fn = self._paged_prefill_fns.get(key)
        if fn is None:
            fn = jax.jit(
                self._ruled(partial(self.model.prefill_paged_chunk, pos0=pos0)),
                donate_argnums=(2,),
            )
            self._paged_prefill_fns[key] = fn
        return fn

    def _packed_prefill_fn(self, t_pack: int, num_chunks: int,
                           max_pages: int, pages_bound: int) -> Callable:
        """One jit variant per (packed length, chunk rows, table width,
        context-pages bound) — i.e. ONE compile per serve configuration for
        every way prompt lengths mix inside the buffer, times a logarithmic
        handful of pow2 ``pages_bound`` buckets (the bound keeps a launch
        whose chunks have little committed context from paying the
        full-table context gather)."""
        key = (t_pack, num_chunks, max_pages, pages_bound)
        fn = self._packed_prefill_fns.get(key)
        if fn is None:
            fn = jax.jit(
                self._ruled(
                    partial(self.model.prefill_packed, pages_bound=pages_bound)
                ),
                donate_argnums=(2,),
            )
            self._packed_prefill_fns[key] = fn
        return fn

    def serve_paged(
        self,
        requests: List[ServeRequest],
        num_slots: Optional[int] = None,
        page_size: Optional[int] = None,
        num_pages: Optional[int] = None,
        prefill_chunk: Optional[int] = None,
        overcommit: float = 1.0,
        prefill_mode: str = "packed",
        prefill_budget: Optional[int] = None,
        spec_k: int = 0,
        spec_ngram: int = 3,
        prefix_cache: bool = False,
        clock: Callable[[], float] = time.perf_counter,
        tracer=None,
        fault_hook: Optional[Callable] = None,
        deadline_ms: float = 0.0,
        tenants: Optional[List[TenantSpec]] = None,
        fairness: bool = True,
        checkpoint_every: int = 0,
        checkpoints: Optional[Dict[int, PageSnapshot]] = None,
        restores: Optional[Dict[int, PageSnapshot]] = None,
    ) -> PagedStats:
        """Paged-KV continuous batching.

        The KV cache is a global pool of ``num_pages`` pages of ``page_size``
        tokens; each slot owns only the pages its live tokens need, recorded
        in a per-slot page table.  Admission is keyed on *free pages*: a
        request enters when a slot and its prompt's pages are available AND
        the pool's committed worst-case pages (every active request's
        ``prompt + max_new_tokens``) stay within ``capacity * overcommit`` —
        at the default 1.0 growth can never fail, so preemption never fires.
        ``overcommit > 1`` admits more aggressively (live usage is usually
        far below worst case); if the gamble loses and a decode step finds
        the pool dry, the youngest request is preempted (pages freed,
        request requeued for recompute-style restart).

        Prefill interleaves with decode at step boundaries in one of two
        pipelines.  ``prefill_mode="packed"`` (default) coalesces every
        prefilling slot's next span into ONE token-packed varlen launch of
        ``prefill_budget`` tokens per boundary (oldest request first): no
        pow2 padding, the kernel writes K/V straight into the page pool,
        and one jit variant serves every length mix — ``prefill_budget`` is
        the knob bounding how much prefill work may delay the decode step.
        ``prefill_mode="chunked"`` is the legacy path: one
        ``prefill_chunk``-token batch-1 chunk per slot per boundary, one
        jit variant per chunk length × offset.  Greedy tokens are identical
        to ``serve_continuous`` in both modes.

        ``spec_k > 0`` turns on self-speculative decoding: at each boundary
        a host-side prompt-lookup drafter (n-gram match of the last
        ``spec_ngram`` committed tokens against the request's prompt +
        output) proposes up to ``spec_k`` draft tokens per slot, and ONE
        multi-token verification launch scores every slot's ``[next_token,
        draft_1..draft_k]`` window against the paged pool — the KV working
        set streams once for up to ``spec_k + 1`` tokens.  Acceptance is
        greedy exact-match, so emitted tokens stay bit-identical to the
        non-speculative path; rejected suffixes roll back by rewinding
        ``lengths`` (pages are append-only) plus a page-table truncation
        when a rejected draft had opened a fresh page.  Boundaries where no
        slot has a draft fall back to a plain fused decode step, so
        lookup-hostile text pays only the host-side scan.

        ``prefix_cache=True`` turns on automatic prefix caching: every full
        prompt page a request prefills is registered in a
        :class:`~repro.serve.page_table.PrefixCache` (hash-chained token
        blocks -> physical pages), and admission maps the longest cached
        page-aligned prefix of each new prompt read-only into the slot's
        table — only the uncached suffix is prefilled (page-aligned, so the
        packed/chunked pipelines need no new shapes), cached tokens cost
        the :class:`PrefillBudget` nothing, and the worst-case page
        commitment counts shared pages ONCE globally, multiplying peak
        concurrency on shared-prefix workloads.  A full hit (page-aligned
        prompt entirely cached) skips prefill outright and replays the last
        prompt token through the decode path — the append into the shared
        last page copy-on-writes it to a private page first (a device-side
        page copy), so cached content is never mutated and greedy tokens
        stay bit-identical to a cache-off run.  Pages released by finished
        requests stay cached (refcount 1: the cache's own reference) in an
        LRU tier reclaimed only when admission/growth/COW actually need
        pages; eviction never touches a referenced page, and preemption
        still works unchanged (shared pages just drop a reference).

        ``fault_hook`` (None by default — the zero-cost path) is called once
        per loop boundary with a :class:`~repro.serve.faults.FaultContext`
        (step counter, page pool, clock, tracer): the fleet's fault
        injection and heartbeat-lease hooks both ride it.  A hook that
        raises :class:`~repro.serve.faults.WorkerCrash` kills the run, but
        resumably: the exception is re-raised carrying ``results`` (every
        request already finished — commit-worthy) and ``pending`` (every
        request not yet finished — replayable from its prompt, exactly the
        preemption-recompute contract), so a router can requeue the
        worker's in-flight work onto survivors with zero silent losses.

        ``deadline_ms > 0`` sets a run TTL (fleet-parity semantics): a
        request still queued past the deadline is terminally ``rejected``
        (never silently dropped), and a request that finishes late stays
        ``completed`` but falls out of ``goodput``.  With a warm decode-rate
        estimate, admission also sheds queued work whose deadline is
        already unmeetable given the queue's prompt tokens ahead, the
        per-boundary prefill budget, and the measured decode tok/s.
        ``tenants`` registers :class:`~repro.serve.scheduler.TenantSpec`
        contracts (priority tier, fair-share weight, token bucket charged
        in prompt+decode tokens); admission then dequeues by priority tier
        and weighted fair share instead of FIFO (work-conserving: dry
        tenants are deprioritized, never starved), and preemption evicts
        the lowest-priority youngest slot first.  ``fairness=False`` keeps
        strict FIFO admission (the baseline the SLO benchmark compares
        against).

        ``checkpoint_every=K > 0`` (with a ``checkpoints`` dict) makes
        in-flight KV state a transferable artifact: every K decode steps,
        each decoding slot's live pages are gathered into a contiguous
        :class:`~repro.serve.page_table.PageSnapshot` (exact stored bytes —
        quantized pools snapshot codes + scales — plus per-page checksums,
        lengths and emitted tokens) and written to ``checkpoints`` keyed by
        request id.  The checkpoint runs at the boundary top, BEFORE the
        fault hook, so a crash at boundary S leaves checkpoints as-of S
        (staleness is bounded by the cadence K).  A
        :class:`~repro.serve.faults.WorkerDrain` raised by the hook
        additionally snapshots every live decoding slot fresh before the
        crash re-raises — planned handoff loses zero tokens.  ``restores``
        maps request ids to snapshots a previous worker checkpointed: at
        admission such a request skips prefill entirely — checksums are
        verified, pages scatter into freshly allocated pages, lengths and
        emitted tokens rebuild the slot, and decoding continues
        bit-identically to an undisturbed run.  A failed verify counts a
        ``checksum_failure``, drops the snapshot, and the request falls
        back to ordinary prefill (replay-from-prompt) — corrupted state is
        never served.
        """
        if prefill_mode not in ("packed", "chunked"):
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
        if spec_k < 0:
            raise ValueError("spec_k must be >= 0")
        if spec_ngram < 1:
            raise ValueError("spec_ngram must be >= 1")
        if checkpoint_every < 0:
            raise ValueError("checkpoint_every must be >= 0")
        if checkpoint_every > 0 and checkpoints is None:
            raise ValueError("checkpoint_every > 0 needs a checkpoints dict")
        if not requests:
            return PagedStats([], 0, 0.0, 0, 0.0, 0.0, 0, self.page_size, 0,
                              0.0, 0, 0, 0, {}, prefill_mode=prefill_mode,
                              tp=self.tp,
                              kv_dtype=self.kv_dtype or self.cache_dtype)
        if overcommit <= 0:
            raise ValueError("overcommit must be > 0")
        compiles_before = self.compile_stats()
        page_size = page_size or self.page_size
        num_slots = num_slots or self.max_batch
        prefill_chunk = prefill_chunk or 4 * page_size
        prefill_chunk = max(
            page_size, (prefill_chunk // page_size) * page_size
        )  # chunk starts must stay page-aligned
        packed = prefill_mode == "packed"
        # packed-buffer size: the per-boundary prefill token budget, snapped
        # to a page multiple (chunk spans inside the buffer are page-aligned)
        t_pack = max(
            page_size,
            ((prefill_budget or 4 * prefill_chunk) // page_size) * page_size,
        )
        budget = PrefillBudget(t_pack) if packed else None
        max_pages_per_seq = pages_needed(self.max_seq, page_size)
        if num_pages is None:
            num_pages = num_slots * max_pages_per_seq + 1
        pool = PagePool(num_pages, page_size, reserved=1)
        # admission budget: worst-case commitment per the overcommit factor,
        # but never above physical capacity (growth still needs real pages)
        commit_budget = min(pool.capacity, pool.capacity * overcommit)
        for r in requests:
            if len(r.prompt) + r.max_new_tokens > self.max_seq:
                raise ValueError(
                    f"request {r.request_id}: prompt + generation exceeds max_seq"
                )
            if pool.pages_needed(len(r.prompt) + r.max_new_tokens) > commit_budget:
                raise ValueError(
                    f"request {r.request_id}: needs more pages than the pool "
                    f"(or overcommit budget) admits"
                )
        slots = PagedSlotPool(num_slots, pool, tracer=tracer, clock=clock)
        table = PageTable(num_slots, max_pages_per_seq, scratch_page=0)
        pcache = PrefixCache(pool) if prefix_cache else None
        # quantized mode swaps the pool dtype and adds the f32 scale pools
        # (paged_cache_defs branches on the dtype string)
        pool_dtype = self.kv_dtype or self.cache_dtype
        cache = self.model.init_paged_cache(
            num_pages, page_size, dtype=pool_dtype
        )
        if self.rules is not None:
            # heads-split pool: each shard holds kv/tp heads of EVERY page,
            # so a fixed per-shard page budget carries tp× the tokens while
            # the PagePool/PageTable accounting above stays host-global
            # (the scale pools shard on the same kv-head axis)
            cache = jax.device_put(
                cache,
                _named_shardings(
                    self.rules.mesh,
                    self.model.paged_cache_pspecs(
                        self.rules, num_pages, page_size,
                        dtype=pool_dtype,
                    ),
                ),
            )
        queue = deque(requests)
        # -- SLO / multi-tenant admission state ---------------------------
        tenant_ledger = TenantLedger(tenants or ())
        fair = fairness and (
            bool(tenants)
            or any(getattr(r, "tenant", "default") != "default"
                   or getattr(r, "priority", 1) != 1 for r in requests)
        )

        def req_cost(r) -> float:
            # bucket charge: prompt + worst-case decode tokens
            return float(len(r.prompt) + r.max_new_tokens)

        def req_prio(r) -> int:
            p = getattr(r, "priority", None)
            return 1 if p is None else int(p)

        rejected_n = 0
        deferred_n = 0
        decode_tokens_emitted = 0
        nxt = np.zeros((num_slots,), np.int32)
        lengths = np.zeros((num_slots,), np.int32)   # live tokens per slot
        slot_tokens: Dict[int, List[int]] = {}
        slot_times: Dict[int, List[float]] = {}      # token-emission clocks
        prefilling: Dict[int, int] = {}              # slot -> next chunk start
        decoding: set = set()
        admit_order: Dict[int, int] = {}             # slot -> admission sequence
        admit_seq = 0
        # prefix-cache bookkeeping: per-slot worst-case PRIVATE page
        # commitment, cached tokens granted at admission, prompt tokens
        # prefilled this admission, and full-hit slots awaiting their first
        # decode emission (their TTFT is that boundary, not a prefill)
        slot_commit: Dict[int, int] = {}
        slot_cached: Dict[int, int] = {}
        slot_prefilled: Dict[int, int] = {}
        replay_first: set = set()
        # slots rebuilt from a migrated snapshot: their prompt was never
        # admitted to THIS worker's prefill ledger, so a later preemption
        # must not charge it as dropped prefill debt
        restored_slots: set = set()
        # pages slots mapped FROM the cache (not allocated themselves): the
        # commitment ledger counts each of these once globally, no matter
        # how many requests share it — the concurrency multiplier
        pinned_refs: Dict[int, int] = {}             # page -> mapping slots
        slot_shared: Dict[int, List[int]] = {}       # slot -> acquired pages
        finished: Dict[int, RequestResult] = {}
        t_start = clock()
        submit_s = {r.request_id: t_start for r in requests}
        deadline = t_start + deadline_ms / 1e3 if deadline_ms > 0 else None
        step = 0
        occupancy_sum = 0
        peak_occupancy = 0
        pages_sum = 0.0
        samples = 0
        chunks_done = 0
        prefill_launches = 0
        prefill_s = 0.0
        prefill_tokens = 0
        prefill_padded = 0
        prompt_admitted = 0
        saved_tokens = 0
        dropped_tokens = 0
        cow_copies = 0
        ckpt_saved = 0
        ckpt_bytes = 0
        restored_n = 0
        restored_tok = 0
        restore_bytes = 0
        checksum_failures = 0
        last_ckpt_step = -1
        decode_s = 0.0
        spec = spec_k > 0
        ledger = SpecLedger() if spec else None
        itl_all: List[float] = []                    # every inter-token gap
        # -- device-resident decode state: the page table, per-slot positions
        # and (non-spec) next tokens / active mask live on device and are
        # patched only for slots that changed (admission, page growth,
        # release, rollback) — steady-state boundaries upload nothing and
        # fetch one small int32 array (the fused argmax / acceptance result)
        dev_table = jnp.zeros((num_slots, max_pages_per_seq), jnp.int32)
        dev_pos = jnp.zeros((num_slots,), jnp.int32)
        dev_nxt = jnp.zeros((num_slots,), jnp.int32)
        dev_mask = jnp.zeros((num_slots,), bool)
        if self.rules is not None:
            # explicitly replicated so the donated mirror-patch scatter and
            # the decode launches agree on placement from the first step
            # (no GSPMD resharding inserted at a steady-state boundary)
            rep = NamedSharding(self.rules.mesh, PartitionSpec())
            dev_table, dev_pos, dev_nxt, dev_mask = (
                jax.device_put(a, rep)
                for a in (dev_table, dev_pos, dev_nxt, dev_mask)
            )
        cur_mask = np.zeros((num_slots,), bool)
        dirty: set = set()                           # slots needing a patch
        # -- analytic TP-collective ledger: every transformer layer closes
        # two tensor-parallel boundaries (attention o-proj, MLP down-proj),
        # each summing a (tokens, d_model) partial block output across the
        # model axis.  Ring all-reduce moves 2(tp-1)/tp of the payload per
        # shard; reduce-scatter (rs_block_outputs, seq-shardable launches
        # only) halves that.  Emitted per launch for analysis.tp_summary.
        tp = self.tp
        rs_opt = bool(
            self.rules is not None
            and self.rules.opts.get("rs_block_outputs")
        )
        d_model = int(getattr(self.model.cfg, "d_model", 0) or 0)
        n_layers = int(getattr(self.model.cfg, "num_layers", 0) or 0)

        def tp_event(phase: str, t0: float, t1: float, tokens: int,
                     seq_shardable: bool = False) -> None:
            if tp <= 1 or tracer is None or not tokens:
                return
            kind = (
                "reduce_scatter"
                if rs_opt and seq_shardable and tokens % tp == 0
                else "psum"
            )
            count = 2 * n_layers
            payload = tokens * d_model * 4           # f32 block outputs
            factor = (tp - 1) / tp * (2.0 if kind == "psum" else 1.0)
            tracer.event(
                "tp:collective", t0, t1, phase=phase, kind=kind, tp=tp,
                count=count, payload_bytes=payload * count,
                moved_bytes=int(payload * count * factor),
            )

        def sync_device(active: List[int]) -> None:
            """Patch the device mirrors for slots whose table row, position,
            next token or active-mask bit changed since the last launch —
            one jitted donated scatter over exactly the dirty slots."""
            nonlocal dev_table, dev_pos, dev_nxt, dev_mask, cur_mask
            new_mask = np.zeros((num_slots,), bool)
            new_mask[active] = True
            stale = dirty | set(np.nonzero(new_mask != cur_mask)[0].tolist())
            if stale:
                # pad the dirty set to a pow2 bucket with repeats of the
                # last dirty slot (duplicate scatter indices write the same
                # values, so the patch is idempotent): log2(num_slots)
                # variants instead of one per distinct dirty count
                cnt = bucket_pow2(len(stale), cap=num_slots)
                # keyed by the full traced shape: a same-engine run with a
                # different slot count / table width re-traces the patch jit
                # and must show up in the compile delta
                self._mirror_patch_shapes.add((num_slots, max_pages_per_seq, cnt))
                idx = np.fromiter(sorted(stale), np.int32, len(stale))
                idx = np.concatenate(
                    [idx, np.full((cnt - len(idx),), idx[-1], np.int32)]
                )
                rows = np.where(
                    new_mask[idx, None], table.table[idx], np.int32(0)
                )
                dev_table, dev_pos, dev_nxt, dev_mask = self._mirror_patch(
                    dev_table, dev_pos, dev_nxt, dev_mask, idx, rows,
                    np.where(new_mask[idx], lengths[idx], 0).astype(np.int32),
                    np.where(new_mask[idx], nxt[idx], 0).astype(np.int32),
                    new_mask[idx],
                )
                cur_mask = new_mask
                dirty.clear()

        def unpin(slot: int, page: int) -> None:
            """Drop ``slot``'s record of mapping ``page`` from the cache (the
            commitment ledger's pinned set must mirror the actual mappings)."""
            held = slot_shared.get(slot, [])
            if page in held:
                held.remove(page)
                pinned_refs[page] -= 1
                if not pinned_refs[page]:
                    del pinned_refs[page]

        def release_slot(slot: int, preempted: bool = False):
            nonlocal dropped_tokens
            req = slots.release_paged(slot, table.clear(slot), preempted=preempted)
            if preempted and slot not in restored_slots:
                # prompt tokens this admission promised but never prefilled:
                # the recompute debt the saved-token ledger must stay exact
                # against (cached grants + computed tokens cover the rest)
                dropped_tokens += max(
                    len(req.prompt)
                    - slot_cached.get(slot, 0)
                    - slot_prefilled.get(slot, 0),
                    0,
                )
            lengths[slot] = 0
            slot_tokens.pop(slot, None)
            slot_times.pop(slot, None)
            prefilling.pop(slot, None)
            decoding.discard(slot)
            admit_order.pop(slot, None)
            slot_commit.pop(slot, None)
            slot_cached.pop(slot, None)
            slot_prefilled.pop(slot, None)
            replay_first.discard(slot)
            restored_slots.discard(slot)
            for p in list(slot_shared.get(slot, [])):
                unpin(slot, p)
            slot_shared.pop(slot, None)
            dirty.add(slot)
            return req

        def preempt_one() -> Optional[int]:
            """Evict the lowest-priority youngest request (recompute-style):
            free its pages and push it back to the queue front.  Within one
            priority tier this is the globally youngest slot; best-effort
            work is always evicted before any higher tier.  The victim may
            be the very slot that asked to grow — self-preemption parks it
            back in the queue rather than evicting older work for it."""
            if not admit_order:
                return None
            victim = min(
                admit_order,
                key=lambda s: (req_prio(slots.active[s]), -admit_order[s]),
            )
            queue.appendleft(release_slot(victim, preempted=True))
            return victim

        def ensure_free(n: int) -> bool:
            """Guarantee ``n`` free pages, reclaiming cached-but-unreferenced
            pages (LRU, true free) before the caller has to queue or preempt
            live work — the ONLY path that evicts cache entries (the run's
            eviction count is the cache's own ``evicted_pages``)."""
            if pool.num_free >= n:
                return True
            if pcache is not None:
                evicted = pcache.evict(n - pool.num_free)
                if evicted and tracer is not None:
                    now = clock()
                    tracer.event("prefix:evict", now, now, pages=evicted)
            return pool.num_free >= n

        def cow_if_shared(s: int) -> bool:
            """Copy-on-write guard before any append at position
            ``lengths[s]``: if the destination page is still referenced by
            other holders (the prefix cache / other requests), duplicate it
            on device into a private page and remap the slot's table —
            committed cache content is never mutated.  Returns False when
            no page can be found for the copy (caller preempts)."""
            nonlocal cache, cow_copies
            li = int(lengths[s]) // page_size
            held = table.pages_of(s)
            if li >= len(held):
                return True          # append opens a fresh page (growth path)
            p = held[li]
            if pool.refcount(p) <= 1:
                return True          # exclusively ours already
            if not ensure_free(1):
                return False
            fresh = pool.alloc(1)
            if fresh is None:  # pragma: no cover - guarded by ensure_free
                return False
            t0c = clock()
            src_d = np.asarray([p], np.int32)
            dst_d = np.asarray([fresh[0]], np.int32)
            if "k_scales" in cache:
                # the scale rows move with their pages
                (cache["k_pages"], cache["v_pages"],
                 cache["k_scales"], cache["v_scales"]) = self._cow_copy_q(
                    cache["k_pages"], cache["v_pages"], src_d, dst_d,
                    cache["k_scales"], cache["v_scales"],
                )
            else:
                cache["k_pages"], cache["v_pages"] = self._cow_copy(
                    cache["k_pages"], cache["v_pages"], src_d, dst_d,
                )
            # pool shapes are per-call arguments: one jit variant per
            # (pool size, page size) configuration
            self._cow_shapes.add((num_pages, page_size))
            table.replace(s, li, fresh[0])
            pool.free([p])           # drop our reference to the shared page
            unpin(s, p)              # no longer mapped from the cache
            cow_copies += 1
            dirty.add(s)
            if tracer is not None:
                tracer.event("prefix:cow", t0c, clock(), slot=s, page=fresh[0])
            return True

        def snapshot_slot(s: int) -> Optional[PageSnapshot]:
            """Gather slot ``s``'s live pages into a transferable
            :class:`PageSnapshot`: one jitted gather of exactly the pages
            holding its first ``lengths[s]`` tokens (K/V pools and, when
            quantized, the parallel scale pools — exact stored bytes), plus
            emitted tokens, length and per-page checksums.  The gather index
            is padded to a pow2 bucket with repeats of the last real page
            (sliced off host-side) so variant count stays log2-bounded.
            Returns None for slots still prefilling (nothing to migrate —
            replay-from-prompt is already the cheapest recovery for them)."""
            nonlocal ckpt_saved, ckpt_bytes
            if s not in decoding or not slot_tokens.get(s):
                return None
            req = slots.active[s]
            length = int(lengths[s])
            held = table.pages_of(s)[: pool.pages_needed(max(length, 1))]
            if not held:
                return None
            t0s = clock()
            cnt = bucket_pow2(len(held), cap=max_pages_per_seq)
            self._xfer_shapes.add((num_pages, page_size, cnt))
            idx = np.fromiter(held, np.int32, len(held))
            idx = np.concatenate(
                [idx, np.full((cnt - len(idx),), idx[-1], np.int32)]
            )
            if "k_scales" in cache:
                arrs = self._export(
                    cache["k_pages"], cache["v_pages"], idx,
                    cache["k_scales"], cache["v_scales"],
                )
                k, v, ks, vs = (
                    np.asarray(a)[:, : len(held)] for a in arrs
                )
            else:
                arrs = self._export(cache["k_pages"], cache["v_pages"], idx)
                k, v = (np.asarray(a)[:, : len(held)] for a in arrs)
                ks = vs = None
            snap = PageSnapshot(
                request_id=req.request_id,
                prompt_len=len(req.prompt),
                length=length,
                tokens=np.asarray(slot_tokens[s], np.int32),
                k=k, v=v, k_scales=ks, v_scales=vs,
                checksums=page_checksums(k, v, ks, vs),
                step=step,
                kv_dtype=pool_dtype,
            )
            ckpt_saved += 1
            ckpt_bytes += snap.nbytes
            if tracer is not None:
                tracer.event(
                    "ckpt:save", t0s, clock(), request=req.request_id,
                    step=step, pages=len(held), bytes=snap.nbytes,
                    tokens=len(slot_tokens[s]),
                )
            return snap

        def emit_tenant(req, status: str, now: float, latency: float) -> None:
            if tracer is None:
                return
            slo = getattr(req, "slo_ms", 0.0) or deadline_ms
            slo_ok = (status == "completed"
                      and (slo <= 0 or latency * 1e3 <= slo))
            tracer.event(
                "sched:tenant", now, now,
                tenant=getattr(req, "tenant", "default"),
                priority=req_prio(req),
                status=status,
                latency_s=latency,
                slo_ms=slo,
                slo_ok=slo_ok,
                tokens=req_cost(req),
            )

        def reject(req, reason: str) -> None:
            """Terminal ``rejected`` result — fleet parity, never silent."""
            nonlocal rejected_n
            now_r = clock()
            latency = now_r - submit_s[req.request_id]
            finished[req.request_id] = RequestResult(
                request_id=req.request_id,
                tokens=np.zeros((0,), np.int32),
                slot=-1,
                admit_step=-1,
                finish_step=step,
                ttft_s=0.0,
                latency_s=latency,
                tokens_per_s=0.0,
                status="rejected",
                reason=reason,
                tenant=getattr(req, "tenant", "default"),
                priority=req_prio(req),
                within_deadline=False,
            )
            rejected_n += 1
            emit_tenant(req, "rejected", now_r, latency)

        def unmeetable(req, queued_prompt_ahead: int, now: float) -> bool:
            """SLO-aware admission estimate: the queue's prompt tokens ahead
            flow through the per-boundary prefill budget, then the request
            decodes at the measured per-slot tok/s — shed it when even that
            optimistic finish lands past the deadline."""
            if deadline is None or step == 0 or decode_s <= 0:
                return False
            decode_tps = decode_tokens_emitted / decode_s
            if decode_tps <= 0:
                return False
            boundary_s = (prefill_s + decode_s) / step
            prefill_wait = (
                (queued_prompt_ahead + len(req.prompt)) / t_pack * boundary_s
                if packed else 0.0
            )
            per_slot_tps = decode_tps / max(1, slots.num_active)
            est_finish = now + prefill_wait + req.max_new_tokens / per_slot_tps
            return est_finish > deadline

        def pick_admission(now: float) -> int:
            """Index of the next admission candidate: priority tier first,
            then weighted fair share across tenants (dry buckets sink the
            tenant — work-conserving rate limiting), then FIFO order."""
            if not fair or len(queue) == 1:
                return 0
            best, best_key = 0, None
            for i, r in enumerate(queue):
                tname = getattr(r, "tenant", "default")
                dry = 1 if tenant_ledger.dry(tname, req_cost(r), now) else 0
                key = (dry, -req_prio(r),
                       tenant_ledger.vtime.get(tname, 0.0), i)
                if best_key is None or key < best_key:
                    best, best_key = i, key
            return best

        while queue or slots.num_active:
            progressed = False
            # 0a) periodic checkpoint: runs BEFORE the fault hook, so a
            #     crash at boundary S observes checkpoints as-of S — the
            #     migration staleness bound is exactly the cadence.  Cadence
            #     is keyed on the decode-step counter, once per value
            #     (prefill-only boundaries don't advance ``step``).
            if (
                checkpoint_every > 0
                and checkpoints is not None
                and step > 0
                and step % checkpoint_every == 0
                and step != last_ckpt_step
            ):
                last_ckpt_step = step
                for s in sorted(decoding):
                    snap = snapshot_slot(s)
                    if snap is not None:
                        checkpoints[snap.request_id] = snap
            # 0b) boundary fault/heartbeat hook.  WorkerCrash can only be
            #    raised here, so the resumable snapshot (finished results +
            #    replayable pending requests) is attached at this one site.
            if fault_hook is not None:
                try:
                    fault_hook(FaultContext(
                        step=step, pool=pool, clock=clock, tracer=tracer,
                        checkpoints=checkpoints,
                    ))
                except WorkerCrash as crash:
                    if isinstance(crash, WorkerDrain) and checkpoints is not None:
                        # planned drain: snapshot EVERY live decoding slot
                        # fresh (not the stale periodic copy) so the router
                        # migrates all of them with zero recompute
                        for s in sorted(decoding):
                            snap = snapshot_slot(s)
                            if snap is not None:
                                checkpoints[snap.request_id] = snap
                    crash.results = [
                        finished[r.request_id] for r in requests
                        if r.request_id in finished
                    ]
                    crash.pending = [
                        r for r in requests if r.request_id not in finished
                    ]
                    if hasattr(fault_hook, "release"):
                        fault_hook.release()   # return seized pressure pages
                    raise
            # 1) retire finished sequences, returning their pages
            for slot in list(decoding):
                req = slots.active[slot]
                if len(slot_tokens[slot]) >= req.max_new_tokens:
                    now = clock()
                    itls = [
                        b - a for a, b in zip(
                            slot_times.get(slot, []), slot_times.get(slot, [])[1:]
                        )
                    ]
                    itl_all.extend(itls)
                    prop, acc = ledger.of(req.request_id) if ledger else (0, 0)
                    latency = now - submit_s[req.request_id]
                    finished[req.request_id] = RequestResult(
                        request_id=req.request_id,
                        tokens=np.asarray(slot_tokens[slot], np.int32),
                        slot=slot,
                        admit_step=req._admit_step,  # type: ignore[attr-defined]
                        finish_step=step,
                        ttft_s=req._ttft_s,          # type: ignore[attr-defined]
                        latency_s=latency,
                        tokens_per_s=(
                            req.max_new_tokens / latency
                            if now > submit_s[req.request_id] else float("inf")
                        ),
                        itl_p50_s=percentile(itls, 50.0) if itls else 0.0,
                        itl_p99_s=percentile(itls, 99.0) if itls else 0.0,
                        draft_proposed=prop,
                        draft_accepted=acc,
                        tenant=getattr(req, "tenant", "default"),
                        priority=req_prio(req),
                        # late completions stay completed but fall out of
                        # goodput — the fleet's within_deadline semantics
                        within_deadline=deadline is None or now <= deadline,
                    )
                    emit_tenant(req, "completed", now, latency)
                    release_slot(slot)
                    progressed = True
            # 2) admission keyed on free pages: a request enters only when a
            #    slot AND its prompt's pages are available AND its worst-case
            #    page commitment fits the (possibly overcommitted) pool.
            #    With the prefix cache on, the longest cached page-aligned
            #    prefix is mapped (shared) instead of allocated: only the
            #    uncached suffix needs fresh pages, the commitment ledger
            #    counts each shared page ONCE globally (plus one COW page
            #    for a full hit), and cached-unreferenced pages are evicted
            #    on demand before admission gives up
            if deadline is not None and queue and clock() > deadline:
                # TTL passed while still queued: terminal rejected (fleet
                # parity) — expired work leaves the queue, it never runs
                while queue:
                    reject(queue.popleft(), "deadline")
                progressed = True
            while queue:
                now_adm = clock()
                idx0 = pick_admission(now_adm)
                req0 = queue[idx0]
                if unmeetable(
                    req0,
                    sum(len(r.prompt) for r in queue) - len(req0.prompt),
                    now_adm,
                ):
                    del queue[idx0]
                    reject(req0, "slo-unmeetable")
                    progressed = True
                    continue
                # migrate-restore admission: a request arriving with a
                # checkpointed snapshot skips prefill entirely — verify the
                # per-page checksums, scatter the snapshot into freshly
                # allocated pages, rebuild lengths + emitted tokens, and
                # continue decoding bit-identically.  A failed verify drops
                # the snapshot and falls through to ordinary prefill
                # (replay-from-prompt): corrupted state is never served.
                snap = restores.get(req0.request_id) if restores else None
                if snap is not None and not snap.verify():
                    checksum_failures += 1
                    del restores[req0.request_id]
                    if tracer is not None:
                        now_cf = clock()
                        tracer.event(
                            "migrate:checksum_fail", now_cf, now_cf,
                            request=req0.request_id, step=step,
                            pages=snap.num_pages,
                        )
                    snap = None
                if snap is not None:
                    worst = pool.pages_needed(
                        len(req0.prompt) + req0.max_new_tokens
                    )
                    npages = snap.num_pages
                    committed = sum(slot_commit.values()) + len(pinned_refs)
                    if not slots.num_free:
                        break
                    if committed + worst > pool.capacity * overcommit:
                        break
                    if not ensure_free(npages):
                        break
                    req = req0
                    del queue[idx0]
                    del restores[req.request_id]
                    if fair:
                        tenant_ledger.on_admit(
                            getattr(req, "tenant", "default"), req_cost(req),
                            now_adm,
                        )
                    t0m = clock()
                    slot, pages = slots.admit_paged(req, npages, step=step)
                    table.assign(slot, pages)
                    # scatter the snapshot into the fresh pages: destination
                    # AND source are padded to the pow2 bucket with the last
                    # real page (duplicate scatter indices rewrite the same
                    # bytes, so the import is idempotent)
                    cnt = bucket_pow2(len(pages), cap=max_pages_per_seq)
                    self._xfer_shapes.add((num_pages, page_size, cnt))
                    dst = np.fromiter(pages, np.int32, len(pages))
                    dst = np.concatenate(
                        [dst, np.full((cnt - len(pages),), dst[-1], np.int32)]
                    )
                    sel = np.concatenate([
                        np.arange(len(pages), dtype=np.int32),
                        np.full((cnt - len(pages),), len(pages) - 1, np.int32),
                    ])
                    if "k_scales" in cache:
                        (cache["k_pages"], cache["v_pages"],
                         cache["k_scales"], cache["v_scales"]) = self._import_q(
                            cache["k_pages"], cache["v_pages"], dst,
                            jnp.asarray(snap.k[:, sel]),
                            jnp.asarray(snap.v[:, sel]),
                            cache["k_scales"], cache["v_scales"],
                            jnp.asarray(snap.k_scales[:, sel]),
                            jnp.asarray(snap.v_scales[:, sel]),
                        )
                    else:
                        cache["k_pages"], cache["v_pages"] = self._import(
                            cache["k_pages"], cache["v_pages"], dst,
                            jnp.asarray(snap.k[:, sel]),
                            jnp.asarray(snap.v[:, sel]),
                        )
                    lengths[slot] = snap.length
                    toks = [int(t) for t in snap.tokens]
                    slot_tokens[slot] = toks
                    slot_times[slot] = []
                    nxt[slot] = toks[-1]
                    slot_commit[slot] = worst
                    slot_cached[slot] = 0
                    slot_prefilled[slot] = 0
                    admit_order[slot] = admit_seq
                    admit_seq += 1
                    req._admit_step = step      # type: ignore[attr-defined]
                    # first token was emitted on the source worker; TTFT on
                    # the survivor is the restore latency itself
                    req._ttft_s = clock() - submit_s[req.request_id]  # type: ignore
                    decoding.add(slot)
                    restored_slots.add(slot)
                    dirty.add(slot)
                    restored_n += 1
                    restored_tok += snap.length
                    restore_bytes += snap.nbytes
                    if tracer is not None:
                        tracer.event(
                            "migrate:restore", t0m, clock(),
                            request=req.request_id, pages=len(pages),
                            bytes=snap.nbytes, tokens=len(toks),
                            length=snap.length,
                        )
                    progressed = True
                    continue
                hit_pages: List[int] = []
                cached = 0
                if pcache is not None:
                    hit_pages, cached = pcache.match(req0.prompt)
                full_hit = cached >= len(req0.prompt)
                npages = pool.pages_needed(len(req0.prompt)) - len(hit_pages)
                worst = pool.pages_needed(len(req0.prompt) + req0.max_new_tokens)
                # private worst case: shared pages are not this request's
                # cost (they're pinned once, below); a full hit will split
                # its shared last page copy-on-write, so reserve that page
                commit = worst - len(hit_pages) + (1 if full_hit else 0)
                # shared pages counted once globally: every page some slot
                # already mapped from the cache plus the ones THIS admission
                # would newly pin
                pinned = len(pinned_refs) + sum(
                    1 for p in hit_pages if p not in pinned_refs
                )
                committed = sum(slot_commit.values()) + pinned
                if not slots.num_free:
                    break
                if committed + commit > pool.capacity * overcommit:
                    break
                # pin the hit pages BEFORE eviction runs: they are exactly
                # the cached-unreferenced pages ensure_free may reclaim
                if hit_pages:
                    pool.incref(hit_pages)
                if not ensure_free(npages):
                    if hit_pages:
                        pool.free(hit_pages)
                    break
                req = req0
                del queue[idx0]
                if fair:
                    tenant_ledger.on_admit(
                        getattr(req, "tenant", "default"), req_cost(req),
                        now_adm,
                    )
                if pcache is not None:
                    pcache.record(len(req.prompt), hit_pages)
                slot, pages = slots.admit_paged(req, npages, step=step)
                table.assign(slot, hit_pages + pages)
                for p in hit_pages:
                    pinned_refs[p] = pinned_refs.get(p, 0) + 1
                slot_shared[slot] = list(hit_pages)
                slot_tokens[slot] = []
                slot_commit[slot] = commit
                slot_prefilled[slot] = 0
                prompt_admitted += len(req.prompt)
                admit_order[slot] = admit_seq
                admit_seq += 1
                req._admit_step = step              # type: ignore[attr-defined]
                if full_hit:
                    # every prompt page is cached: skip prefill entirely and
                    # replay the last prompt token through the decode path
                    # (its append copy-on-writes the shared last page); TTFT
                    # collapses to one decode boundary
                    slot_cached[slot] = len(req.prompt)
                    saved_tokens += len(req.prompt)
                    if budget is not None:
                        budget.credit(len(req.prompt))
                    lengths[slot] = len(req.prompt) - 1
                    nxt[slot] = int(req.prompt[-1])
                    slot_times[slot] = []
                    decoding.add(slot)
                    replay_first.add(slot)
                    dirty.add(slot)
                else:
                    slot_cached[slot] = cached
                    saved_tokens += cached
                    if budget is not None and cached:
                        budget.credit(cached)
                    lengths[slot] = cached
                    prefilling[slot] = cached
                if tracer is not None and pcache is not None:
                    now = clock()
                    tracer.event(
                        "prefix:lookup", now, now,
                        prompt_tokens=len(req.prompt), cached_tokens=cached,
                        hit_pages=len(hit_pages), full_hit=int(full_hit),
                    )
                progressed = True
            if fair and queue:
                # tenants whose arrived work was passed over because their
                # bucket ran dry: one deferral per tenant per boundary
                now_d = clock()
                seen_dry: set = set()
                for r in queue:
                    tname = getattr(r, "tenant", "default")
                    if tname not in seen_dry and tenant_ledger.dry(
                            tname, req_cost(r), now_d):
                        seen_dry.add(tname)
                        tenant_ledger.note_defer(tname)
                        deferred_n += 1
                        if tracer is not None:
                            tracer.event("sched:defer", now_d, now_d,
                                         tenant=tname)
            # 3) prefill at the boundary, interleaved with decode.
            #    packed: coalesce every prefilling slot's next span into ONE
            #    token-packed varlen launch (oldest first, capped by the
            #    per-boundary token budget); chunked: one batch-1 chunk per
            #    slot (legacy path, one jit variant per length × offset)
            if prefilling and packed:
                t0p = clock()
                budget.begin_step()
                spans: List[Tuple[int, int, int, int]] = []
                used = 0
                for slot in sorted(prefilling, key=lambda s: admit_order[s]):
                    req = slots.active[slot]
                    rem = len(req.prompt) - prefilling[slot]
                    if used >= t_pack:
                        budget.defer(rem)   # left waiting: starvation signal
                        continue
                    # the buffer cap (padded spans) is never looser than the
                    # ledger (real tokens), so grants keep spans page-aligned
                    take = budget.grant(min(rem, t_pack - used))
                    if take <= 0:
                        budget.defer(rem)
                        continue
                    if take < rem:
                        budget.defer(rem - take)
                    span = pages_needed(take, page_size) * page_size
                    spans.append((slot, prefilling[slot], take, span))
                    used += span
                if spans:
                    num_chunks = num_slots
                    tokens_p = np.zeros((1, t_pack), np.int32)
                    tok_pos = np.zeros((t_pack,), np.int32)
                    # buffer-tail pads scatter their K/V into the scratch
                    # page; offsets cycle so writes spread over its rows
                    dst_page = np.zeros((t_pack,), np.int32)
                    dst_off = (np.arange(t_pack) % page_size).astype(np.int32)
                    cu = np.zeros((num_chunks + 1,), np.int32)
                    lens_c = np.zeros((num_chunks,), np.int32)
                    pos0_c = np.zeros((num_chunks,), np.int32)
                    last_idx = np.zeros((num_chunks,), np.int32)
                    tables_c = np.zeros((num_chunks, max_pages_per_seq), np.int32)
                    off = 0
                    for ci, (slot, start, take, span) in enumerate(spans):
                        req = slots.active[slot]
                        tokens_p[0, off : off + take] = req.prompt[
                            start : start + take
                        ]
                        pos = start + np.arange(span, dtype=np.int32)
                        tok_pos[off : off + span] = pos
                        row = table.table[slot]
                        # chunk-pad K/V lands inside the prompt's already-
                        # allocated pages (length-masked until overwritten),
                        # exactly like the chunked path's padded tail
                        dst_page[off : off + span] = row[pos // page_size]
                        dst_off[off : off + span] = pos % page_size
                        cu[ci + 1] = off + span
                        lens_c[ci] = take
                        pos0_c[ci] = start
                        last_idx[ci] = off + take - 1
                        tables_c[ci] = row
                        off += span
                    cu[len(spans) + 1 :] = off
                    # static bound on committed-context pages this launch,
                    # pow2-bucketed so early (low-context) launches don't
                    # stream/gather the full page-table width
                    ctx_pages = max(
                        pages_needed(start, page_size)
                        for _, start, _, _ in spans
                    )
                    bound = bucket_pow2(max(ctx_pages, 1),
                                        cap=max_pages_per_seq)
                    fn = self._packed_prefill_fn(
                        t_pack, num_chunks, max_pages_per_seq, bound
                    )
                    batch_p = {
                        "tokens": jnp.asarray(tokens_p),
                        "tok_pos": jnp.asarray(tok_pos),
                        "dst_page": jnp.asarray(dst_page),
                        "dst_off": jnp.asarray(dst_off),
                        "cu_seqlens": jnp.asarray(cu),
                        "chunk_lens": jnp.asarray(lens_c),
                        "chunk_pos0": jnp.asarray(pos0_c),
                        "page_tables": jnp.asarray(tables_c),
                        "last_idx": jnp.asarray(last_idx),
                    }
                    logits, cache = fn(self.params, batch_p, cache)
                    jax.block_until_ready(logits)
                    for ci, (slot, start, take, span) in enumerate(spans):
                        req = slots.active[slot]
                        new_start = start + take
                        lengths[slot] = new_start
                        slot_prefilled[slot] = slot_prefilled.get(slot, 0) + take
                        chunks_done += 1
                        if new_start >= len(req.prompt):
                            del prefilling[slot]
                            if pcache is not None:
                                pcache.insert(req.prompt, table.pages_of(slot))
                            tok0 = int(jnp.argmax(logits[ci]))
                            nxt[slot] = tok0
                            slot_tokens[slot] = [tok0]
                            decoding.add(slot)
                            dirty.add(slot)
                            tnow = clock()
                            slot_times[slot] = [tnow]
                            req._ttft_s = tnow - submit_s[req.request_id]  # type: ignore
                        else:
                            prefilling[slot] = new_start
                    real = sum(s[2] for s in spans)
                    prefill_launches += 1
                    prefill_tokens += real
                    prefill_padded += t_pack - real
                    now = clock()
                    prefill_s += now - t0p
                    if tracer is not None:
                        tracer.event(
                            "prefill:packed", t0p, now,
                            tokens=real, padding=t_pack - real,
                            chunks=len(spans), buffer=t_pack,
                            budget=budget.tokens_per_step,
                        )
                    tp_event("prefill", t0p, now, t_pack, seq_shardable=True)
                    progressed = True
            elif prefilling:
                t0p = clock()
                chunk_tok = 0
                for slot in list(prefilling):
                    req = slots.active[slot]
                    start = prefilling[slot]
                    c = min(prefill_chunk, len(req.prompt) - start)
                    # bucket the chunk shape to a page multiple so ragged
                    # prompt tails don't compile one jit variant per distinct
                    # residual; pad K/V lands inside the prompt's already-
                    # allocated pages and stays length-masked until decode
                    # overwrites it
                    c_pad = min(
                        prefill_chunk, pages_needed(c, page_size) * page_size
                    )
                    chunk = np.zeros((1, c_pad), np.int32)
                    chunk[0, :c] = req.prompt[start : start + c]
                    fn = self._paged_prefill_fn(c_pad, start)
                    logits, cache = fn(
                        self.params,
                        jnp.asarray(chunk),
                        cache,
                        jnp.asarray(table.table[slot]),
                        jnp.int32(c - 1),
                    )
                    jax.block_until_ready(logits)
                    chunks_done += 1
                    prefill_launches += 1
                    prefill_tokens += c
                    prefill_padded += c_pad - c
                    chunk_tok += c_pad
                    start += c
                    lengths[slot] = start
                    slot_prefilled[slot] = slot_prefilled.get(slot, 0) + c
                    progressed = True
                    if start >= len(req.prompt):
                        del prefilling[slot]
                        if pcache is not None:
                            pcache.insert(req.prompt, table.pages_of(slot))
                        tok0 = int(jnp.argmax(logits[0]))
                        nxt[slot] = tok0
                        slot_tokens[slot] = [tok0]
                        decoding.add(slot)
                        dirty.add(slot)
                        tnow = clock()
                        slot_times[slot] = [tnow]
                        req._ttft_s = tnow - submit_s[req.request_id]  # type: ignore
                    else:
                        prefilling[slot] = start
                now = clock()
                prefill_s += now - t0p
                tp_event("prefill", t0p, now, chunk_tok, seq_shardable=True)
            # 4) one decode step over the whole pool.  With ``spec_k > 0``
            #    the prompt-lookup drafter proposes up to ``spec_k`` tokens
            #    per slot and ONE verify launch scores every slot's window;
            #    boundaries with no drafts anywhere fall back to a W=1
            #    launch (numerically the plain decode step)
            active_dec = [
                s for s in decoding
                if len(slot_tokens[s]) < slots.active[s].max_new_tokens
            ]
            drafts: Dict[int, List[int]] = {}
            if spec and active_dec:
                for s in active_dec:
                    req = slots.active[s]
                    rem = req.max_new_tokens - len(slot_tokens[s])
                    # a boundary emits accepted+1 tokens: never draft past
                    # the request's token budget or the cache's max_seq
                    cap = min(spec_k, rem - 1,
                              self.max_seq - int(lengths[s]) - 1)
                    if cap > 0:
                        ctx = np.concatenate(
                            [req.prompt, np.asarray(slot_tokens[s], np.int32)]
                        )
                        drafts[s] = ngram_propose(ctx, spec_ngram, cap)
                    else:
                        drafts[s] = []
            # copy-on-write, then growth, for every decoding row.  The next
            # token (plus any draft tokens — the verify scatter writes them
            # too) appends at ``lengths[s]``: if that position lands in a
            # page other holders still reference (a full-hit slot's shared
            # last page), split it into a private copy FIRST; then grow the
            # table for rows whose window opens a new page.  Both paths
            # reclaim cached-unreferenced pages before preempting the
            # youngest request.  Speculative demand must never evict live
            # work (or self-preempt into a recompute loop): when growth
            # fails, first trim the slot's draft to the pages it already
            # holds — only the REAL next token's page may preempt, exactly
            # like the non-spec path
            for s in sorted(active_dec, key=lambda s: admit_order[s]):
                while s in decoding and not cow_if_shared(s):
                    if preempt_one() is None:
                        raise RuntimeError(
                            "page pool exhausted with nothing to preempt"
                        )
                while (
                    s in decoding   # may have been evicted (even by itself)
                    and table.num_pages_of(s) * page_size
                    <= int(lengths[s]) + len(drafts.get(s, ()))
                ):
                    grown = slots.grow(1) if ensure_free(1) else None
                    if grown is None:
                        d = drafts.get(s)
                        if d:
                            fit = (table.num_pages_of(s) * page_size
                                   - int(lengths[s]) - 1)
                            del d[max(fit, 0):]
                            continue
                        if preempt_one() is None:
                            raise RuntimeError(
                                "page pool exhausted with nothing to preempt"
                            )
                        continue
                    table.append(s, grown[0])
                    dirty.add(s)
            active_dec = [s for s in active_dec if s in decoding]  # may be preempted
            if active_dec:
                t0d = clock()
                use_spec = spec and any(drafts.get(s) for s in active_dec)
                W = spec_k + 1 if use_spec else 1
                sync_device(active_dec)
                live = max(
                    int(lengths[s]) + 1 + len(drafts.get(s, ()))
                    for s in active_dec
                )
                bound = bucket_pow2(
                    pages_needed(live, page_size), cap=max_pages_per_seq
                )
                if use_spec:
                    win = np.zeros((num_slots, W), np.int32)
                    wlens_h = np.zeros((num_slots,), np.int32)
                    for s in active_dec:
                        d = drafts.get(s, [])
                        win[s, 0] = nxt[s]
                        win[s, 1 : 1 + len(d)] = d
                        wlens_h[s] = 1 + len(d)
                    fn = self._spec_decode_fn(bound, W)
                    greedy, n_acc, dev_pos, dev_nxt, cache = fn(
                        self.params, win, cache, dev_table,
                        dev_pos, wlens_h, dev_nxt,
                    )
                    g, na = jax.device_get((greedy, n_acc))
                else:
                    fn = self._paged_decode_fn(bound)
                    tok, dev_nxt, dev_pos, cache = fn(
                        self.params, dev_nxt, cache, dev_table, dev_pos,
                        dev_mask,
                    )
                    g = np.asarray(tok)[:, None]
                    na = np.zeros((num_slots,), np.int32)
                now = clock()
                decode_s += now - t0d
                tp_event("verify" if use_spec else "decode", t0d, now,
                         num_slots * W)
                step += 1
                occupancy_sum += slots.num_active
                prop_total = acc_total = 0
                for s in active_dec:
                    a = int(na[s])
                    emitted = g[s, : a + 1]
                    req = slots.active[s]
                    slot_tokens[s].extend(int(t) for t in emitted)
                    nxt[s] = int(emitted[-1])
                    lengths[s] += a + 1
                    decode_tokens_emitted += a + 1
                    slot_times[s].extend([now] * (a + 1))
                    if s in replay_first:
                        # full cache hit: the first token came from this
                        # decode boundary, not from a prefill launch
                        replay_first.discard(s)
                        req._ttft_s = now - submit_s[req.request_id]  # type: ignore
                    if spec:
                        prop = len(drafts.get(s, ()))
                        ledger.record(req.request_id, prop, a)
                        prop_total += prop
                        acc_total += a
                        # rollback: lengths already rewound to the committed
                        # prefix (the device bump is accepted+1, not the full
                        # window); a rejected suffix that opened a fresh page
                        # hands it straight back to the pool
                        freed = table.truncate(
                            s, pages_needed(int(lengths[s]), page_size)
                        )
                        if freed:
                            pool.free(freed)
                            ledger.record_rollback(len(freed))
                            dirty.add(s)
                if spec:
                    ledger.record_launch(use_spec)
                    if use_spec and tracer is not None:
                        tracer.event(
                            "spec:verify", t0d, now,
                            window=W, slots=len(active_dec),
                            proposed=prop_total, accepted=acc_total,
                            emitted=len(active_dec) + acc_total,
                        )
                progressed = True
            # peak concurrency is a per-boundary property: prefill-only
            # boundaries (no decode yet) still hold admitted requests
            peak_occupancy = max(peak_occupancy, slots.num_active)
            pages_sum += pool.num_in_use
            samples += 1
            slots.record_occupancy(step)
            if not progressed and not prefilling and not decoding:
                raise RuntimeError("paged serve loop stalled (admission deadlock)")
        if fault_hook is not None and hasattr(fault_hook, "release"):
            fault_hook.release()   # pressure seizures held past the last step
        jax.block_until_ready(cache["k_pages"])
        wall = clock() - t_start
        results = [finished[r.request_id] for r in requests]
        total_tokens = sum(len(r.tokens) for r in results)
        completed_n = sum(1 for r in results if r.status == "completed")
        in_goodput = sum(
            1 for r in results
            if r.status == "completed" and r.within_deadline
        )
        return PagedStats(
            results=results,
            steps=step,
            wall_s=wall,
            total_tokens=total_tokens,
            throughput_tps=total_tokens / wall if wall > 0 else float("inf"),
            mean_slot_occupancy=occupancy_sum / step if step else 0.0,
            peak_slot_occupancy=peak_occupancy,
            page_size=page_size,
            num_pages=pool.capacity,
            mean_pages_in_use=pages_sum / samples if samples else 0.0,
            peak_pages_in_use=pool.peak_in_use,
            preemptions=slots.preemptions,
            prefill_chunks=chunks_done,
            compile_stats=self._compile_delta(compiles_before),
            prefill_mode=prefill_mode,
            prefill_launches=prefill_launches,
            prefill_s=prefill_s,
            prefill_tokens=prefill_tokens,
            prefill_padded_tokens=prefill_padded,
            prefill_budget=t_pack if packed else 0,
            prefill_budget_stats=budget.stats() if budget else {},
            prompt_tokens_admitted=prompt_admitted,
            saved_prefill_tokens=saved_tokens,
            prefill_tokens_dropped=dropped_tokens,
            prefix_cache=prefix_cache,
            cow_copies=cow_copies,
            cache_evictions=pcache.evicted_pages if pcache else 0,
            prefix_stats=pcache.stats() if pcache else {},
            decode_s=decode_s,
            spec_k=spec_k,
            spec_stats=ledger.stats() if ledger else {},
            itl_p50_ms=percentile(itl_all, 50.0) * 1e3 if itl_all else 0.0,
            itl_p99_ms=percentile(itl_all, 99.0) * 1e3 if itl_all else 0.0,
            tp=self.tp,
            kv_dtype=pool_dtype,
            kv_bytes_per_token=float(
                sum(v.nbytes for v in cache.values())
                / (num_pages * page_size)
            ),
            completed=completed_n,
            rejected=rejected_n,
            deferred=deferred_n,
            goodput=in_goodput / len(results) if results else 1.0,
            deadline_ms=deadline_ms,
            checkpoints_saved=ckpt_saved,
            checkpoint_bytes=ckpt_bytes,
            restored_requests=restored_n,
            restored_tokens=restored_tok,
            restore_bytes=restore_bytes,
            checksum_failures=checksum_failures,
        )
