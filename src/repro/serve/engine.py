"""Batched serving engine: prefill + decode with a reusable KV cache.

This is the platform's "cloud scenario" executor (the paper deploys models
either for cloud serving or edge inference). Requests are grouped into
fixed-size batches (padded), prefilled once, then decoded token-by-token
with cache donation so decode is allocation-free at steady state.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models.lm import BaseModel


@dataclass
class GenerationResult:
    tokens: np.ndarray          # (b, new_tokens)
    prefill_s: float
    decode_s: float
    tokens_per_s: float


class ServingEngine:
    def __init__(
        self,
        model: BaseModel,
        params,
        max_batch: int,
        max_seq: int,
        cache_dtype: str = "float32",
    ) -> None:
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.cache_dtype = cache_dtype
        self._prefill = jax.jit(model.prefill)
        # donate the cache so steady-state decode does not reallocate it
        self._decode = jax.jit(model.decode, donate_argnums=(2,))

    def _pad_prompts(self, prompts: List[np.ndarray]) -> Tuple[np.ndarray, np.ndarray]:
        b = len(prompts)
        if b > self.max_batch:
            raise ValueError(f"batch {b} > max_batch {self.max_batch}")
        max_len = max(len(p) for p in prompts)
        out = np.zeros((b, max_len), np.int32)
        lens = np.zeros((b,), np.int32)
        for i, p in enumerate(prompts):
            # left-pad so every prompt's last token sits at max_len-1; the
            # causal mask plus identical suffix alignment keeps decode simple
            out[i, max_len - len(p):] = p
            lens[i] = len(p)
        return out, lens

    def generate(
        self,
        prompts: List[np.ndarray],
        max_new_tokens: int,
        extra_inputs: Optional[Dict[str, Any]] = None,
        greedy: bool = True,
    ) -> GenerationResult:
        tokens, _ = self._pad_prompts(prompts)
        b, s = tokens.shape
        if s + max_new_tokens > self.max_seq:
            raise ValueError("prompt + generation exceeds max_seq")
        cache = self.model.init_cache(b, self.max_seq, dtype=self.cache_dtype)
        batch = {"tokens": jnp.asarray(tokens)}
        if extra_inputs:
            batch.update({k: jnp.asarray(v) for k, v in extra_inputs.items()})
        t0 = time.perf_counter()
        logits, cache = jax.block_until_ready(self._prefill(self.params, batch, cache))
        t1 = time.perf_counter()
        out = np.zeros((b, max_new_tokens), np.int32)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for i in range(max_new_tokens):
            out[:, i] = np.asarray(nxt)
            logits, cache = self._decode(self.params, nxt, cache)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(logits)
        t2 = time.perf_counter()
        decode_s = t2 - t1
        return GenerationResult(
            tokens=out,
            prefill_s=t1 - t0,
            decode_s=decode_s,
            tokens_per_s=b * max_new_tokens / decode_s if decode_s > 0 else float("inf"),
        )
