"""Batched + continuous-batching serving engine.

This is the platform's "cloud scenario" executor (the paper deploys models
either for cloud serving or edge inference). Two generate paths share the
prefill/decode jits:

* ``generate``          — static fixed-batch: requests grouped into padded
  batches, prefilled once, decoded token-by-token with cache donation so
  decode is allocation-free at steady state.
* ``serve_continuous``  — slot-based continuous batching: a fixed pool of
  KV-cache slots; finished sequences free their slot and queued prompts are
  admitted at decode-step boundaries (batch-1 prefill scattered into the
  pooled cache), so long and short generations no longer convoy. Uses the
  model's masked per-row cache-update path (``uniform_pos=False``) because
  slots sit at different sequence positions. Reports per-request
  time-to-first-token and tokens/sec.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models.lm import BaseModel
from ..models.params import tree_map_defs
from .scheduler import SlotPool


@dataclass
class GenerationResult:
    tokens: np.ndarray          # (b, new_tokens)
    prefill_s: float
    decode_s: float
    tokens_per_s: float


@dataclass
class ServeRequest:
    """One prompt for the continuous-batching loop."""

    request_id: int
    prompt: np.ndarray
    max_new_tokens: int


@dataclass
class RequestResult:
    """Per-request serving metrics (continuous batching)."""

    request_id: int
    tokens: np.ndarray          # (max_new_tokens,)
    slot: int
    admit_step: int             # decode-step boundary at which it was admitted
    finish_step: int
    ttft_s: float               # submit -> first token (prefill argmax)
    latency_s: float            # submit -> last token
    tokens_per_s: float


@dataclass
class ContinuousStats:
    """Aggregate output of one ``serve_continuous`` run."""

    results: List[RequestResult]
    steps: int                  # decode steps executed
    wall_s: float
    total_tokens: int
    throughput_tps: float
    mean_slot_occupancy: float  # active slots per decode step


class ServingEngine:
    def __init__(
        self,
        model: BaseModel,
        params,
        max_batch: int,
        max_seq: int,
        cache_dtype: str = "float32",
    ) -> None:
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.cache_dtype = cache_dtype
        self._prefill = jax.jit(model.prefill)
        # donate the cache so steady-state decode does not reallocate it
        self._decode = jax.jit(model.decode, donate_argnums=(2,))
        # continuous batching: masked per-row cache updates (slots decode at
        # different positions) + slot scatter of a batch-1 prefill cache
        self._decode_ragged = jax.jit(
            partial(model.decode, uniform_pos=False), donate_argnums=(2,)
        )
        self._slot_writers: Dict[int, Callable] = {}

    def _pad_prompts(self, prompts: List[np.ndarray]) -> Tuple[np.ndarray, np.ndarray]:
        b = len(prompts)
        if b > self.max_batch:
            raise ValueError(f"batch {b} > max_batch {self.max_batch}")
        max_len = max(len(p) for p in prompts)
        out = np.zeros((b, max_len), np.int32)
        lens = np.zeros((b,), np.int32)
        for i, p in enumerate(prompts):
            # left-pad so every prompt's last token sits at max_len-1; the
            # causal mask plus identical suffix alignment keeps decode simple
            out[i, max_len - len(p):] = p
            lens[i] = len(p)
        return out, lens

    def generate(
        self,
        prompts: List[np.ndarray],
        max_new_tokens: int,
        extra_inputs: Optional[Dict[str, Any]] = None,
        greedy: bool = True,
    ) -> GenerationResult:
        tokens, _ = self._pad_prompts(prompts)
        b, s = tokens.shape
        if s + max_new_tokens > self.max_seq:
            raise ValueError("prompt + generation exceeds max_seq")
        cache = self.model.init_cache(b, self.max_seq, dtype=self.cache_dtype)
        batch = {"tokens": jnp.asarray(tokens)}
        if extra_inputs:
            batch.update({k: jnp.asarray(v) for k, v in extra_inputs.items()})
        t0 = time.perf_counter()
        logits, cache = jax.block_until_ready(self._prefill(self.params, batch, cache))
        t1 = time.perf_counter()
        out = np.zeros((b, max_new_tokens), np.int32)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        for i in range(max_new_tokens):
            out[:, i] = np.asarray(nxt)
            logits, cache = self._decode(self.params, nxt, cache)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        jax.block_until_ready(logits)
        t2 = time.perf_counter()
        decode_s = t2 - t1
        return GenerationResult(
            tokens=out,
            prefill_s=t1 - t0,
            decode_s=decode_s,
            tokens_per_s=b * max_new_tokens / decode_s if decode_s > 0 else float("inf"),
        )

    # -- continuous batching -------------------------------------------------
    def _slot_writer(self, num_slots: int) -> Callable:
        """Jitted scatter of a batch-1 cache into slot ``i`` of the pool.

        The batch axis of each cache leaf comes from the model's own P-tree
        axis names, so this works for every cache layout (dense/MoE KV,
        interleaved pairs, SSM state, hybrid, enc-dec cross caches).
        """
        writer = self._slot_writers.get(num_slots)
        if writer is not None:
            return writer
        defs = self.model.cache_defs(num_slots, self.max_seq, dtype=self.cache_dtype)
        axis_tree = tree_map_defs(lambda path, p: p.axes.index("batch"), defs)

        def write(pool, one, slot):
            def w(dst, src, ax):
                starts = tuple(slot if i == ax else 0 for i in range(dst.ndim))
                return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype), starts)

            return jax.tree.map(w, pool, one, axis_tree)

        writer = jax.jit(write, donate_argnums=(0,))
        self._slot_writers[num_slots] = writer
        return writer

    def serve_continuous(
        self,
        requests: List[ServeRequest],
        num_slots: Optional[int] = None,
        clock: Callable[[], float] = time.perf_counter,
    ) -> ContinuousStats:
        """Slot-based continuous-batching generate loop.

        All prompts are left-padded to a common prefill length (one compile);
        admission runs a batch-1 prefill and scatters its cache into the free
        slot, then every decode step advances all active slots together.
        ``clock`` is injectable so tests measure deterministic timings.
        """
        if not requests:
            return ContinuousStats([], 0, 0.0, 0, 0.0, 0.0)
        if getattr(self.model.cfg, "family", "") == "encdec":
            raise NotImplementedError(
                "continuous batching does not support encoder-decoder models: "
                "admission prefill would need per-request encoder frames"
            )
        num_slots = num_slots or self.max_batch
        prefill_len = max(len(r.prompt) for r in requests)
        for r in requests:
            if prefill_len + r.max_new_tokens > self.max_seq:
                raise ValueError(
                    f"request {r.request_id}: prompt + generation exceeds max_seq"
                )
        pool = SlotPool(num_slots)
        cache = self.model.init_cache(num_slots, self.max_seq, dtype=self.cache_dtype)
        write = self._slot_writer(num_slots)
        # one reusable batch-1 cache for admission prefills (prefill is
        # functional: it returns a fresh tree, the zeros base is never mutated)
        cache1 = self.model.init_cache(1, self.max_seq, dtype=self.cache_dtype)
        queue = deque(requests)
        nxt = np.zeros((num_slots,), np.int32)
        # slot -> [generated tokens]; request/submit times by id
        slot_tokens: Dict[int, List[int]] = {}
        finished: Dict[int, RequestResult] = {}
        t_start = clock()
        submit_s = {r.request_id: t_start for r in requests}
        step = 0
        occupancy_sum = 0
        while queue or pool.num_active:
            # retire sequences that already hold all their tokens, so their
            # slots are free for admission at this same step boundary
            for slot in list(pool.active):
                req = pool.active[slot]
                if len(slot_tokens[slot]) >= req.max_new_tokens:
                    now = clock()
                    finished[req.request_id] = RequestResult(
                        request_id=req.request_id,
                        tokens=np.asarray(slot_tokens.pop(slot), np.int32),
                        slot=slot,
                        admit_step=req._admit_step,  # type: ignore[attr-defined]
                        finish_step=step,
                        ttft_s=req._ttft_s,          # type: ignore[attr-defined]
                        latency_s=now - submit_s[req.request_id],
                        tokens_per_s=(
                            req.max_new_tokens / (now - submit_s[req.request_id])
                            if now > submit_s[req.request_id] else float("inf")
                        ),
                    )
                    pool.release(slot)
            # admission at the decode-step boundary: fill every free slot
            while queue and pool.num_free:
                req = queue.popleft()
                slot = pool.admit(req, step=step)
                padded = np.zeros((prefill_len,), np.int32)
                padded[prefill_len - len(req.prompt):] = req.prompt
                logits1, filled = self._prefill(
                    self.params, {"tokens": jnp.asarray(padded[None])}, cache1
                )
                tok0 = int(jnp.argmax(logits1[0]))
                cache = write(cache, filled, jnp.int32(slot))
                nxt[slot] = tok0
                slot_tokens[slot] = [tok0]
                req._admit_step = step          # type: ignore[attr-defined]
                req._ttft_s = clock() - submit_s[req.request_id]  # type: ignore
            if not pool.num_active:
                if queue:
                    continue            # freshly-retired slots admit the queue
                break
            if all(
                len(slot_tokens[s]) >= pool.active[s].max_new_tokens
                for s in pool.active
            ):
                continue  # every active slot is at budget: retire, don't decode
            # one decode step for the whole pool (inactive slots are ignored)
            logits, cache = self._decode_ragged(self.params, jnp.asarray(nxt), cache)
            tokens_all = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
            step += 1
            occupancy_sum += pool.num_active
            for slot in pool.active:
                if len(slot_tokens[slot]) < pool.active[slot].max_new_tokens:
                    slot_tokens[slot].append(int(tokens_all[slot]))
                    nxt[slot] = tokens_all[slot]
        jax.block_until_ready(cache["pos"])
        wall = clock() - t_start
        results = [finished[r.request_id] for r in requests]
        total_tokens = sum(len(r.tokens) for r in results)
        return ContinuousStats(
            results=results,
            steps=step,
            wall_s=wall,
            total_tokens=total_tokens,
            throughput_tps=total_tokens / wall if wall > 0 else float("inf"),
            mean_slot_occupancy=occupancy_sum / step if step else float(num_slots),
        )
