"""Paged KV-cache bookkeeping: global page pool + per-request page tables.

The serving engine's paged mode replaces the dense per-slot ``max_seq``
cache with a global pool of ``page_size``-token pages (the vLLM layout):
HBM footprint scales with *live* tokens, not ``num_slots * max_seq``.  This
module is the pure-Python side of that design — page ownership, allocation,
and the (num_slots, max_pages) int32 indirection table the Pallas paged
kernel dereferences — so admission control and preemption are testable
without a model.  The engine owns the actual page tensors.

Page 0 (more generally, the first ``reserved`` pages) is never allocated:
idle batch rows point their table entries at it so their masked-out decode
writes land in a scratch page instead of a live request's memory.

Pages are REFCOUNTED so automatic prefix caching can map one physical page
into many requests' tables: :class:`PrefixCache` hash-chains full
``page_size``-token prompt blocks to the physical page that holds their
K/V, holding one reference of its own per cached page.  A page whose
refcount drops to the cache's single reference enters the "cached but
unreferenced" LRU tier — still serving future lookups, reclaimed (true
free) only when admission or growth actually needs pages.  Correctness
never depends on cache state: eviction only ever frees unreferenced pages,
and any write into a page someone else still references is copy-on-write
at the engine layer.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "PagePool",
    "PageSnapshot",
    "PageTable",
    "PrefixCache",
    "page_checksums",
    "pages_needed",
    "scatter_cache_to_pages",
]


def pages_needed(tokens: int, page_size: int) -> int:
    """Pages required to hold ``tokens`` tokens (ceil division)."""
    if page_size < 1:
        raise ValueError("page_size must be >= 1")
    return max((tokens + page_size - 1) // page_size, 0)


def scatter_cache_to_pages(k_cache, v_cache, page_size: int, rng=None):
    """Scatter a contiguous (b, S, kvh, d) cache into a page pool with a
    RANDOM physical page assignment (page 0 reserved as scratch).

    The layout oracle shared by tests and benchmarks when validating paged
    attention against the dense reference: any permutation of physical pages
    must produce identical attention.  Returns numpy
    ``(k_pages, v_pages, page_table)`` with pool shape
    ``(b * ceil(S/page_size) + 1, page_size, kvh, d)``.
    """
    rng = rng or np.random.default_rng(0)
    kc, vc = np.asarray(k_cache), np.asarray(v_cache)
    b, S, kvh, d = kc.shape
    npg = pages_needed(S, page_size)
    total = b * npg + 1
    k_pages = np.zeros((total, page_size, kvh, d), kc.dtype)
    v_pages = np.zeros_like(k_pages)
    table = np.zeros((b, npg), np.int32)
    perm = rng.permutation(np.arange(1, total))
    for i in range(b):
        for j in range(npg):
            pid = int(perm[i * npg + j])
            blk = kc[i, j * page_size:(j + 1) * page_size]
            k_pages[pid, : blk.shape[0]] = blk
            v_pages[pid, : blk.shape[0]] = vc[i, j * page_size:(j + 1) * page_size]
            table[i, j] = pid
    return k_pages, v_pages, table


def page_checksums(k, v, k_scales=None, v_scales=None) -> List[int]:
    """CRC32 per page over the exact stored bytes — K then V (then the
    scale rows in quantized mode), all layers of one page chained into one
    word.  Computed over snapshot arrays shaped ``(L, n, page_size, ...)``
    (page axis 1), i.e. the bytes exactly as the append/quantize path wrote
    them: a quantized pool checksums the int8/fp8 codes plus their f32
    scales, never a dequantized view, so verification is byte-strict."""
    n = int(np.asarray(k).shape[1])
    sums: List[int] = []
    for j in range(n):
        c = zlib.crc32(np.ascontiguousarray(k[:, j]).tobytes())
        c = zlib.crc32(np.ascontiguousarray(v[:, j]).tobytes(), c)
        if k_scales is not None:
            c = zlib.crc32(np.ascontiguousarray(k_scales[:, j]).tobytes(), c)
            c = zlib.crc32(np.ascontiguousarray(v_scales[:, j]).tobytes(), c)
        sums.append(c & 0xFFFFFFFF)
    return sums


@dataclass
class PageSnapshot:
    """A request's in-flight KV state as a first-class transferable
    artifact: the contiguous page bytes (``ops.export_pages`` output,
    fetched to host), the lengths/tokens needed to resume decoding, and a
    per-page checksum ledger guarding the transfer path.

    ``length`` counts the KV positions the pages actually hold (the
    engine's ``lengths[slot]`` at the checkpoint boundary: prompt plus all
    emitted tokens except the still-unappended latest one, which is
    exactly ``tokens[-1]``).  A restore scatters the pages into freshly
    allocated pages on the destination pool, rebuilds the slot state from
    ``tokens``/``length``, and continues decoding — bit-identical to an
    undisturbed run because the pages are exact stored bytes.
    """

    request_id: int
    prompt_len: int
    length: int                 # KV positions held by the pages
    tokens: np.ndarray          # emitted tokens so far (np.int32)
    k: np.ndarray               # (L, n, page_size, kvh, d) page bytes
    v: np.ndarray
    k_scales: Optional[np.ndarray] = None   # (L, n, page_size, kvh) f32
    v_scales: Optional[np.ndarray] = None
    checksums: List[int] = field(default_factory=list)
    step: int = 0               # engine decode step of the checkpoint
    kv_dtype: str = "float32"

    @property
    def num_pages(self) -> int:
        return int(self.k.shape[1])

    @property
    def nbytes(self) -> int:
        """Bytes a migration of this snapshot moves (pages + scales)."""
        n = self.k.nbytes + self.v.nbytes
        if self.k_scales is not None:
            n += self.k_scales.nbytes + self.v_scales.nbytes
        return n

    def verify(self) -> bool:
        """Recompute the per-page checksums and compare against the ledger
        — the import-side guard: a mismatch means the bytes changed between
        checkpoint and restore and the request MUST replay from its prompt
        (corrupted state is never served)."""
        return (
            page_checksums(self.k, self.v, self.k_scales, self.v_scales)
            == self.checksums
        )

    def corrupt(self, page: int = 0) -> None:
        """Flip the bytes of one page WITHOUT updating the checksum ledger
        (the seeded ``corrupt@W:S`` fault's payload — a bit-rot / torn-write
        stand-in that :meth:`verify` must catch)."""
        # device-fetched arrays arrive read-only: take a writable copy
        k = np.array(self.k, copy=True)
        view = k.view(np.uint8)
        view[:, page] ^= 0xFF
        self.k = k


class PagePool:
    """Free-list allocator over the global KV page pool, with per-page
    refcounts so prefix caching can share one physical page across many
    requests (and the cache itself)."""

    def __init__(self, num_pages: int, page_size: int, reserved: int = 1) -> None:
        if num_pages <= reserved:
            raise ValueError(
                f"num_pages {num_pages} must exceed reserved scratch pages {reserved}"
            )
        self.num_pages = num_pages
        self.page_size = page_size
        self.reserved = reserved
        # pop() hands out low page ids first
        self._free: List[int] = list(range(num_pages - 1, reserved - 1, -1))
        self._ref: Dict[int, int] = {}      # page -> reference count (>= 1)
        self.peak_in_use = 0
        self.allocs = 0
        self.frees = 0

    @property
    def capacity(self) -> int:
        """Allocatable pages (excludes the reserved scratch pages)."""
        return self.num_pages - self.reserved

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_in_use(self) -> int:
        return self.capacity - len(self._free)

    @property
    def num_shared(self) -> int:
        """Pages referenced more than once (mapped by several requests, or
        by a request and the prefix cache) — the pages admission must count
        once globally rather than per request."""
        return sum(1 for c in self._ref.values() if c > 1)

    def refcount(self, page: int) -> int:
        """Current reference count of ``page`` (0 when free)."""
        return self._ref.get(page, 0)

    def pages_needed(self, tokens: int) -> int:
        return pages_needed(tokens, self.page_size)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Allocate ``n`` pages atomically (each at refcount 1); None when
        the pool can't supply all of them (the caller then evicts cached
        pages, queues, or preempts)."""
        if n < 0:
            raise ValueError("cannot allocate a negative page count")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        self.allocs += n
        self.peak_in_use = max(self.peak_in_use, self.num_in_use)
        return pages

    def incref(self, pages: List[int]) -> None:
        """Add one reference per page (a request mapping cached pages into
        its table, or the prefix cache registering a page)."""
        for p in pages:
            if p not in self._ref:
                raise ValueError(f"page {p} is not allocated (incref on free page)")
            self._ref[p] += 1

    def free(self, pages: List[int]) -> List[int]:
        """Drop one reference per page; pages whose count reaches zero go
        back to the free list.  Returns the pages actually released (shared
        pages survive their other holders).  Freeing an unallocated page —
        or more times than it was referenced — raises (double-free guard).
        """
        released: List[int] = []
        for p in pages:
            c = self._ref.get(p, 0)
            if c <= 0:
                raise ValueError(f"page {p} is not allocated (double free?)")
            if c == 1:
                del self._ref[p]
                self._free.append(p)
                self.frees += 1
                released.append(p)
            else:
                self._ref[p] = c - 1
        return released


class PageTable:
    """(num_slots, max_pages) indirection table mapping a slot's logical page
    index to its physical page id.  Unassigned entries stay at the scratch
    page (0) so every row is always safe to hand to the paged kernel."""

    def __init__(self, num_slots: int, max_pages: int, scratch_page: int = 0) -> None:
        if num_slots < 1 or max_pages < 1:
            raise ValueError("num_slots and max_pages must be >= 1")
        self.max_pages = max_pages
        self.scratch_page = scratch_page
        self.table = np.full((num_slots, max_pages), scratch_page, np.int32)
        self._pages: Dict[int, List[int]] = {}

    def pages_of(self, slot: int) -> List[int]:
        return list(self._pages.get(slot, []))

    def num_pages_of(self, slot: int) -> int:
        return len(self._pages.get(slot, []))

    def assign(self, slot: int, pages: List[int]) -> None:
        """Give ``slot`` a fresh run of pages (admission)."""
        if slot in self._pages:
            raise ValueError(f"slot {slot} already holds pages")
        if len(pages) > self.max_pages:
            raise ValueError(f"{len(pages)} pages > max_pages {self.max_pages}")
        self.table[slot, :] = self.scratch_page
        self.table[slot, : len(pages)] = pages
        self._pages[slot] = list(pages)

    def append(self, slot: int, page: int) -> None:
        """Grow ``slot`` by one page (decode crossing a page boundary)."""
        held = self._pages.setdefault(slot, [])
        if len(held) >= self.max_pages:
            raise ValueError(f"slot {slot} already holds max_pages pages")
        self.table[slot, len(held)] = page
        held.append(page)

    def replace(self, slot: int, index: int, page: int) -> int:
        """Swap the physical page behind logical page ``index`` (copy-on-
        write: the slot is about to append into a shared page, so it remaps
        that logical page to a private copy).  Returns the old physical
        page so the caller can drop its reference."""
        held = self._pages.get(slot, [])
        if not 0 <= index < len(held):
            raise ValueError(f"slot {slot} holds no logical page {index}")
        old = held[index]
        held[index] = page
        self.table[slot, index] = page
        return old

    def truncate(self, slot: int, keep: int) -> List[int]:
        """Drop every page past the first ``keep`` (speculative-decoding
        rollback: a rejected draft suffix may have opened a fresh page past
        the committed length).  Returns the freed pages so the caller can
        hand them back to the pool."""
        if keep < 0:
            raise ValueError("cannot keep a negative page count")
        held = self._pages.get(slot, [])
        if keep >= len(held):
            return []
        freed = held[keep:]
        del held[keep:]
        self.table[slot, keep:] = self.scratch_page
        return freed

    def clear(self, slot: int) -> List[int]:
        """Drop the slot's mapping (completion/preemption); returns the pages
        so the caller can return them to the pool."""
        pages = self._pages.pop(slot, [])
        self.table[slot, :] = self.scratch_page
        return pages

    def rows_for(self, mask: np.ndarray) -> np.ndarray:
        """Table snapshot with non-``mask`` rows pointed at the scratch page
        (idle/prefilling rows must not let the batched decode write into
        their live pages)."""
        return np.where(mask[:, None], self.table, np.int32(self.scratch_page))


class _CacheEntry:
    """One cached full prompt page: the physical page holding the K/V of a
    ``page_size``-token block reached through a specific prefix chain."""

    __slots__ = ("page", "parent", "children", "last_use")

    def __init__(self, page: int, parent: Optional[tuple], last_use: int) -> None:
        self.page = page
        self.parent = parent        # key of the previous block in the chain
        self.children = 0           # cached blocks extending this prefix
        self.last_use = last_use


class PrefixCache:
    """Automatic prefix cache: hash-chain of full prompt pages -> physical
    page ids, sharing committed K/V across requests.

    Keys chain ``(parent_key, token_block_bytes)`` so a cached page is only
    ever reachable through the exact token prefix that produced it — two
    prompts share page ``i`` iff their first ``(i + 1) * page_size`` tokens
    are identical.  The cache holds ONE pool reference per cached page, so
    a page shared by the cache and ``r`` requests has refcount ``r + 1``;
    when every request releases, the page (refcount 1) sits in the "cached
    but unreferenced" LRU tier until :meth:`evict` reclaims it on demand.

    Eviction is leaf-first in LRU order and can only ever free unreferenced
    pages: a referenced child implies a referenced parent (requests always
    map a cached run from block 0), so the unreferenced entries form a
    subtree-closed set that leaf-first eviction fully drains — ``evictable``
    counts them all.  Only full prompt pages are ever cached; partially
    filled last pages stay private to their request.
    """

    def __init__(self, pool: PagePool) -> None:
        self.pool = pool
        self._entries: Dict[tuple, _CacheEntry] = {}
        self._tick = 0
        # counters (surface through stats() -> PagedStats.prefix_stats)
        self.lookups = 0
        self.hits = 0
        self.full_hits = 0
        self.hit_pages = 0
        self.hit_tokens = 0
        self.inserts = 0
        self.evicted_pages = 0

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def evictable(self) -> int:
        """Cached pages reclaimable on demand (refcount 1: no request maps
        them).  Leaf-first eviction reaches every one of them."""
        return sum(
            1 for e in self._entries.values() if self.pool.refcount(e.page) == 1
        )

    def _blocks(self, prompt: np.ndarray):
        ps = self.pool.page_size
        toks = np.asarray(prompt, np.int32)
        for i in range(len(toks) // ps):
            yield toks[i * ps : (i + 1) * ps].tobytes()

    def match(self, prompt: np.ndarray) -> Tuple[List[int], int]:
        """Longest cached page-aligned prefix of ``prompt``: returns the
        physical pages (block 0 first) and the token count they cover.
        Bumps recency but records no hit/miss counters — an admission
        *probe*; the caller increfs the pages it actually maps and calls
        :meth:`record` once the request really enters (a queued request is
        re-probed every boundary and must not inflate the hit rate).  A
        hit run covering the whole (page-aligned) prompt is a *full hit*:
        the engine skips prefill entirely and replays the last prompt
        token through the decode path (copy-on-write splits the shared
        last page)."""
        self._tick += 1
        pages: List[int] = []
        parent: Optional[tuple] = None
        for blk in self._blocks(prompt):
            key = (parent, blk)
            e = self._entries.get(key)
            if e is None:
                break
            e.last_use = self._tick
            pages.append(e.page)
            parent = key
        return pages, len(pages) * self.pool.page_size

    def record(self, prompt_tokens: int, pages: List[int]) -> None:
        """Count one admitted request's lookup outcome (hit-rate / saved-
        token accounting)."""
        self.lookups += 1
        if pages:
            cached = len(pages) * self.pool.page_size
            self.hits += 1
            self.hit_pages += len(pages)
            self.hit_tokens += cached
            if cached >= prompt_tokens:
                self.full_hits += 1

    def lookup(self, prompt: np.ndarray) -> Tuple[List[int], int]:
        """:meth:`match` + :meth:`record` in one call (the non-probing
        form)."""
        pages, cached = self.match(prompt)
        self.record(len(np.asarray(prompt)), pages)
        return pages, cached

    def insert(self, prompt: np.ndarray, slot_pages: List[int]) -> int:
        """Register a just-prefilled request's full prompt pages.  Blocks
        already cached (under any physical page) are left alone — first
        writer wins, the newcomer keeps its private copy; each newly cached
        page gains the cache's own reference.  Returns pages added."""
        self._tick += 1
        parent: Optional[tuple] = None
        added = 0
        for i, blk in enumerate(self._blocks(prompt)):
            key = (parent, blk)
            e = self._entries.get(key)
            if e is None:
                page = slot_pages[i]
                self.pool.incref([page])
                e = _CacheEntry(page, parent, self._tick)
                self._entries[key] = e
                if parent is not None:
                    self._entries[parent].children += 1
                added += 1
            else:
                e.last_use = self._tick
            parent = key
        self.inserts += added
        return added

    def evict(self, need: int) -> int:
        """Reclaim up to ``need`` cached-but-unreferenced pages (true free:
        the pages return to the pool's free list), least recently used
        leaves first.  Referenced pages are never touched.  Returns the
        number of pages actually freed."""
        freed = 0
        while freed < need:
            # one LRU-sorted pass over the unreferenced tier (leaves are
            # checked live, so a chain drains within the pass); evicting a
            # leaf exposes its parent, which an older ``last_use`` may have
            # placed earlier in the order — repeat until dry or satisfied
            candidates = sorted(
                (
                    (key, e)
                    for key, e in self._entries.items()
                    if self.pool.refcount(e.page) == 1
                ),
                key=lambda kv: kv[1].last_use,
            )
            progressed = False
            for key, e in candidates:
                if freed >= need:
                    break
                if e.children:
                    continue
                del self._entries[key]
                if e.parent is not None:
                    self._entries[e.parent].children -= 1
                self.pool.free([e.page])
                freed += 1
                progressed = True
            if not progressed:
                break
        self.evicted_pages += freed
        return freed

    def stats(self) -> Dict[str, float]:
        """Scalar summary of the cache economy over one run."""
        return {
            "lookups": float(self.lookups),
            "hits": float(self.hits),
            "full_hits": float(self.full_hits),
            "hit_rate": self.hits / self.lookups if self.lookups else 0.0,
            "hit_pages": float(self.hit_pages),
            "hit_tokens": float(self.hit_tokens),
            "inserts": float(self.inserts),
            "evicted_pages": float(self.evicted_pages),
            "cached_pages": float(len(self._entries)),
            "unreferenced_pages": float(self.evictable),
        }
