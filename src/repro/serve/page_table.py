"""Paged KV-cache bookkeeping: global page pool + per-request page tables.

The serving engine's paged mode replaces the dense per-slot ``max_seq``
cache with a global pool of ``page_size``-token pages (the vLLM layout):
HBM footprint scales with *live* tokens, not ``num_slots * max_seq``.  This
module is the pure-Python side of that design — page ownership, allocation,
and the (num_slots, max_pages) int32 indirection table the Pallas paged
kernel dereferences — so admission control and preemption are testable
without a model.  The engine owns the actual page tensors.

Page 0 (more generally, the first ``reserved`` pages) is never allocated:
idle batch rows point their table entries at it so their masked-out decode
writes land in a scratch page instead of a live request's memory.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import numpy as np

__all__ = ["PagePool", "PageTable", "pages_needed", "scatter_cache_to_pages"]


def pages_needed(tokens: int, page_size: int) -> int:
    """Pages required to hold ``tokens`` tokens (ceil division)."""
    if page_size < 1:
        raise ValueError("page_size must be >= 1")
    return max((tokens + page_size - 1) // page_size, 0)


def scatter_cache_to_pages(k_cache, v_cache, page_size: int, rng=None):
    """Scatter a contiguous (b, S, kvh, d) cache into a page pool with a
    RANDOM physical page assignment (page 0 reserved as scratch).

    The layout oracle shared by tests and benchmarks when validating paged
    attention against the dense reference: any permutation of physical pages
    must produce identical attention.  Returns numpy
    ``(k_pages, v_pages, page_table)`` with pool shape
    ``(b * ceil(S/page_size) + 1, page_size, kvh, d)``.
    """
    rng = rng or np.random.default_rng(0)
    kc, vc = np.asarray(k_cache), np.asarray(v_cache)
    b, S, kvh, d = kc.shape
    npg = pages_needed(S, page_size)
    total = b * npg + 1
    k_pages = np.zeros((total, page_size, kvh, d), kc.dtype)
    v_pages = np.zeros_like(k_pages)
    table = np.zeros((b, npg), np.int32)
    perm = rng.permutation(np.arange(1, total))
    for i in range(b):
        for j in range(npg):
            pid = int(perm[i * npg + j])
            blk = kc[i, j * page_size:(j + 1) * page_size]
            k_pages[pid, : blk.shape[0]] = blk
            v_pages[pid, : blk.shape[0]] = vc[i, j * page_size:(j + 1) * page_size]
            table[i, j] = pid
    return k_pages, v_pages, table


class PagePool:
    """Free-list allocator over the global KV page pool."""

    def __init__(self, num_pages: int, page_size: int, reserved: int = 1) -> None:
        if num_pages <= reserved:
            raise ValueError(
                f"num_pages {num_pages} must exceed reserved scratch pages {reserved}"
            )
        self.num_pages = num_pages
        self.page_size = page_size
        self.reserved = reserved
        # pop() hands out low page ids first
        self._free: List[int] = list(range(num_pages - 1, reserved - 1, -1))
        self._allocated: set = set()
        self.peak_in_use = 0
        self.allocs = 0
        self.frees = 0

    @property
    def capacity(self) -> int:
        """Allocatable pages (excludes the reserved scratch pages)."""
        return self.num_pages - self.reserved

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_in_use(self) -> int:
        return self.capacity - len(self._free)

    def pages_needed(self, tokens: int) -> int:
        return pages_needed(tokens, self.page_size)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Allocate ``n`` pages atomically; None when the pool can't supply
        all of them (the caller then queues or preempts)."""
        if n < 0:
            raise ValueError("cannot allocate a negative page count")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._allocated.update(pages)
        self.allocs += n
        self.peak_in_use = max(self.peak_in_use, self.num_in_use)
        return pages

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if p not in self._allocated:
                raise ValueError(f"page {p} is not allocated (double free?)")
            self._allocated.discard(p)
            self._free.append(p)
            self.frees += 1


class PageTable:
    """(num_slots, max_pages) indirection table mapping a slot's logical page
    index to its physical page id.  Unassigned entries stay at the scratch
    page (0) so every row is always safe to hand to the paged kernel."""

    def __init__(self, num_slots: int, max_pages: int, scratch_page: int = 0) -> None:
        if num_slots < 1 or max_pages < 1:
            raise ValueError("num_slots and max_pages must be >= 1")
        self.max_pages = max_pages
        self.scratch_page = scratch_page
        self.table = np.full((num_slots, max_pages), scratch_page, np.int32)
        self._pages: Dict[int, List[int]] = {}

    def pages_of(self, slot: int) -> List[int]:
        return list(self._pages.get(slot, []))

    def num_pages_of(self, slot: int) -> int:
        return len(self._pages.get(slot, []))

    def assign(self, slot: int, pages: List[int]) -> None:
        """Give ``slot`` a fresh run of pages (admission)."""
        if slot in self._pages:
            raise ValueError(f"slot {slot} already holds pages")
        if len(pages) > self.max_pages:
            raise ValueError(f"{len(pages)} pages > max_pages {self.max_pages}")
        self.table[slot, :] = self.scratch_page
        self.table[slot, : len(pages)] = pages
        self._pages[slot] = list(pages)

    def append(self, slot: int, page: int) -> None:
        """Grow ``slot`` by one page (decode crossing a page boundary)."""
        held = self._pages.setdefault(slot, [])
        if len(held) >= self.max_pages:
            raise ValueError(f"slot {slot} already holds max_pages pages")
        self.table[slot, len(held)] = page
        held.append(page)

    def truncate(self, slot: int, keep: int) -> List[int]:
        """Drop every page past the first ``keep`` (speculative-decoding
        rollback: a rejected draft suffix may have opened a fresh page past
        the committed length).  Returns the freed pages so the caller can
        hand them back to the pool."""
        if keep < 0:
            raise ValueError("cannot keep a negative page count")
        held = self._pages.get(slot, [])
        if keep >= len(held):
            return []
        freed = held[keep:]
        del held[keep:]
        self.table[slot, keep:] = self.scratch_page
        return freed

    def clear(self, slot: int) -> List[int]:
        """Drop the slot's mapping (completion/preemption); returns the pages
        so the caller can return them to the pool."""
        pages = self._pages.pop(slot, [])
        self.table[slot, :] = self.scratch_page
        return pages

    def rows_for(self, mask: np.ndarray) -> np.ndarray:
        """Table snapshot with non-``mask`` rows pointed at the scratch page
        (idle/prefilling rows must not let the batched decode write into
        their live pages)."""
        return np.where(mask[:, None], self.table, np.int32(self.scratch_page))
