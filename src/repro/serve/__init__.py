"""Serving layer: request scheduler + batched/continuous serving engine.

Attribute access is lazy (PEP 562) so that the dependency-light scheduler
(`repro.serve.scheduler`, pure Python) can be imported by the core scenario
layer without pulling in jax and the model zoo via `repro.serve.engine`.
"""
_EXPORTS = {
    "ServingEngine": ".engine",
    "GenerationResult": ".engine",
    "ServeRequest": ".engine",
    "RequestResult": ".engine",
    "ContinuousStats": ".engine",
    "PagedStats": ".engine",
    "RequestScheduler": ".scheduler",
    "SchedulerConfig": ".scheduler",
    "SchedulerQueueFull": ".scheduler",
    "ScheduledRequest": ".scheduler",
    "CompletionFuture": ".scheduler",
    "DeadlineExceeded": ".scheduler",
    "RetriesExhausted": ".scheduler",
    "backoff_delay": ".scheduler",
    "SlotPool": ".scheduler",
    "PagedSlotPool": ".scheduler",
    "PrefillBudget": ".scheduler",
    "SpecLedger": ".scheduler",
    "PagePool": ".page_table",
    "PageTable": ".page_table",
    "FaultPlan": ".faults",
    "FaultSpec": ".faults",
    "WorkerCrash": ".faults",
    "FleetConfig": ".fleet",
    "FleetRouter": ".fleet",
    "FleetStats": ".fleet",
    "FleetResult": ".fleet",
    "DegradeLadder": ".fleet",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        import importlib

        module = importlib.import_module(_EXPORTS[name], __name__)
        value = getattr(module, name)
        globals()[name] = value
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))
