from .engine import ServingEngine

__all__ = ["ServingEngine"]
