"""Fault-tolerant multi-worker serving fleet (the "millions of users" story).

A :class:`FleetRouter` runs N ``serve_paged`` workers — in-process engine
instances, each with its own page pool — behind one admission queue:

* **load balancing** — each round, ready requests are packed onto alive
  workers by free-page budget and assigned queue depth (worst-case page
  commitment per request, the same ledger the engine's admission uses);
* **deadlines + retries** — every request carries a TTL and a retry budget
  with capped exponential backoff + jitter (seeded, so schedules are
  deterministic);
* **requeue-on-death** — a worker that crashes (or fails to renew its
  heartbeat lease mid-run) raises :class:`~repro.serve.faults.WorkerCrash`
  carrying a resumable snapshot: finished results commit, pending requests
  replay from their prompts on the survivors (the preemption-recompute
  contract — greedy decode makes the replay bit-identical);
* **live KV migration** — with ``recovery="migrate"`` workers checkpoint
  each decoding slot's KV pages every ``checkpoint_every`` steps
  (:class:`~repro.serve.page_table.PageSnapshot`: exact page bytes +
  per-page checksums + emitted tokens); orphans whose checkpoint survives
  are *restored* on a survivor — O(bytes moved) instead of O(prompt
  tokens recomputed) — and continue bit-identically even beyond greedy
  decoding.  Replay-from-prompt stays the fallback when no checkpoint
  exists or its checksums fail (corrupted state is never served);
* **elasticity** — ``drain(worker)`` snapshots every live slot at a loop
  boundary and migrates all of them with zero recompute before removing
  the worker (planned removal, not a death); ``join(engine)`` adds a
  worker mid-serve that immediately participates in balancing;
* **idempotent completion** — a request duplicated by straggler/hedge
  dispatch commits exactly once (first commit wins, later ones count as
  ``duplicate_commits``).  In parallel mode a worker whose lease lapses
  mid-run is *detached*: its thread keeps running, its uncommitted work is
  immediately re-dispatched to the survivors, and whatever the straggler
  eventually returns is deduped at commit;
* **graceful degradation** — a :class:`DegradeLadder` steps through
  pressure levels with hysteresis: first disable spec decode, then shrink
  the prefill budget, then shed new admissions with an explicit
  ``rejected`` status.  Shedding is priority-aware: only the lowest tier
  present among the shed candidates is dropped each round, so best-effort
  work absorbs the overload before any higher tier loses a request;
* **tenant fairness** — with per-request ``tenant``/``priority`` tags
  (and optional :class:`~repro.serve.scheduler.TenantSpec` token buckets)
  the per-round packing order follows the same policy as the scheduler:
  bucket-dry tenants sink, higher tiers first, then weighted fair share
  by admitted tokens.  Untagged workloads keep exact FIFO packing.

Every submitted request ends in exactly one attributed terminal status —
``completed``, ``failed`` (with a reason) or ``rejected`` — zero silent
losses.  Transitions emit ``fleet:*`` tracer events feeding
``analysis.fleet_summary`` (deaths, requeues, sheds, recovery time,
goodput retained).
"""
from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from ..core.registry import KVStore
from .faults import FaultPlan, WorkerCrash, WorkerDrain
from .page_table import pages_needed
from .scheduler import TenantLedger, TenantSpec, backoff_delay

__all__ = [
    "DEGRADE_LEVELS",
    "DegradeLadder",
    "FleetConfig",
    "FleetResult",
    "FleetRouter",
    "FleetStats",
]

DEGRADE_LEVELS = ("normal", "no_spec", "tight_prefill", "shed")


class DegradeLadder:
    """Pressure-driven degrade levels with hysteresis.

    ``update(pressure)`` steps the level up by one when pressure crosses
    the high watermark, down by one when it falls below the low watermark,
    and holds inside the band — so a pressure signal oscillating between
    the watermarks cannot flap the serving mode.  Levels (in order):
    ``normal`` -> ``no_spec`` (speculative decode off) -> ``tight_prefill``
    (prefill budget halved) -> ``shed`` (new admissions rejected).
    Pure bookkeeping: the router applies the effects.
    """

    def __init__(self, high: float = 0.85, low: float = 0.60,
                 tracer: Any = None,
                 clock: Callable[[], float] = time.perf_counter) -> None:
        if not 0.0 <= low < high:
            raise ValueError("need 0 <= low < high")
        self.high = high
        self.low = low
        self.level = 0
        self.max_level = 0
        self.tracer = tracer
        self.clock = clock
        # (time, from_level, to_level, pressure) — the transition audit trail
        self.transitions: List[Tuple[float, int, int, float]] = []

    @property
    def name(self) -> str:
        return DEGRADE_LEVELS[self.level]

    def update(self, pressure: float) -> int:
        new = self.level
        if pressure >= self.high and self.level < len(DEGRADE_LEVELS) - 1:
            new = self.level + 1
        elif pressure < self.low and self.level > 0:
            new = self.level - 1
        if new != self.level:
            now = self.clock()
            self.transitions.append((now, self.level, new, pressure))
            if self.tracer is not None:
                self.tracer.event(
                    "fleet:degrade", now, now,
                    frm=self.level, to=new, pressure=pressure,
                    mode=DEGRADE_LEVELS[new],
                )
            self.level = new
            self.max_level = max(self.max_level, new)
        return self.level


@dataclass
class FleetConfig:
    """Router knobs: failure handling, degradation, and dispatch mode."""

    deadline_s: float = 0.0        # per-request TTL from submit (0 = none)
    max_retries: int = 2           # requeues per request before failed
    backoff_base_s: float = 0.0    # requeue backoff base (0 = immediate)
    backoff_cap_s: float = 0.25    # requeue backoff cap
    backoff_jitter: float = 0.0    # ±fraction jitter (seeded rng)
    seed: int = 0                  # jitter rng seed
    lease_ttl_s: float = 30.0      # worker heartbeat lease TTL
    high_watermark: float = 0.85   # pressure above -> degrade one level
    low_watermark: float = 0.60    # pressure below -> recover one level
    fairness: bool = True          # tenant-fair packing order (off: FIFO)
    parallel: bool = False         # threads per round (else deterministic
    #                                sequential rounds — same commits/tokens)
    hedge: bool = True             # parallel mode: detach a lease-expired
    #                                worker and re-dispatch its work now
    max_rounds: int = 1000         # safety valve against router bugs
    recovery: str = "migrate"      # orphan recovery: "migrate" restores the
    #                                latest checkpointed KV pages on a
    #                                survivor (O(bytes) failover); "replay"
    #                                re-prefills from the prompt (PR-8 path).
    #                                Replay stays the fallback either way
    #                                when no checkpoint exists or its
    #                                checksums fail
    checkpoint_every: int = 0      # decode steps between KV checkpoints
    #                                (0 = none: only planned drains migrate)


@dataclass
class FleetResult:
    """One request's terminal outcome (exactly one per submitted request)."""

    request_id: int
    status: str                    # completed | failed | rejected
    worker: int = -1               # worker that committed it (-1: none)
    tokens: Any = None             # np.int32 tokens (completed only)
    reason: str = ""               # failed/rejected attribution
    attempts: int = 0              # dispatch attempts consumed
    latency_s: float = 0.0         # submit -> terminal
    within_deadline: bool = True   # completed before its TTL (goodput)


@dataclass
class FleetStats:
    """One fleet run: per-request outcomes + failure/degradation ledgers."""

    results: List[FleetResult]
    num_workers: int
    wall_s: float
    rounds: int = 0
    completed: int = 0
    failed: int = 0
    rejected: int = 0              # shed with explicit rejected status
    deaths: int = 0
    requeued: int = 0              # requests replayed after a death
    hedged: int = 0                # duplicate dispatches on lease expiry
    duplicate_commits: int = 0     # later commits deduped (idempotence)
    total_tokens: int = 0
    throughput_tps: float = 0.0
    goodput: float = 0.0           # completed-within-deadline / admitted
    recovery_s: List[float] = field(default_factory=list)
    degrade_transitions: List[Tuple[float, int, int, float]] = \
        field(default_factory=list)
    max_degrade_level: int = 0
    per_worker: List[Dict[str, Any]] = field(default_factory=list)
    # -- migration / elasticity ledger (PR 10) ---------------------------
    migrated: int = 0              # orphans restored from a KV checkpoint
    migrated_tokens: int = 0       # KV tokens restored without recompute
    recomputed_prefill_tokens: int = 0  # replay-path orphans' prompt tokens
    bytes_moved: int = 0           # snapshot bytes scattered on survivors
    checkpoints_saved: int = 0
    checkpoint_bytes: int = 0
    checksum_failures: int = 0     # corrupted snapshots detected (never served)
    drains: int = 0                # planned worker removals (not deaths)
    joins: int = 0                 # workers added mid-serve

    def result_of(self, request_id: int) -> FleetResult:
        for r in self.results:
            if r.request_id == request_id:
                return r
        raise KeyError(f"request {request_id} not in fleet results")


class _Tracked:
    """Router-side request state: one per submitted request, forever."""

    __slots__ = ("req", "attempts", "not_before", "result", "worker",
                 "dispatched", "worst_pages")

    def __init__(self, req: Any, worst_pages: int) -> None:
        self.req = req
        self.attempts = 0          # dispatches so far
        self.not_before = 0.0      # backoff gate for the next dispatch
        self.result: Optional[FleetResult] = None
        self.worker = -1
        self.dispatched = False    # ever assigned to a worker
        self.worst_pages = worst_pages

    @property
    def terminal(self) -> bool:
        return self.result is not None


class _Worker:
    """One serve_paged engine instance plus its lease + fault hook."""

    def __init__(self, index: int, engine: Any, kwargs: Dict[str, Any]) -> None:
        self.index = index
        self.engine = engine
        self.alive = True
        self.served = 0
        self.steps = 0
        self.deaths = 0
        self.hook: Optional[Callable] = None
        page_size = kwargs.get("page_size") or engine.page_size
        num_slots = kwargs.get("num_slots") or engine.max_batch
        per_seq = pages_needed(engine.max_seq, page_size)
        num_pages = kwargs.get("num_pages") or num_slots * per_seq + 1
        self.page_size = page_size
        self.num_slots = num_slots
        # allocatable worst-case page budget (engine reserves one scratch
        # page) — the router's admission ledger mirror
        self.capacity = num_pages - 1
        # request_id -> PageSnapshot written by the engine's periodic
        # checkpoint (and the drain handler); harvested on death/drain
        self.checkpoints: Dict[int, Any] = {}

    @property
    def lease_key(self) -> str:
        return f"fleet/worker-{self.index}"


class FleetRouter:
    """Routes requests over N paged-serving workers with a failure model."""

    def __init__(
        self,
        engines: Sequence[Any],
        config: Optional[FleetConfig] = None,
        engine_kwargs: Optional[Dict[str, Any]] = None,
        fault_plan: Optional[FaultPlan] = None,
        *,
        store: Optional[KVStore] = None,
        clock: Callable[[], float] = time.perf_counter,
        sleep: Callable[[float], None] = time.sleep,
        tracer: Any = None,
        tenants: Sequence[TenantSpec] = (),
    ) -> None:
        if not engines:
            raise ValueError("need at least one engine")
        self.config = config or FleetConfig()
        self.engine_kwargs = dict(engine_kwargs or {})
        for k in ("clock", "tracer", "fault_hook"):
            if k in self.engine_kwargs:
                raise ValueError(f"engine_kwargs may not override {k!r}")
        self.fault_plan = fault_plan or FaultPlan()
        self.clock = clock
        self.sleep = sleep
        self.tracer = tracer
        self.store = store or KVStore(clock=clock)
        self._rng = random.Random(self.config.seed)
        self.workers = [
            _Worker(i, e, self.engine_kwargs) for i, e in enumerate(engines)
        ]
        for w in self.workers:
            w.hook = self._make_hook(w)
        self.ladder = DegradeLadder(
            self.config.high_watermark, self.config.low_watermark,
            tracer=tracer, clock=clock,
        )
        self.tenant_ledger = TenantLedger(tenants)
        self._has_tenants = bool(tenants)
        # detached stragglers (parallel mode): worker index -> holder dict
        # with the still-running thread and, once done, its outcome
        self._inflight: Dict[int, Dict[str, Any]] = {}
        self._lock = threading.Lock()
        # elasticity: scripted drains (worker -> boundary step) and pending
        # joins ((round, engine) — added at the start of that round)
        self._drain_at: Dict[int, int] = {}
        self._joins: List[Tuple[int, Any]] = []
        # harvested snapshots awaiting a survivor: request_id -> snapshot
        self._migrations: Dict[int, Any] = {}

    # -- hooks ---------------------------------------------------------------
    def _make_hook(self, w: _Worker) -> Callable:
        """Boundary hook: heartbeat the worker's lease first (a renewal
        refused after expiry is a self-inflicted death — lease expiry and
        crash share one recovery path), then fire any scripted faults."""
        fhook = self.fault_plan.hook_for(w.index, sleep=self.sleep)
        store, key, ttl = self.store, w.lease_key, self.config.lease_ttl_s

        def hook(ctx) -> None:
            w.steps += 1
            if not store.renew(key, ttl):
                raise WorkerCrash(w.index, ctx.step, reason="lease-expired")
            at = self._drain_at.get(w.index)
            if at is not None and ctx.step >= at:
                # planned removal: the engine's drain handler snapshots
                # every live slot before this propagates (fires once)
                del self._drain_at[w.index]
                raise WorkerDrain(w.index, ctx.step)
            if fhook is not None:
                fhook(ctx)

        hook.release = fhook.release if fhook is not None else (lambda: 0)
        return hook

    # -- elasticity ----------------------------------------------------------
    def drain(self, worker: int, at_step: int = 0) -> None:
        """Schedule a planned removal of ``worker``: at the first loop
        boundary with ``step >= at_step`` the worker snapshots every live
        slot and exits; its requests migrate to survivors with ZERO
        recompute (drain works even with ``checkpoint_every=0``)."""
        if not 0 <= worker < len(self.workers):
            raise ValueError(f"no worker {worker}")
        self._drain_at[worker] = at_step

    def join(self, engine: Any, at_round: int = 0) -> int:
        """Add a worker mid-serve: ``engine`` joins the alive set at the
        start of round ``at_round`` (0 = the next round) and immediately
        participates in balancing — including picking up migrations.
        Returns the new worker's index."""
        index = len(self.workers) + len(self._joins)
        self._joins.append((at_round, engine))
        return index

    # -- terminal-state bookkeeping -----------------------------------------
    def _commit(self, t: _Tracked, tokens: Any, worker: int,
                now: float) -> bool:
        """Idempotent completion: the first commit wins; duplicates (from
        straggler/hedge dispatch) are counted and dropped."""
        if t.terminal:
            self._dups += 1
            if self.tracer is not None:
                self.tracer.event(
                    "fleet:commit", now, now, request=t.req.request_id,
                    worker=worker, duplicate=1,
                )
            return False
        latency = now - self._t_start
        within = (self.config.deadline_s <= 0
                  or latency <= self.config.deadline_s)
        t.result = FleetResult(
            request_id=t.req.request_id, status="completed", worker=worker,
            tokens=tokens, attempts=t.attempts, latency_s=latency,
            within_deadline=within,
        )
        t.worker = worker
        if self.tracer is not None:
            self.tracer.event(
                "fleet:commit", now, now, request=t.req.request_id,
                worker=worker, duplicate=0, within_deadline=int(within),
                latency_s=latency,
            )
        return True

    def _fail(self, t: _Tracked, reason: str, now: float,
              status: str = "failed") -> None:
        if t.terminal:
            return
        t.result = FleetResult(
            request_id=t.req.request_id, status=status, worker=-1,
            reason=reason, attempts=t.attempts,
            latency_s=now - self._t_start, within_deadline=False,
        )
        if self.tracer is not None:
            self.tracer.event(
                f"fleet:{'shed' if status == 'rejected' else 'failed'}",
                now, now, request=t.req.request_id, reason=reason,
            )

    def _requeue(self, orphans: List[_Tracked], now: float) -> int:
        """Push orphaned requests back for the survivors, honoring each
        request's retry budget with capped exponential backoff + jitter;
        returns how many were actually requeued (vs terminally failed)."""
        n = 0
        for t in orphans:
            if t.terminal:
                continue
            if t.attempts > self.config.max_retries:
                self._fail(t, "retries-exhausted", now)
                continue
            delay = 0.0
            if self.config.backoff_base_s > 0:
                delay = backoff_delay(
                    max(t.attempts, 1), self.config.backoff_base_s,
                    self.config.backoff_cap_s, self.config.backoff_jitter,
                    self._rng,
                )
            t.not_before = now + delay
            n += 1
            if self.tracer is not None:
                self.tracer.event(
                    "fleet:requeue", now, now, request=t.req.request_id,
                    attempts=t.attempts, delay_s=delay,
                )
        return n

    # -- dispatch ------------------------------------------------------------
    @staticmethod
    def _req_tenant(t: _Tracked) -> str:
        return getattr(t.req, "tenant", "default")

    @staticmethod
    def _req_prio(t: _Tracked) -> int:
        return int(getattr(t.req, "priority", 1))

    @staticmethod
    def _req_cost(t: _Tracked) -> float:
        return float(len(t.req.prompt) + t.req.max_new_tokens)

    def _fair_order(self, ready: List[_Tracked],
                    now: float) -> List[_Tracked]:
        """Packing order: the scheduler's dequeue policy applied per round —
        bucket-dry tenants last, then priority tier, then weighted fair
        share (per-tenant virtual time), then submission order.  Untagged
        workloads (single default tenant, uniform priority, no buckets)
        reduce to the identity: exact FIFO, byte-for-byte the old order."""
        if not self.config.fairness:
            return ready
        if not self._has_tenants and all(
            self._req_tenant(t) == "default" and self._req_prio(t) == 1
            for t in ready
        ):
            return ready
        led = self.tenant_ledger

        def key(pair: Tuple[int, _Tracked]):
            i, t = pair
            name = self._req_tenant(t)
            return (led.dry(name, self._req_cost(t), now),
                    -self._req_prio(t), led.vtime.get(name, 0.0), i)

        return [t for _, t in sorted(enumerate(ready), key=key)]

    def _balance(self, ready: List[_Tracked], alive: List[_Worker],
                 now: float = 0.0) -> Dict[int, List[_Tracked]]:
        """Pack ready requests (fair order; FIFO when untagged) onto alive
        workers by free worst-case page budget + assigned queue depth; a
        request that fits no worker this round waits for the next one."""
        load = {w.index: 0 for w in alive}       # assigned worst-case pages
        count = {w.index: 0 for w in alive}      # assigned queue depth
        out: Dict[int, List[_Tracked]] = {w.index: [] for w in alive}
        by_index = {w.index: w for w in alive}
        for t in self._fair_order(ready, now):
            best = None
            best_score = None
            for i, w in by_index.items():
                if load[i] + t.worst_pages > w.capacity:
                    continue
                if count[i] >= 2 * w.num_slots:
                    continue         # bound per-round queueing inside a run
                score = (load[i] / w.capacity, count[i], i)
                if best_score is None or score < best_score:
                    best, best_score = i, score
            if best is None:
                continue
            out[best].append(t)
            load[best] += t.worst_pages
            count[best] += 1
            if self.config.fairness and (
                self._has_tenants or self._req_tenant(t) != "default"
                or self._req_prio(t) != 1
            ):
                self.tenant_ledger.on_admit(
                    self._req_tenant(t), self._req_cost(t), now
                )
        return {i: ts for i, ts in out.items() if ts}

    def _degraded_kwargs(self) -> Dict[str, Any]:
        kw = dict(self.engine_kwargs)
        if self.ladder.level >= 1:
            kw["spec_k"] = 0        # greedy acceptance: tokens unchanged
        if self.ladder.level >= 2:
            page = kw.get("page_size") or self.workers[0].page_size
            base = kw.get("prefill_budget") or 0
            if base:
                kw["prefill_budget"] = max(page, (base // 2 // page) * page)
        return kw

    def _run_worker(self, w: _Worker,
                    batch: List[_Tracked]) -> Tuple[str, Any]:
        reqs = [t.req for t in batch]
        kw = self._degraded_kwargs()
        restores: Dict[int, Any] = {}
        if self.config.recovery == "migrate":
            # arm the engine's checkpoint/restore machinery: a fresh
            # checkpoint store per run (stale snapshots must not outlive
            # the run that wrote them) plus this batch's pending migrations
            w.checkpoints.clear()
            kw["checkpoints"] = w.checkpoints
            kw["checkpoint_every"] = self.config.checkpoint_every
            restores = {
                t.req.request_id: self._migrations.pop(t.req.request_id)
                for t in batch if t.req.request_id in self._migrations
            }
            if restores:
                kw["restores"] = restores
        try:
            stats = w.engine.serve_paged(
                reqs, clock=self.clock, tracer=self.tracer,
                fault_hook=w.hook, **kw,
            )
            return ("ok", stats)
        except WorkerCrash as crash:
            return ("crash", crash)
        finally:
            # snapshots the run never consumed (crash before admission, or
            # engine-side rejection) go back in the pool for the next
            # survivor; checksum-failed ones were deleted by the engine
            self._migrations.update(restores)

    # -- the round loop ------------------------------------------------------
    def serve(self, requests: Sequence[Any]) -> FleetStats:
        """Serve ``requests`` to terminal status across the fleet."""
        cfg = self.config
        self._t_start = self.clock()
        self._dups = 0
        seen: set = set()
        tracked: List[_Tracked] = []
        min_cap = min(w.capacity for w in self.workers)
        for r in requests:
            if r.request_id in seen:
                raise ValueError(f"duplicate request_id {r.request_id}")
            seen.add(r.request_id)
            worst = pages_needed(
                len(r.prompt) + r.max_new_tokens, self.workers[0].page_size
            )
            tracked.append(_Tracked(r, worst))
        self._by_id = {t.req.request_id: t for t in tracked}
        stats = FleetStats(results=[], num_workers=len(self.workers),
                           wall_s=0.0)
        self._deaths_open: List[Dict[str, Any]] = []
        # oversize requests can never be admitted anywhere: attributed
        # failure up front (the engine would raise mid-run otherwise)
        max_seq = min(w.engine.max_seq for w in self.workers)
        for t in tracked:
            r = t.req
            if (len(r.prompt) + r.max_new_tokens > max_seq
                    or t.worst_pages > min_cap):
                self._fail(t, "oversize", self._t_start)
        # leases: every worker starts alive with a fresh lease
        for w in self.workers:
            self.store.put(w.lease_key, {"worker": w.index},
                           ttl=cfg.lease_ttl_s)
        rounds = 0
        while any(not t.terminal for t in tracked):
            now = self.clock()
            # 0) elasticity: pending joins whose round has arrived enter the
            #    alive set with a fresh lease and hook — they participate in
            #    this round's balancing (including pending migrations)
            for rnd, eng in list(self._joins):
                if rnd <= rounds:
                    self._joins.remove((rnd, eng))
                    w = _Worker(len(self.workers), eng, self.engine_kwargs)
                    w.hook = self._make_hook(w)
                    self.workers.append(w)
                    self.store.put(w.lease_key, {"worker": w.index},
                                   ttl=cfg.lease_ttl_s)
                    stats.joins += 1
                    if self.tracer is not None:
                        self.tracer.event(
                            "fleet:join", now, now, worker=w.index,
                            round=rounds,
                        )
            # collect any detached straggler that finished since last round
            # (their commits dedupe — the idempotent-completion path)
            self._process_outcomes(self._collect_stragglers(block=False),
                                   stats)
            busy = set(self._inflight)
            alive = [w for w in self.workers
                     if w.alive and w.index not in busy]
            live = [t for t in tracked if not t.terminal]
            # 1) deadline enforcement before dispatch: queued requests whose
            #    TTL already passed fail with an attributed status
            if cfg.deadline_s > 0:
                for t in live:
                    if now - self._t_start > cfg.deadline_s:
                        self._fail(t, "deadline", now)
                live = [t for t in live if not t.terminal]
                if not live:
                    break
            if not live:
                break
            if not alive and not busy:
                for t in live:
                    self._fail(t, "no-workers-left", now)
                break
            rounds += 1
            if rounds > cfg.max_rounds:
                raise RuntimeError(
                    f"fleet router exceeded {cfg.max_rounds} rounds"
                )
            # 2) pressure -> degrade ladder (hysteresis).  Pressure is the
            #    worst of: demand vs the alive fleet's page budget, and the
            #    missed-deadline rate so far
            demand = sum(t.worst_pages for t in live)
            cap = sum(w.capacity for w in alive)
            done = [t for t in tracked if t.terminal]
            missed = sum(
                1 for t in done
                if t.result.status == "failed"
                and t.result.reason == "deadline"
            )
            rate = missed / len(done) if done else 0.0
            pressure = max(demand / cap if cap else 1.0, rate)
            level = self.ladder.update(pressure)
            if self.tracer is not None:
                self.tracer.event(
                    "fleet:round", now, now, round=rounds, alive=len(alive),
                    queued=len(live), pressure=pressure, level=level,
                )
            # 3) backoff gate
            ready = [t for t in live if t.not_before <= now]
            if not ready or not alive:
                horizon = [t.not_before for t in live if t.not_before > now]
                wait = (min(horizon) - now) if horizon \
                    else max(cfg.lease_ttl_s / 4.0, 1e-3)
                self.sleep(wait)
                continue
            # 4) pack ready work onto workers; at the shed level, ready
            #    requests that did not fit this round AND were never
            #    dispatched before are rejected (shed), not queued forever.
            #    Shedding is priority-aware: only the lowest tier present
            #    among the candidates drops this round, so best-effort
            #    work absorbs overload before any higher tier is touched
            #    (liveness holds — a surviving tier becomes the lowest
            #    present next round and sheds then if still unplaceable)
            assignment = self._balance(ready, alive, now)
            assigned = {t.req.request_id
                        for ts in assignment.values() for t in ts}
            if level >= 3:
                victims = [t for t in ready
                           if t.req.request_id not in assigned
                           and not t.dispatched]
                if victims:
                    floor = min(self._req_prio(t) for t in victims)
                    for t in victims:
                        if self._req_prio(t) == floor:
                            self.tenant_ledger.note_shed(self._req_tenant(t))
                            self._fail(t, "shed", now, status="rejected")
            if not assignment:
                # every candidate exceeded the per-round bounds (can only
                # happen transiently while stragglers hold workers busy)
                self.sleep(max(cfg.lease_ttl_s / 4.0, 1e-3))
                continue
            for i, ts in assignment.items():
                # dispatch-time health check: grant a fresh lease (an idle
                # in-process worker is healthy by construction; only a
                # worker that stalls MID-run can miss renewals and die)
                self.store.put(self.workers[i].lease_key, {"worker": i},
                               ttl=cfg.lease_ttl_s)
                for t in ts:
                    t.attempts += 1
                    t.dispatched = True
                if self.tracer is not None:
                    self.tracer.event(
                        "fleet:dispatch", now, now, worker=i,
                        requests=len(ts),
                        pages=sum(t.worst_pages for t in ts),
                    )
            # 5) run the round and fold in the outcomes
            self._process_outcomes(self._run_round(assignment, stats), stats)
            # 6) recovery accounting: a death is recovered once every
            #    request it orphaned has reached a terminal status
            self._settle_recoveries(stats)
        # drain detached stragglers so their late results are accounted
        # (as duplicates, or as real commits for still-pending work)
        self._process_outcomes(self._collect_stragglers(block=True), stats)
        self._settle_recoveries(stats)
        tnow = self.clock()
        for d in self._deaths_open:  # pragma: no cover - drained above
            stats.recovery_s.append(tnow - d["t"])
        stats.results = [t.result for t in tracked]
        stats.rounds = rounds
        stats.num_workers = len(self.workers)   # joins included
        stats.wall_s = tnow - self._t_start
        stats.completed = sum(1 for r in stats.results
                              if r.status == "completed")
        stats.failed = sum(1 for r in stats.results if r.status == "failed")
        stats.rejected = sum(1 for r in stats.results
                             if r.status == "rejected")
        stats.duplicate_commits = self._dups
        stats.total_tokens = sum(
            len(r.tokens) for r in stats.results if r.tokens is not None
        )
        stats.throughput_tps = (
            stats.total_tokens / stats.wall_s if stats.wall_s > 0
            else float("inf")
        )
        admitted = stats.completed + stats.failed
        within = sum(1 for r in stats.results
                     if r.status == "completed" and r.within_deadline)
        stats.goodput = within / admitted if admitted else 0.0
        stats.degrade_transitions = list(self.ladder.transitions)
        stats.max_degrade_level = self.ladder.max_level
        stats.per_worker = [
            {"worker": w.index, "alive": w.alive, "served": w.served,
             "steps": w.steps, "deaths": w.deaths}
            for w in self.workers
        ]
        return stats

    # -- outcome folding -----------------------------------------------------
    def _fold_result(self, rr: Any, worker: int, tnow: float) -> None:
        """Fold one engine-level result into router state.  Completed
        results commit (idempotently); an engine that itself rejected a
        request (its own deadline/SLO shed) propagates that terminal
        status instead of being mistaken for a commit."""
        t = self._by_id[rr.request_id]
        status = getattr(rr, "status", "completed")
        if status == "completed":
            self._commit(t, rr.tokens, worker, tnow)
        else:
            reason = getattr(rr, "reason", "") or status
            self._fail(t, reason, tnow,
                       status="rejected" if status == "rejected"
                       else "failed")

    def _process_outcomes(self, outcomes: Dict[int, Tuple[str, Any]],
                          stats: FleetStats) -> None:
        for i, (kind, payload) in sorted(outcomes.items()):
            w = self.workers[i]
            tnow = self.clock()
            if kind == "ok":
                for rr in payload.results:
                    self._fold_result(rr, i, tnow)
                w.served += len(payload.results)
                # fold the engine's migration ledger into the fleet's
                # (getattr: stub engines in tests return bare namespaces)
                stats.migrated += getattr(payload, "restored_requests", 0)
                stats.migrated_tokens += getattr(payload, "restored_tokens", 0)
                stats.bytes_moved += getattr(payload, "restore_bytes", 0)
                stats.checkpoints_saved += getattr(
                    payload, "checkpoints_saved", 0)
                stats.checkpoint_bytes += getattr(
                    payload, "checkpoint_bytes", 0)
                stats.checksum_failures += getattr(
                    payload, "checksum_failures", 0)
                # a worker that returned cleanly is demonstrably responsive:
                # refresh its lease (a detached straggler's lease lapsed,
                # and it must not self-crash on its next dispatch)
                self.store.put(w.lease_key, {"worker": w.index},
                               ttl=self.config.lease_ttl_s)
            else:
                crash: WorkerCrash = payload
                drained = crash.reason == "drain"
                w.alive = False
                if drained:
                    stats.drains += 1
                else:
                    w.deaths += 1
                    stats.deaths += 1
                for rr in crash.results:
                    self._fold_result(rr, i, tnow)
                w.served += len(crash.results)
                orphans = [self._by_id[r.request_id] for r in crash.pending]
                orphans = [t for t in orphans if not t.terminal]
                # harvest the dead worker's checkpoints: orphans with a
                # snapshot migrate (O(bytes) restore on a survivor); the
                # rest replay from their prompts — that recompute debt is
                # exactly their prompt tokens
                migrated_here = 0
                recompute_here = 0
                for t in orphans:
                    rid = t.req.request_id
                    snap = (w.checkpoints.pop(rid, None)
                            if self.config.recovery == "migrate" else None)
                    if snap is not None:
                        self._migrations[rid] = snap
                        migrated_here += 1
                    else:
                        recompute_here += len(t.req.prompt)
                stats.recomputed_prefill_tokens += recompute_here
                if self.tracer is not None:
                    self.tracer.event(
                        "fleet:drain" if drained else "fleet:death",
                        tnow, tnow, worker=i,
                        reason=crash.reason, step=crash.step,
                        requeued=len(orphans), migrating=migrated_here,
                        recompute_tokens=recompute_here,
                    )
                n = self._requeue(orphans, tnow)
                stats.requeued += n
                if orphans:
                    self._deaths_open.append({
                        "t": tnow, "worker": i,
                        "rids": {t.req.request_id for t in orphans},
                    })

    def _settle_recoveries(self, stats: FleetStats) -> None:
        tnow = self.clock()
        for d in list(self._deaths_open):
            if all(self._by_id[rid].terminal for rid in d["rids"]):
                stats.recovery_s.append(tnow - d["t"])
                self._deaths_open.remove(d)
                if self.tracer is not None:
                    self.tracer.event(
                        "fleet:recovered", d["t"], tnow,
                        worker=d["worker"], orphans=len(d["rids"]),
                    )

    # -- round execution -----------------------------------------------------
    def _run_round(self, assignment: Dict[int, List[_Tracked]],
                   stats: FleetStats) -> Dict[int, Tuple[str, Any]]:
        """Run one round of worker batches.

        Sequential mode (default) runs workers in index order — fully
        deterministic, same commits and tokens as any interleaving since
        workers share nothing.  Parallel mode runs them in threads and
        (with ``hedge=True``) monitors leases: a worker whose lease expires
        mid-run is detached — its uncommitted assignment is requeued
        immediately (duplicate dispatch) and its thread keeps running into
        later rounds; whatever it eventually returns dedupes at commit.
        """
        outcomes: Dict[int, Tuple[str, Any]] = {}
        workers = {w.index: w for w in self.workers}
        if not self.config.parallel:
            for i in sorted(assignment):
                outcomes[i] = self._run_worker(workers[i], assignment[i])
            return outcomes
        holders: Dict[int, Dict[str, Any]] = {}
        for i, batch in assignment.items():
            holder: Dict[str, Any] = {"batch": batch, "outcome": None}

            def run(i=i, holder=holder) -> None:
                out = self._run_worker(workers[i], holder["batch"])
                with self._lock:
                    holder["outcome"] = out

            th = threading.Thread(target=run, daemon=True)
            holder["thread"] = th
            holders[i] = holder
            th.start()
        poll = max(self.config.lease_ttl_s / 8.0, 1e-3)
        detached: set = set()
        while True:
            waiting = [i for i in holders if i not in detached]
            with self._lock:
                pending = [i for i in waiting
                           if holders[i]["outcome"] is None]
            if not pending:
                break
            if self.config.hedge:
                now = self.clock()
                for i in pending:
                    if self.store.get(workers[i].lease_key) is None:
                        # straggler: lease lapsed mid-run — detach it and
                        # re-dispatch its uncommitted work right now; its
                        # eventual results dedupe at commit
                        detached.add(i)
                        orphans = [t for t in holders[i]["batch"]
                                   if not t.terminal]
                        n = self._requeue(orphans, now)
                        stats.hedged += n
                        self._inflight[i] = holders[i]
                        if self.tracer is not None:
                            self.tracer.event(
                                "fleet:hedge", now, now, worker=i,
                                requests=n,
                            )
                pending = [i for i in pending if i not in detached]
                if not pending:
                    break
            holders[pending[0]]["thread"].join(timeout=poll)
        with self._lock:
            return {i: holders[i]["outcome"] for i in holders
                    if i not in detached and holders[i]["outcome"] is not None}

    def _collect_stragglers(self, block: bool) -> Dict[int, Tuple[str, Any]]:
        """Harvest detached stragglers' outcomes; with ``block=True`` wait
        for every one of them (end-of-run drain)."""
        out: Dict[int, Tuple[str, Any]] = {}
        for i, holder in list(self._inflight.items()):
            if block:
                holder["thread"].join()
            with self._lock:
                done = holder["outcome"]
            if done is not None:
                out[i] = done
                del self._inflight[i]
        return out
