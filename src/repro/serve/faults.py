"""Deterministic fault injection for the serving fleet.

The fleet's failure model is only testable if failures are *scripted*: a
seeded :class:`FaultPlan` lists exactly which worker fails how and at which
engine-loop boundary, so a fault run is as reproducible as a fault-free one
(same seed -> same deaths -> same requeues -> same tokens).  Three fault
kinds cover the failure classes the router must survive:

* ``crash``   — the worker raises :class:`WorkerCrash` at the boundary; the
  engine attaches a resumable snapshot (finished results + every request
  not yet finished) before re-raising, and the router replays the pending
  requests from their prompts on the survivors.
* ``stall``   — the worker sleeps at the boundary (GC pause / network
  partition stand-in).  A stall longer than the worker's lease TTL makes
  the next heartbeat renewal fail, which the fleet turns into a
  self-inflicted :class:`WorkerCrash` — lease expiry and crash share one
  recovery path.
* ``pressure``— the fault seizes pages from the worker's pool for a number
  of boundaries (a noisy-neighbour / fragmentation stand-in), exercising
  preemption and the router's degrade ladder without killing anyone.
* ``corrupt`` — the fault flips the bytes of one page inside the worker's
  latest live-KV checkpoint WITHOUT touching its checksum ledger (bit-rot /
  torn-write stand-in).  Nothing dies; the corruption is only *observable*
  when a later migration tries to restore that snapshot — the import-side
  checksum verify must catch it and downgrade the request to
  replay-from-prompt (corrupted state is never served).  A corrupt spec
  whose step has arrived but whose worker holds no checkpoint yet stays
  pending until one exists (it needs a victim to bite).

Two faults may not share a ``worker:step`` slot: the firing order inside
one boundary would be ambiguous, so :meth:`FaultPlan.parse` rejects the
duplicate naming the offending spec token.

The engine loop calls the per-worker hook once per boundary behind a no-op
default (``fault_hook=None`` costs nothing), and every injected fault emits
a ``fault:*`` tracer event so recovery shows up in the analysis timeline
next to the ``fleet:*`` events it triggers.
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence

__all__ = [
    "FaultContext",
    "FaultError",
    "FaultPlan",
    "FaultSpec",
    "WorkerCrash",
    "WorkerDrain",
]

FAULT_KINDS = ("crash", "stall", "pressure", "corrupt")


class FaultError(RuntimeError):
    """Base class for injected faults."""


class WorkerCrash(FaultError):
    """A worker died (injected crash, or a lease the worker failed to renew).

    The engine catches this at the serve loop, attaches a *resumable
    snapshot* — ``results`` (every request already finished, commit-worthy)
    and ``pending`` (every request not yet finished, replayable from its
    prompt exactly like a preempted request) — and re-raises for the router.
    """

    def __init__(self, worker: int, step: int, reason: str = "crash") -> None:
        super().__init__(f"worker {worker} died at step {step} ({reason})")
        self.worker = worker
        self.step = step
        self.reason = reason
        self.results: List[Any] = []   # RequestResult, attached by the engine
        self.pending: List[Any] = []   # ServeRequest, attached by the engine


class WorkerDrain(WorkerCrash):
    """Planned elasticity: the router asked this worker to hand off its
    live work and leave the fleet.

    Shares the :class:`WorkerCrash` recovery path, with one upgrade: the
    engine catches it at the boundary and snapshots EVERY live decoding
    slot into the worker's checkpoint store *before* re-raising — the
    snapshots are as-of the drain boundary, so every migrated request
    resumes with zero recomputed tokens (a crash can only offer the last
    periodic checkpoint; a drain is voluntary, so it gets a fresh one).
    """

    def __init__(self, worker: int, step: int) -> None:
        super().__init__(worker, step, reason="drain")


@dataclass
class FaultContext:
    """What the engine exposes to a boundary hook: enough to observe and
    perturb the run, nothing that would let a fault corrupt bookkeeping.
    The engine is worker-agnostic — a hook that needs its worker index
    carries it itself (see :class:`_WorkerHook`)."""

    step: int
    pool: Any = None      # the worker's PagePool (pressure faults)
    clock: Callable[[], float] = time.perf_counter
    tracer: Any = None
    checkpoints: Any = None   # worker's {request_id: PageSnapshot} store
    #                           (corrupt faults bite the latest snapshot)


@dataclass(frozen=True)
class FaultSpec:
    """One scripted fault: ``kind`` hits ``worker`` at loop ``step``."""

    kind: str
    worker: int
    step: int
    duration_s: float = 0.0   # stall: how long the boundary sleeps
    pages: int = 0            # pressure: pages seized from the pool
    hold_steps: int = 1       # pressure: boundaries the seizure lasts

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.worker < 0 or self.step < 0:
            raise ValueError("worker and step must be >= 0")
        if self.kind == "stall" and self.duration_s < 0:
            raise ValueError("stall duration_s must be >= 0")
        if self.kind == "pressure" and (self.pages < 1 or self.hold_steps < 1):
            raise ValueError("pressure needs pages >= 1 and hold_steps >= 1")
        # corrupt takes no extra arguments: it bites the worker's latest
        # checkpoint, whichever request that happens to cover

    def to_dict(self) -> Dict[str, Any]:
        return {
            "kind": self.kind, "worker": self.worker, "step": self.step,
            "duration_s": self.duration_s, "pages": self.pages,
            "hold_steps": self.hold_steps,
        }


class _WorkerHook:
    """Per-worker boundary hook: fires this worker's specs in step order.

    A spec fires at the first boundary whose step counter has *reached* its
    scripted step (admission-only boundaries do not advance the decode step
    counter, so exact equality would be racy) and fires exactly once.
    Pressure seizures are returned to the pool ``hold_steps`` boundaries
    later, or on :meth:`release` if the run ends while they are held.
    """

    def __init__(self, worker: int, specs: Sequence[FaultSpec],
                 sleep: Callable[[float], None] = time.sleep) -> None:
        self.worker = worker
        self.sleep = sleep
        self._pending = sorted(specs, key=lambda s: (s.step, FAULT_KINDS.index(s.kind)))
        self._boundary = 0                      # boundaries seen (monotonic)
        self._seized: List[tuple] = []          # (release_at_boundary, pages, pool)
        self.fired: List[FaultSpec] = []

    def __call__(self, ctx: FaultContext) -> None:
        self._boundary += 1
        # return seizures whose hold has elapsed
        due = [t for t in self._seized if t[0] <= self._boundary]
        for release_at, pages, pool in due:
            pool.free(pages)
            self._seized.remove((release_at, pages, pool))
            if ctx.tracer is not None:
                now = ctx.clock()
                ctx.tracer.event("fault:pressure_release", now, now,
                                 worker=self.worker, pages=len(pages))
        while self._pending and self._pending[0].step <= ctx.step:
            spec = self._pending[0]
            if spec.kind == "corrupt" and not ctx.checkpoints:
                # nothing checkpointed yet: the fault needs a victim, so it
                # stays pending (holding any later specs — step order is
                # the contract) until a snapshot exists to corrupt
                break
            self._pending.pop(0)
            self.fired.append(spec)
            self._fire(spec, ctx)

    def _fire(self, spec: FaultSpec, ctx: FaultContext) -> None:
        t0 = ctx.clock()
        if spec.kind == "crash":
            if ctx.tracer is not None:
                ctx.tracer.event("fault:crash", t0, t0,
                                 worker=self.worker, step=ctx.step)
            raise WorkerCrash(self.worker, ctx.step, reason="injected-crash")
        if spec.kind == "stall":
            self.sleep(spec.duration_s)
            if ctx.tracer is not None:
                ctx.tracer.event("fault:stall", t0, ctx.clock(),
                                 worker=self.worker, step=ctx.step,
                                 duration_s=spec.duration_s)
            return
        if spec.kind == "corrupt":
            # bite the latest snapshot in the worker's checkpoint store
            # (max request_id of equal-step snapshots is deterministic);
            # the checksum ledger is deliberately left stale — only a
            # later restore's verify can observe the damage
            store = ctx.checkpoints
            rid = max(store, key=lambda r: (store[r].step, r))
            store[rid].corrupt(page=0)
            if ctx.tracer is not None:
                ctx.tracer.event("fault:corrupt", t0, t0,
                                 worker=self.worker, step=ctx.step,
                                 request=rid)
            return
        # pressure: seize what the pool can spare right now
        pool = ctx.pool
        take = min(spec.pages, pool.num_free) if pool is not None else 0
        pages = pool.alloc(take) if take > 0 else None
        if pages:
            self._seized.append((self._boundary + spec.hold_steps, pages, pool))
        if ctx.tracer is not None:
            ctx.tracer.event("fault:pressure", t0, t0, worker=self.worker,
                             step=ctx.step, pages=len(pages or ()),
                             requested=spec.pages, hold_steps=spec.hold_steps)

    def release(self) -> int:
        """Return every still-held seizure to its pool (end-of-run cleanup);
        returns the number of pages released."""
        n = 0
        for _, pages, pool in self._seized:
            pool.free(pages)
            n += len(pages)
        self._seized.clear()
        return n


class FaultPlan:
    """A seeded, deterministic schedule of faults across a worker fleet."""

    def __init__(self, specs: Sequence[FaultSpec] = (), seed: int = 0) -> None:
        self.specs = list(specs)
        self.seed = seed

    def __bool__(self) -> bool:
        return bool(self.specs)

    def for_worker(self, worker: int) -> List[FaultSpec]:
        return [s for s in self.specs if s.worker == worker]

    def hook_for(self, worker: int,
                 sleep: Callable[[float], None] = time.sleep
                 ) -> Optional[_WorkerHook]:
        """The boundary hook for ``worker`` — None when the plan never
        touches it, so the engine keeps its zero-cost default path."""
        specs = self.for_worker(worker)
        if not specs:
            return None
        return _WorkerHook(worker, specs, sleep=sleep)

    @classmethod
    def generate(cls, num_workers: int, seed: int = 0, *,
                 max_step: int = 16, crashes: int = 1, stalls: int = 0,
                 pressures: int = 0, stall_s: float = 0.05,
                 pages: int = 4, hold_steps: int = 2) -> "FaultPlan":
        """A random-but-seeded plan: same seed, same schedule."""
        if num_workers < 1:
            raise ValueError("num_workers must be >= 1")
        rng = random.Random(seed)
        specs: List[FaultSpec] = []
        for _ in range(crashes):
            specs.append(FaultSpec("crash", rng.randrange(num_workers),
                                   rng.randrange(1, max_step + 1)))
        for _ in range(stalls):
            specs.append(FaultSpec("stall", rng.randrange(num_workers),
                                   rng.randrange(1, max_step + 1),
                                   duration_s=stall_s))
        for _ in range(pressures):
            specs.append(FaultSpec("pressure", rng.randrange(num_workers),
                                   rng.randrange(1, max_step + 1),
                                   pages=pages, hold_steps=hold_steps))
        return cls(specs, seed=seed)

    @classmethod
    def parse(cls, text: str, seed: int = 0) -> "FaultPlan":
        """Parse the ``--fault-plan`` CLI syntax: comma-separated items

        * ``crash@W:S``           — crash worker W at step S
        * ``stall@W:S:DUR``       — stall worker W at step S for DUR seconds
        * ``pressure@W:S:PxH``    — seize P pages on worker W at step S for
          H boundaries
        * ``corrupt@W:S``         — flip bytes in worker W's latest live-KV
          checkpoint at step S (checksums stay stale; a later restore's
          verify must catch it)

        e.g. ``crash@1:6,stall@0:3:0.05,pressure@2:4:6x2``; empty or
        ``none`` parses to an empty plan.  Two items landing on the same
        ``worker:step`` are rejected (the firing order inside one boundary
        would be ambiguous) with an error naming the offending token.
        """
        text = (text or "").strip()
        if not text or text.lower() == "none":
            return cls(seed=seed)
        specs: List[FaultSpec] = []
        taken: set = set()              # (worker, step) slots already used
        for item in text.replace(";", ",").split(","):
            item = item.strip()
            if not item:
                continue
            try:
                kind, rest = item.split("@", 1)
                parts = rest.split(":")
                worker, step = int(parts[0]), int(parts[1])
                if (worker, step) in taken:
                    raise ValueError(
                        f"duplicate fault at worker {worker} step {step} "
                        f"(one fault per worker:step slot)"
                    )
                taken.add((worker, step))
                if kind == "crash":
                    specs.append(FaultSpec("crash", worker, step))
                elif kind == "stall":
                    dur = float(parts[2]) if len(parts) > 2 else 0.05
                    specs.append(FaultSpec("stall", worker, step,
                                           duration_s=dur))
                elif kind == "pressure":
                    pages, hold = 4, 2
                    if len(parts) > 2:
                        p = parts[2].lower().split("x")
                        pages = int(p[0])
                        hold = int(p[1]) if len(p) > 1 else 2
                    specs.append(FaultSpec("pressure", worker, step,
                                           pages=pages, hold_steps=hold))
                elif kind == "corrupt":
                    specs.append(FaultSpec("corrupt", worker, step))
                else:
                    raise ValueError(f"unknown fault kind {kind!r}")
            except (ValueError, IndexError) as e:
                raise ValueError(
                    f"bad fault-plan item {item!r}: {e}"
                ) from None
        return cls(specs, seed=seed)

    def describe(self) -> str:
        if not self.specs:
            return "none"
        out = []
        for s in sorted(self.specs, key=lambda s: (s.step, s.worker)):
            if s.kind in ("crash", "corrupt"):
                out.append(f"{s.kind}@{s.worker}:{s.step}")
            elif s.kind == "stall":
                out.append(f"stall@{s.worker}:{s.step}:{s.duration_s:g}")
            else:
                out.append(
                    f"pressure@{s.worker}:{s.step}:{s.pages}x{s.hold_steps}"
                )
        return ",".join(out)

    def to_dict(self) -> Dict[str, Any]:
        return {"seed": self.seed, "specs": [s.to_dict() for s in self.specs]}
