"""Continuous-batching request scheduler (the shared serving hot path).

Every benchmarking scenario and the serving engine issue work through one
asynchronous :class:`RequestScheduler`: a bounded request queue with
dynamic micro-batching (coalesce up to ``max_batch`` requests that arrive
within a ``batch_timeout_ms`` admission window) and per-request completion
futures.  This is the layer the paper's cloud-serving scenarios exercise —
queueing, batching and admission effects all happen here, not inside the
model executor.

Dequeue order is SLO- and tenant-aware rather than strictly FIFO: every
request carries a ``tenant``/``priority``/``slo_ms`` triple, tenants are
rate-limited by token buckets (refill rate + burst, charged in
prompt+decode tokens), and batch formation picks work by priority tier
first, then weighted fair share across tenants (start-time virtual
clocks), then arrival order.  Selection is *work-conserving*: a tenant
that has drained its bucket is deprioritized, never starved, so the
scheduler keeps serving when only over-budget work is queued.  Requests
whose SLO is already unmeetable (estimated from queue depth and the
measured batch service rate) are shed with a terminal ``rejected`` status
instead of wasting capacity — every request still reaches exactly one
terminal status.  With a single default tenant the policy degenerates to
the original FIFO order, byte for byte.

Two drive modes share the same batch-formation logic:

* **synchronous** (no worker thread) — ``step()`` / ``run_until_idle()``
  form and execute micro-batches inline.  With an injected fake
  ``clock``/``sleep`` pair this is a deterministic discrete-event
  simulation of the server (requests may be pre-submitted with future
  ``arrival_s`` values); with real time it is a single-threaded server
  loop.  ``CompletionFuture.result()`` drives the scheduler until that
  request completes, so closed-loop scenarios need no thread.
* **threaded** — ``start()`` spawns a worker that coalesces concurrently
  submitted requests under a condition variable; ``batch_timeout_ms``
  bounds how long a non-full batch waits for stragglers.

The scheduler also owns the *slot* bookkeeping for continuous batching
(:class:`SlotPool`): a fixed pool of KV-cache slots where finished
sequences free their slot and queued prompts are admitted at decode-step
boundaries (used by ``repro.serve.engine.ServingEngine.serve_continuous``).
"""
from __future__ import annotations

import bisect
import random
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

__all__ = [
    "CompletionFuture",
    "DeadlineExceeded",
    "PRIORITY_TIERS",
    "PagedSlotPool",
    "PrefillBudget",
    "RequestScheduler",
    "RetriesExhausted",
    "ScheduledRequest",
    "SchedulerConfig",
    "SchedulerQueueFull",
    "SlotPool",
    "SpecLedger",
    "TenantLedger",
    "TenantSpec",
    "TokenBucket",
    "backoff_delay",
]

# priority tiers, lowest first: tier 0 is shed first and preempted first
PRIORITY_TIERS = ("best_effort", "standard", "premium")


class SchedulerQueueFull(RuntimeError):
    """Raised when a non-blocking submit finds the bounded queue full."""


class DeadlineExceeded(RuntimeError):
    """A request's deadline passed before it could execute (its completion
    future raises this — a deadlined request is terminal, never silent)."""


class RetriesExhausted(RuntimeError):
    """A request failed and its retry budget is spent; carries the last
    underlying error as ``__cause__``."""


def backoff_delay(attempt: int, base_s: float, cap_s: float,
                  jitter: float = 0.0,
                  rng: Optional[random.Random] = None) -> float:
    """Capped exponential backoff: ``min(cap, base * 2**(attempt-1))``,
    optionally scaled by a symmetric ``±jitter`` fraction drawn from ``rng``
    (a seeded :class:`random.Random` keeps retry schedules deterministic).
    Shared by the scheduler retry path, the fleet requeue path and the
    server's re-dispatch loop."""
    if attempt < 1:
        raise ValueError("attempt must be >= 1")
    d = min(cap_s, base_s * (2.0 ** (attempt - 1)))
    if jitter > 0.0 and rng is not None:
        d *= 1.0 + jitter * (2.0 * rng.random() - 1.0)
    return max(d, 0.0)


@dataclass
class TenantSpec:
    """One tenant's contract: priority tier, fair-share weight, and an
    optional token-bucket rate limit (charged in prompt+decode tokens).

    ``burst_tokens`` is the bucket capacity; 0 defaults to one second of
    refill.  ``slo_ms`` is the tenant's default latency SLO, applied to
    submissions that do not override it.
    """

    name: str
    priority: int = 1                 # index into PRIORITY_TIERS (higher wins)
    weight: float = 1.0               # fair-share weight within the tier
    rate_tokens_per_s: float = 0.0    # bucket refill rate (0 = unlimited)
    burst_tokens: float = 0.0         # bucket capacity (0 = 1s of refill)
    slo_ms: float = 0.0               # default per-request SLO (0 = none)

    def __post_init__(self) -> None:
        if not self.name:
            raise ValueError("tenant name must be non-empty")
        if self.weight <= 0:
            raise ValueError("tenant weight must be > 0")
        if self.priority < 0:
            raise ValueError("tenant priority must be >= 0")
        if self.rate_tokens_per_s < 0 or self.burst_tokens < 0:
            raise ValueError("tenant rate/burst must be >= 0")

    @property
    def tier(self) -> str:
        return PRIORITY_TIERS[min(self.priority, len(PRIORITY_TIERS) - 1)]

    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "priority": self.priority,
            "weight": self.weight,
            "rate_tokens_per_s": self.rate_tokens_per_s,
            "burst_tokens": self.burst_tokens,
            "slo_ms": self.slo_ms,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TenantSpec":
        return cls(**{k: v for k, v in d.items() if k in cls.__dataclass_fields__})


class TokenBucket:
    """Classic token bucket, driven by caller-supplied clock readings so an
    injected fake clock yields deterministic admission decisions.

    Charges clamp at zero (leaky, work-conserving): a tenant served while
    over budget does not accumulate unbounded debt, it just stays *dry*
    (``available < cost``) until the refill catches up with its demand.
    """

    def __init__(self, rate_per_s: float, burst: float) -> None:
        if rate_per_s <= 0 or burst <= 0:
            raise ValueError("token bucket needs rate > 0 and burst > 0")
        self.rate = float(rate_per_s)
        self.burst = float(burst)
        self.tokens = float(burst)
        self._last: Optional[float] = None
        self.charged_total = 0.0

    def _refill(self, now: float) -> None:
        if self._last is not None and now > self._last:
            self.tokens = min(self.burst, self.tokens + (now - self._last) * self.rate)
        self._last = now if self._last is None else max(self._last, now)

    def available(self, now: float) -> float:
        self._refill(now)
        return self.tokens

    def dry(self, cost: float, now: float) -> bool:
        return self.available(now) < cost

    def charge(self, cost: float, now: float) -> None:
        """Deduct ``cost`` tokens (floored at zero — see class docstring)."""
        self._refill(now)
        self.tokens = max(0.0, self.tokens - cost)
        self.charged_total += cost

    def time_until(self, cost: float, now: float) -> float:
        """Seconds until ``cost`` tokens will be available (0 if already)."""
        have = self.available(now)
        if have >= cost:
            return 0.0
        return (min(cost, self.burst) - have) / self.rate


class TenantLedger:
    """Per-tenant admission state: token buckets, fair-share virtual
    clocks, and the shed/defer audit counters.

    Fair dequeue is start-time weighted fair queuing: each admission
    advances the tenant's virtual time by ``cost/weight``; the scheduler
    picks the queued request with the smallest ``(dry, -priority, vtime)``
    key, so rate limits bind first, then tiers, then fair share.  A tenant
    returning from idle resumes at the ledger's current virtual time — no
    banked backlog advantage.
    """

    def __init__(self, specs: Sequence[TenantSpec] = ()) -> None:
        self.specs: Dict[str, TenantSpec] = {}
        self.buckets: Dict[str, TokenBucket] = {}
        self.vtime: Dict[str, float] = {}
        self.admitted: Dict[str, int] = {}
        self.tokens_admitted: Dict[str, float] = {}
        self.shed: Dict[str, int] = {}
        self.deferred: Dict[str, int] = {}
        self._vnow = 0.0
        for spec in specs:
            self.register(spec)

    def register(self, spec: TenantSpec) -> TenantSpec:
        self.specs[spec.name] = spec
        if spec.rate_tokens_per_s > 0:
            burst = spec.burst_tokens or spec.rate_tokens_per_s
            self.buckets[spec.name] = TokenBucket(spec.rate_tokens_per_s, burst)
        for ledger in (self.admitted, self.shed, self.deferred):
            ledger.setdefault(spec.name, 0)
        self.tokens_admitted.setdefault(spec.name, 0.0)
        self.vtime.setdefault(spec.name, self._vnow)
        return spec

    def spec_of(self, name: str) -> TenantSpec:
        """The tenant's spec, auto-registering an unlimited default one."""
        spec = self.specs.get(name)
        if spec is None:
            spec = self.register(TenantSpec(name=name))
        return spec

    def dry(self, name: str, cost: float, now: float) -> bool:
        bucket = self.buckets.get(name)
        return bucket is not None and bucket.dry(cost, now)

    def refill_in(self, name: str, cost: float, now: float) -> float:
        bucket = self.buckets.get(name)
        return 0.0 if bucket is None else bucket.time_until(cost, now)

    def on_admit(self, name: str, cost: float, now: float) -> None:
        """Charge the bucket and advance the fair-share virtual clock."""
        spec = self.spec_of(name)
        bucket = self.buckets.get(name)
        if bucket is not None:
            bucket.charge(cost, now)
        base = max(self.vtime.get(name, 0.0), self._vnow)
        self.vtime[name] = base + cost / spec.weight
        self._vnow = base
        self.admitted[name] = self.admitted.get(name, 0) + 1
        self.tokens_admitted[name] = self.tokens_admitted.get(name, 0.0) + cost

    def note_shed(self, name: str) -> None:
        self.spec_of(name)
        self.shed[name] = self.shed.get(name, 0) + 1

    def note_defer(self, name: str) -> None:
        self.spec_of(name)
        self.deferred[name] = self.deferred.get(name, 0) + 1

    def stats(self) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = {}
        for name, spec in self.specs.items():
            bucket = self.buckets.get(name)
            out[name] = {
                "priority": float(spec.priority),
                "weight": float(spec.weight),
                "admitted": float(self.admitted.get(name, 0)),
                "tokens_admitted": float(self.tokens_admitted.get(name, 0.0)),
                "shed": float(self.shed.get(name, 0)),
                "deferred": float(self.deferred.get(name, 0)),
                "bucket_charged": bucket.charged_total if bucket else 0.0,
            }
        return out


@dataclass
class SchedulerConfig:
    """Knobs for the request scheduler (part of the user input; the server
    threads this through dispatch so an evaluation can select the
    scheduler-backed executor)."""

    max_batch: int = 8             # micro-batch coalescing limit (requests)
    batch_timeout_ms: float = 2.0  # admission window for a non-full batch
    queue_depth: int = 1024        # bounded queue (admission control)
    num_slots: int = 8             # KV slots for continuous batching
    page_size: int = 16            # tokens per KV page (paged engine)
    num_pages: int = 0             # global KV page pool size (0 = engine default)
    prefill_chunk: int = 0         # chunked-prefill tokens per step (0 = default)
    prefill_budget: int = 0        # packed-prefill tokens per boundary (0 = default)
    spec_k: int = 0                # speculative draft depth (0 = disabled)
    spec_ngram: int = 3            # prompt-lookup n-gram match length
    prefix_cache: bool = False     # automatic prefix caching (paged engine)
    deadline_ms: float = 0.0       # per-request TTL (0 = no deadline)
    max_retries: int = 0           # batch-failure retry budget per request
    backoff_base_ms: float = 10.0  # retry backoff: base delay
    backoff_cap_ms: float = 1000.0 # retry backoff: cap
    backoff_jitter: float = 0.0    # retry backoff: ±fraction (0 = none)
    retry_seed: int = 0            # jitter RNG seed (determinism)
    fairness: bool = True          # tier + weighted-fair dequeue (off = FIFO)
    slo_shed: bool = True          # shed work whose SLO is already unmeetable

    def to_dict(self) -> Dict[str, Any]:
        return {
            "max_batch": self.max_batch,
            "batch_timeout_ms": self.batch_timeout_ms,
            "queue_depth": self.queue_depth,
            "num_slots": self.num_slots,
            "page_size": self.page_size,
            "num_pages": self.num_pages,
            "prefill_chunk": self.prefill_chunk,
            "prefill_budget": self.prefill_budget,
            "spec_k": self.spec_k,
            "spec_ngram": self.spec_ngram,
            "prefix_cache": self.prefix_cache,
            "deadline_ms": self.deadline_ms,
            "max_retries": self.max_retries,
            "backoff_base_ms": self.backoff_base_ms,
            "backoff_cap_ms": self.backoff_cap_ms,
            "backoff_jitter": self.backoff_jitter,
            "retry_seed": self.retry_seed,
            "fairness": self.fairness,
            "slo_shed": self.slo_shed,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "SchedulerConfig":
        return cls(**{k: v for k, v in d.items() if k in cls.__dataclass_fields__})


@dataclass
class ScheduledRequest:
    """One unit of scheduled work plus its measured lifecycle times.

    All times are in scheduler-clock units (``clock()`` values), so an
    injected fake clock yields fully deterministic latencies.
    """

    request_id: int
    batch_size: int = 1
    arrival_s: float = 0.0      # when the request enters the system
    payload: Any = None
    submit_s: float = 0.0       # when submit() was called
    start_s: float = 0.0        # micro-batch execution start
    end_s: float = 0.0          # micro-batch execution end
    deadline_s: Optional[float] = None  # absolute clock deadline (TTL)
    attempts: int = 0           # failed executions so far (retry ledger)
    status: str = "queued"      # queued | completed | failed | rejected
    tenant: str = "default"     # owning tenant (fairness + rate limiting)
    priority: int = 1           # tier (index into PRIORITY_TIERS)
    slo_ms: float = 0.0         # latency SLO for goodput (0 = none)
    cost_tokens: float = 1.0    # bucket charge (prompt+decode tokens)
    future: "CompletionFuture" = None  # type: ignore[assignment]

    @property
    def queue_s(self) -> float:
        return max(0.0, self.start_s - self.arrival_s)

    @property
    def service_s(self) -> float:
        return self.end_s - self.start_s

    @property
    def latency_s(self) -> float:
        """End-to-end latency including queueing delay."""
        return self.end_s - self.arrival_s


class CompletionFuture:
    """Per-request completion handle.

    In threaded mode ``result()`` blocks on an event; in synchronous mode it
    drives the scheduler until this request's micro-batch has executed.
    """

    __slots__ = ("request", "_scheduler", "_event", "_value", "_error", "_done")

    def __init__(self, scheduler: "RequestScheduler", request: ScheduledRequest):
        self.request = request
        self._scheduler = scheduler
        self._event = threading.Event()
        self._value: Any = None
        self._error: Optional[BaseException] = None
        self._done = False

    def done(self) -> bool:
        return self._done

    def _set(self, value: Any = None, error: Optional[BaseException] = None) -> None:
        self._value = value
        self._error = error
        self._done = True
        self._event.set()

    def result(self, timeout: Optional[float] = None) -> Any:
        if not self._done:
            if self._scheduler.running:
                if not self._event.wait(timeout):
                    raise TimeoutError(
                        f"request {self.request.request_id} not done in {timeout}s"
                    )
            else:
                self._scheduler._drive_until(self)
        if self._error is not None:
            raise self._error
        return self._value


class RequestScheduler:
    """Bounded-queue, micro-batching request scheduler.

    ``execute`` runs one coalesced micro-batch: it receives the list of
    :class:`ScheduledRequest` and returns either one result per request, or
    a single value shared by all of them (or ``None``).
    """

    def __init__(
        self,
        execute: Callable[[List[ScheduledRequest]], Any],
        config: Optional[SchedulerConfig] = None,
        *,
        clock: Callable[[], float] = time.perf_counter,
        sleep: Callable[[float], None] = time.sleep,
        tracer=None,
        tenants: Sequence[TenantSpec] = (),
    ) -> None:
        self.execute = execute
        self.config = config or SchedulerConfig()
        if self.config.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.config.queue_depth < 1:
            raise ValueError("queue_depth must be >= 1")
        self.clock = clock
        self.sleep = sleep
        self.tracer = tracer
        self._cond = threading.Condition()
        # pending requests sorted by (arrival_s, request_id): FIFO within
        # identical arrivals, earliest-arrival-first otherwise
        self._queue: List[ScheduledRequest] = []
        self._next_id = 0
        self._thread: Optional[threading.Thread] = None
        self.running = False
        # stats series: (time, value) samples recorded at each batch execution
        self.queue_depth_series: List[tuple] = []
        self.batch_occupancy_series: List[tuple] = []
        self.submitted = 0
        self.completed = 0
        self.rejected = 0
        self.batches = 0
        self.retries = 0            # re-enqueues after a failed batch
        self.deadline_failures = 0  # requests terminal via DeadlineExceeded
        self.retry_failures = 0     # requests terminal via RetriesExhausted
        # graceful degradation: the router flips this at its top degrade
        # level so NEW admissions are shed with an explicit rejected status
        # (already-queued work still drains)
        self.shedding = False
        self._retry_rng = random.Random(self.config.retry_seed)
        # tenant-aware admission: buckets, fair-share clocks, audit counters
        self.ledger = TenantLedger(tenants)
        self.shed = 0        # requests terminal via SLO-unmeetable admission
        self.deferred = 0    # tenant-boundary deferrals (bucket ran dry)
        self._service_ewma = 0.0  # measured per-batch service time (s)

    # -- submission ----------------------------------------------------------
    def submit(
        self,
        payload: Any = None,
        batch_size: int = 1,
        arrival_s: Optional[float] = None,
        block: bool = True,
        deadline_s: Optional[float] = None,
        tenant: str = "default",
        priority: Optional[int] = None,
        slo_ms: Optional[float] = None,
        cost_tokens: Optional[float] = None,
    ) -> CompletionFuture:
        """Enqueue one request; returns its completion future.

        ``arrival_s`` is an absolute scheduler-clock time; pre-submitting
        future arrivals turns the synchronous drive into a discrete-event
        simulation.  With ``block=False`` a full queue (counting only
        requests whose arrival has passed) raises :class:`SchedulerQueueFull`
        — the admission-control path.  ``deadline_s`` is an absolute clock
        deadline (defaults to ``arrival + config.deadline_ms`` when the
        config sets one); a request still queued past its deadline fails
        with :class:`DeadlineExceeded` instead of executing.

        ``tenant``/``priority``/``slo_ms`` place the request in the
        fairness policy (defaults come from the tenant's registered
        :class:`TenantSpec`); ``cost_tokens`` is the token-bucket charge —
        prompt + expected decode tokens — defaulting to ``batch_size``.
        """
        with self._cond:
            if self.shedding:
                self.rejected += 1
                raise SchedulerQueueFull(
                    "admission shed: scheduler is in degraded (shedding) mode"
                )
            if self._arrived_depth(self.clock()) >= self.config.queue_depth:
                if not block:
                    self.rejected += 1
                    raise SchedulerQueueFull(
                        f"queue depth {self.config.queue_depth} exceeded"
                    )
                if self.running:
                    while self._arrived_depth(self.clock()) >= self.config.queue_depth:
                        self._cond.wait()
            now = self.clock()
            arrival = now if arrival_s is None else arrival_s
            if deadline_s is None and self.config.deadline_ms > 0:
                deadline_s = arrival + self.config.deadline_ms / 1e3
            spec = self.ledger.spec_of(tenant)
            req = ScheduledRequest(
                request_id=self._next_id,
                batch_size=batch_size,
                arrival_s=arrival,
                payload=payload,
                submit_s=now,
                deadline_s=deadline_s,
                tenant=tenant,
                priority=spec.priority if priority is None else priority,
                slo_ms=spec.slo_ms if slo_ms is None else slo_ms,
                cost_tokens=float(batch_size) if cost_tokens is None
                else float(cost_tokens),
            )
            self._next_id += 1
            req.future = CompletionFuture(self, req)
            bisect.insort(self._queue, req, key=lambda r: (r.arrival_s, r.request_id))
            self.submitted += 1
            self._cond.notify_all()
        return req.future

    def _arrived_depth(self, now: float) -> int:
        """Queued requests whose arrival time has passed (the *real* queue);
        pre-submitted future arrivals are not yet in the system."""
        return bisect.bisect_right(self._queue, (now, float("inf")),
                                   key=lambda r: (r.arrival_s, r.request_id))

    @property
    def pending(self) -> int:
        with self._cond:
            return len(self._queue)

    # -- synchronous drive ---------------------------------------------------
    def step(self) -> int:
        """Form and execute one micro-batch; returns the number of requests
        served (0 when the queue is empty).  Sleeps (via the injected
        ``sleep``) to the next arrival when nothing has arrived yet."""
        batch = self._form_batch_sync()
        if not batch:
            return 0
        self._run_batch(batch)
        return len(batch)

    def run_until_idle(self) -> int:
        """Drain the queue completely; returns total requests served."""
        served = 0
        while True:
            n = self.step()
            if n == 0:
                return served
            served += n

    def _drive_until(self, future: CompletionFuture) -> None:
        while not future.done():
            if self.step() == 0:
                raise RuntimeError(
                    f"request {future.request.request_id} unreachable: queue idle"
                )

    # -- tenant-aware selection ---------------------------------------------
    def _policy_key(self, req: ScheduledRequest, now: float) -> tuple:
        """Dequeue order: rate limits bind first (dry tenants sink), then
        priority tier, then weighted fair share, then arrival order."""
        dry = 1 if self.ledger.dry(req.tenant, req.cost_tokens, now) else 0
        return (dry, -req.priority, self.ledger.vtime.get(req.tenant, 0.0),
                req.arrival_s, req.request_id)

    def _pop_policy(self, now: float) -> Optional[ScheduledRequest]:
        """Pop the next arrived request under the fairness policy (caller
        holds the lock).  Work-conserving: when every arrived tenant is
        dry, the best-ranked request is still served."""
        n = self._arrived_depth(now)
        if n == 0:
            return None
        if n == 1 or not self.config.fairness:
            idx = 0
        else:
            idx = min(range(n),
                      key=lambda i: self._policy_key(self._queue[i], now))
        req = self._queue.pop(idx)
        self.ledger.on_admit(req.tenant, req.cost_tokens, now)
        return req

    def _shed_sweep(self, now: float) -> None:
        """Shed arrived requests whose SLO is already unmeetable, estimated
        from queue position and the measured batch service time (caller
        holds the lock).  Terminal ``rejected`` status — never silent."""
        if not self.config.slo_shed or self.batches == 0:
            return
        est = self._service_ewma
        if est <= 0.0:
            return
        idxs = list(range(self._arrived_depth(now)))
        if self.config.fairness:
            # service order is the POLICY order, not arrival order: a
            # high-priority or under-budget tenant deep in the arrival
            # queue will be served early and must not be shed for the
            # backlog in front of it (stable sort: untagged queues keep
            # their arrival ranks exactly)
            idxs.sort(key=lambda i: self._policy_key(self._queue[i], now))
        doomed: List[ScheduledRequest] = []
        for rank, i in enumerate(idxs):
            req = self._queue[i]
            if req.slo_ms <= 0:
                continue
            # rank/max_batch batches ahead of this request, plus its own
            est_finish = now + est * (1.0 + rank / self.config.max_batch)
            if est_finish > req.arrival_s + req.slo_ms / 1e3:
                doomed.append(req)
        for req in doomed:
            self._queue.remove(req)
            req.start_s = req.end_s = now
            req.status = "rejected"
            self.shed += 1
            self.ledger.note_shed(req.tenant)
            req.future._set(None, DeadlineExceeded(
                f"request {req.request_id} shed at admission: "
                f"{req.slo_ms:.0f}ms SLO unmeetable"
            ))
            self._emit_tenant(req)

    def _note_defers(self, now: float) -> None:
        """Count tenants whose arrived work was passed over because their
        bucket ran dry — one deferral per tenant per batch formation."""
        if not self.config.fairness:
            return
        seen: set = set()
        for i in range(self._arrived_depth(now)):
            req = self._queue[i]
            if req.tenant in seen:
                continue
            if self.ledger.dry(req.tenant, req.cost_tokens, now):
                seen.add(req.tenant)
                self.ledger.note_defer(req.tenant)
                self.deferred += 1
                if self.tracer is not None:
                    self.tracer.event("sched:defer", now, now,
                                      tenant=req.tenant)

    def _emit_tenant(self, req: ScheduledRequest) -> None:
        """Publish one ``sched:tenant`` event per terminal request."""
        if self.tracer is None:
            return
        latency = max(0.0, req.end_s - req.arrival_s)
        slo_ok = (req.status == "completed"
                  and (req.slo_ms <= 0 or latency * 1e3 <= req.slo_ms))
        self.tracer.event(
            "sched:tenant",
            req.start_s,
            req.end_s,
            tenant=req.tenant,
            priority=req.priority,
            status=req.status,
            latency_s=latency,
            slo_ms=req.slo_ms,
            slo_ok=slo_ok,
            tokens=req.cost_tokens,
        )

    def _form_batch_sync(self) -> List[ScheduledRequest]:
        timeout_s = self.config.batch_timeout_ms / 1e3
        while True:
            with self._cond:
                if not self._queue:
                    return []
                first = self._queue[0]
            now = self.clock()
            if first.arrival_s > now:
                self.sleep(first.arrival_s - now)
                now = self.clock()
            deadline = now + timeout_s
            batch: List[ScheduledRequest] = []
            with self._cond:
                self._shed_sweep(now)
                while len(batch) < self.config.max_batch:
                    req = self._pop_policy(now)
                    if req is not None:
                        batch.append(req)
                        continue
                    if not batch or not self._queue:
                        break
                    nxt = self._queue[0]
                    if timeout_s > 0 and nxt.arrival_s <= deadline:
                        # hold the batch open until the straggler arrives
                        self.sleep(nxt.arrival_s - now)
                        now = self.clock()
                    else:
                        break
                self._note_defers(now)
                self._cond.notify_all()
                if batch or not self._queue:
                    return batch
            # everything arrived was shed; loop on to the next arrival

    # -- threaded drive ------------------------------------------------------
    def start(self) -> "RequestScheduler":
        if self._thread is not None:
            return self
        self.running = True
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()
        return self

    def stop(self, wait: bool = True) -> None:
        with self._cond:
            self.running = False
            self._cond.notify_all()
        if self._thread is not None and wait:
            self._thread.join()
            self._thread = None

    def _pop_threaded(self) -> ScheduledRequest:
        """Policy pop for the worker thread: prefer the fairness ranking
        over arrived requests, fall back to the queue head (caller holds
        the lock and has checked the queue is non-empty)."""
        req = self._pop_policy(self.clock())
        if req is None:
            req = self._queue.pop(0)
            self.ledger.on_admit(req.tenant, req.cost_tokens, self.clock())
        return req

    def _worker(self) -> None:
        timeout_s = self.config.batch_timeout_ms / 1e3
        while True:
            batch: List[ScheduledRequest] = []
            with self._cond:
                while self.running and not self._queue:
                    self._cond.wait()
                if not self.running and not self._queue:
                    return
                batch.append(self._pop_threaded())
                deadline = time.monotonic() + timeout_s
                while len(batch) < self.config.max_batch:
                    if self._queue:
                        batch.append(self._pop_threaded())
                        continue
                    remaining = deadline - time.monotonic()
                    if remaining <= 0 or not self.running:
                        break
                    self._cond.wait(remaining)
                    if not self._queue and time.monotonic() >= deadline:
                        break
                self._cond.notify_all()
            self._run_batch(batch)

    # -- execution -----------------------------------------------------------
    def _run_batch(self, batch: List[ScheduledRequest]) -> None:
        start = self.clock()
        with self._cond:
            depth = self._arrived_depth(start)
        # deadline enforcement BEFORE execution: a request whose TTL passed
        # while queued is terminal (DeadlineExceeded), never silently run
        # late and never left hanging
        live: List[ScheduledRequest] = []
        for req in batch:
            if req.deadline_s is not None and start > req.deadline_s:
                req.start_s = req.end_s = start
                req.status = "failed"
                self.deadline_failures += 1
                req.future._set(None, DeadlineExceeded(
                    f"request {req.request_id} missed deadline "
                    f"({start - req.deadline_s:.3f}s late)"
                ))
                self._emit_tenant(req)
            else:
                live.append(req)
        error: Optional[BaseException] = None
        out: Any = None
        if live:
            try:
                out = self.execute(live)
            except BaseException as e:  # noqa: BLE001 - propagated via futures
                error = e
        end = self.clock()
        terminal = len(batch) - len(live)
        if error is not None and self.config.max_retries > 0:
            # failed batch with a retry budget: re-enqueue what still has
            # budget (capped exponential backoff + jitter pushes the retry
            # arrival into the future), fail the rest terminally
            retried: List[ScheduledRequest] = []
            for req in live:
                req.attempts += 1
                if req.attempts <= self.config.max_retries:
                    delay = backoff_delay(
                        req.attempts,
                        self.config.backoff_base_ms / 1e3,
                        self.config.backoff_cap_ms / 1e3,
                        self.config.backoff_jitter,
                        self._retry_rng,
                    )
                    req.arrival_s = end + delay
                    retried.append(req)
                    self.retries += 1
                else:
                    req.start_s, req.end_s = start, end
                    req.status = "failed"
                    self.retry_failures += 1
                    terminal += 1
                    exhausted = RetriesExhausted(
                        f"request {req.request_id} failed after "
                        f"{req.attempts} attempt(s): {error}"
                    )
                    exhausted.__cause__ = error
                    req.future._set(None, exhausted)
                    self._emit_tenant(req)
            if retried:
                with self._cond:
                    for req in retried:
                        bisect.insort(
                            self._queue, req,
                            key=lambda r: (r.arrival_s, r.request_id),
                        )
                    self._cond.notify_all()
        else:
            results: Sequence[Any]
            if isinstance(out, (list, tuple)) and len(out) == len(live):
                results = out
            else:
                results = [out] * len(live)
            for req, value in zip(live, results):
                req.start_s = start
                req.end_s = end
                req.status = "failed" if error is not None else "completed"
                req.future._set(value, error)
                self._emit_tenant(req)
            terminal += len(live)
        if live:
            # measured batch service time feeds the SLO-shed estimator
            dt = end - start
            self._service_ewma = (dt if self._service_ewma <= 0.0
                                  else 0.5 * dt + 0.5 * self._service_ewma)
        self.batches += 1
        self.completed += terminal
        self.queue_depth_series.append((start, depth))
        self.batch_occupancy_series.append((start, len(batch)))
        if self.tracer is not None:
            self.tracer.event(
                "scheduler:batch",
                start,
                end,
                occupancy=len(batch),
                queue_depth=depth,
                inputs=sum(r.batch_size for r in batch),
            )
        with self._cond:
            self._cond.notify_all()

    # -- reporting -----------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        """Scalar summary of the queue/batching series (metrics block)."""
        occ = [v for _, v in self.batch_occupancy_series]
        dep = [v for _, v in self.queue_depth_series]
        return {
            "batches": float(self.batches),
            "submitted": float(self.submitted),
            "completed": float(self.completed),
            "rejected": float(self.rejected),
            "retries": float(self.retries),
            "deadline_failures": float(self.deadline_failures),
            "retry_failures": float(self.retry_failures),
            "shed": float(self.shed),
            "deferred": float(self.deferred),
            "mean_batch_occupancy": sum(occ) / len(occ) if occ else 0.0,
            "max_queue_depth": float(max(dep)) if dep else 0.0,
            "mean_queue_depth": sum(dep) / len(dep) if dep else 0.0,
        }


class SlotPool:
    """Fixed pool of KV-cache slots for continuous batching.

    Finished sequences release their slot; queued prompts are admitted into
    free slots at decode-step boundaries.  Pure bookkeeping — the engine owns
    the actual cache tensors — so admission order and slot reuse are testable
    without a model.
    """

    def __init__(self, num_slots: int) -> None:
        if num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        self.num_slots = num_slots
        self._free: List[int] = list(range(num_slots - 1, -1, -1))  # pop() -> 0,1,..
        self.active: Dict[int, Any] = {}
        # admission log: (step, slot, request) — the slot-reuse audit trail
        self.admissions: List[tuple] = []

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_active(self) -> int:
        return len(self.active)

    def admit(self, request: Any, step: int = 0) -> Optional[int]:
        """Assign a free slot to ``request``; None when the pool is full."""
        if not self._free:
            return None
        slot = self._free.pop()
        self.active[slot] = request
        self.admissions.append((step, slot, request))
        return slot

    def release(self, slot: int) -> Any:
        """Free a slot; returns the request that held it."""
        if slot not in self.active:
            raise KeyError(f"slot {slot} is not active")
        req = self.active.pop(slot)
        self._free.append(slot)
        return req


class PrefillBudget:
    """Per-boundary prefill-token ledger for the packed-prefill pipeline.

    The paged engine coalesces every admissible prompt chunk into one packed
    varlen launch per decode-step boundary; this ledger caps the *real*
    prompt tokens granted per boundary (``tokens_per_step``) so a burst of
    queued prompts cannot starve decoding slots — the knob that bounds
    decode latency under the server scenario.  Pure bookkeeping (testable
    without a model); the engine owns the packed buffer itself.
    """

    def __init__(self, tokens_per_step: int) -> None:
        if tokens_per_step < 1:
            raise ValueError("tokens_per_step must be >= 1")
        self.tokens_per_step = tokens_per_step
        self.steps = 0
        self.requested_total = 0
        self.granted_total = 0
        self.cached_total = 0
        self._remaining = 0
        # (step_index, granted_this_step) samples, one per begin_step window
        self.granted_series: List[tuple] = []

    @property
    def remaining(self) -> int:
        return self._remaining

    def begin_step(self) -> None:
        """Open a fresh per-boundary budget window."""
        self.steps += 1
        self._remaining = self.tokens_per_step
        self.granted_series.append((self.steps - 1, 0))

    def grant(self, tokens: int) -> int:
        """Grant up to ``tokens`` from this boundary's remaining budget."""
        if tokens < 0:
            raise ValueError("cannot request a negative token count")
        self.requested_total += tokens
        g = min(tokens, self._remaining)
        self._remaining -= g
        self.granted_total += g
        if self.granted_series:
            step, sofar = self.granted_series[-1]
            self.granted_series[-1] = (step, sofar + g)
        return g

    def defer(self, tokens: int) -> None:
        """Record demand that could NOT be served this boundary (prompt
        tokens left waiting once the budget/buffer filled) — the starvation
        signal ``stats()`` reports as ``starved_tokens``."""
        if tokens < 0:
            raise ValueError("cannot defer a negative token count")
        self.requested_total += tokens

    def credit(self, tokens: int) -> None:
        """Record prompt tokens served straight from the prefix cache: they
        enter the system but are ZERO-COST to the ledger — never requested,
        never granted, never starving anyone — so a cache-heavy boundary
        keeps its whole budget for the uncached suffixes."""
        if tokens < 0:
            raise ValueError("cannot credit a negative token count")
        self.cached_total += tokens

    def stats(self) -> Dict[str, float]:
        """Scalar summary: how saturated the per-boundary budget ran."""
        cap = self.steps * self.tokens_per_step
        return {
            "steps": float(self.steps),
            "tokens_per_step": float(self.tokens_per_step),
            "granted_tokens": float(self.granted_total),
            "requested_tokens": float(self.requested_total),
            "cached_tokens": float(self.cached_total),
            "budget_utilization": self.granted_total / cap if cap else 0.0,
            "starved_tokens": float(self.requested_total - self.granted_total),
        }


class SpecLedger:
    """Per-request draft accounting for speculative decoding.

    The paged engine's draft/verify/accept loop records, per request, how
    many draft tokens the prompt-lookup drafter proposed and how many the
    verification launch accepted — the acceptance rate is the whole story
    of whether speculation pays (accepted drafts are free tokens; rejected
    ones are wasted verify FLOPs).  Pure bookkeeping, testable without a
    model; the engine owns the draft/verify loop itself.
    """

    def __init__(self) -> None:
        self.proposed: Dict[int, int] = {}   # request_id -> drafts proposed
        self.accepted: Dict[int, int] = {}   # request_id -> drafts accepted
        self.launches = 0                    # verify launches (windows > 1)
        self.fallback_steps = 0              # boundaries with no drafts at all
        self.rollback_pages = 0              # pages freed by rejected suffixes

    def record(self, request_id: int, proposed: int, accepted: int) -> None:
        """Record one request's share of a verify launch."""
        if proposed < 0 or accepted < 0 or accepted > proposed:
            raise ValueError(
                f"invalid draft accounting: proposed={proposed} "
                f"accepted={accepted}"
            )
        self.proposed[request_id] = self.proposed.get(request_id, 0) + proposed
        self.accepted[request_id] = self.accepted.get(request_id, 0) + accepted

    def record_launch(self, speculative: bool) -> None:
        if speculative:
            self.launches += 1
        else:
            self.fallback_steps += 1

    def record_rollback(self, pages: int) -> None:
        """Pages handed back because a rejected draft had opened them."""
        if pages < 0:
            raise ValueError("cannot roll back a negative page count")
        self.rollback_pages += pages

    def of(self, request_id: int) -> tuple:
        """(proposed, accepted) for one request."""
        return (
            self.proposed.get(request_id, 0),
            self.accepted.get(request_id, 0),
        )

    def stats(self) -> Dict[str, float]:
        """Scalar summary of the draft economy over one run."""
        prop = float(sum(self.proposed.values()))
        acc = float(sum(self.accepted.values()))
        return {
            "spec_launches": float(self.launches),
            "fallback_steps": float(self.fallback_steps),
            "draft_proposed": prop,
            "draft_accepted": acc,
            "acceptance_rate": acc / prop if prop else 0.0,
            "rollback_pages": float(self.rollback_pages),
        }


class PagedSlotPool(SlotPool):
    """Slot pool whose admission is keyed on *free KV pages*, not free slots.

    A request is admitted only when a slot AND all the pages its prompt
    needs are available; releasing a slot returns its pages to the pool.
    The pool publishes ``pages:occupancy`` events (used/free/active) to the
    tracer so page pressure shows up in the analysis workflow next to the
    scheduler's queue-depth series.
    """

    def __init__(self, num_slots: int, pool, tracer=None,
                 clock: Callable[[], float] = time.perf_counter) -> None:
        super().__init__(num_slots)
        self.pool = pool
        self.tracer = tracer
        self.clock = clock
        self.preemptions = 0
        self.pages_in_use_series: List[tuple] = []  # (step, pages_in_use)

    def can_admit(self, npages: int) -> bool:
        return bool(self._free) and self.pool.num_free >= npages

    def admit_paged(self, request: Any, npages: int, step: int = 0):
        """Admit ``request`` with ``npages`` prompt pages; returns
        ``(slot, pages)`` or ``None`` when either resource is exhausted."""
        if not self.can_admit(npages):
            return None
        pages = self.pool.alloc(npages)
        if pages is None:  # pragma: no cover - guarded by can_admit
            return None
        slot = self.admit(request, step=step)
        return slot, pages

    def grow(self, n: int = 1):
        """Allocate ``n`` more pages for a decoding slot (page-boundary
        crossing); None signals the caller to preempt."""
        return self.pool.alloc(n)

    def release_paged(self, slot: int, pages: List[int],
                      preempted: bool = False) -> Any:
        """Free a slot and return its pages to the pool."""
        req = self.release(slot)
        if pages:
            self.pool.free(pages)
        if preempted:
            self.preemptions += 1
        return req

    def record_occupancy(self, step: int) -> None:
        """Sample page occupancy at a decode-step boundary."""
        self.pages_in_use_series.append((step, self.pool.num_in_use))
        if self.tracer is not None:
            now = self.clock()
            self.tracer.event(
                "pages:occupancy",
                now,
                now,
                step=step,
                pages_in_use=self.pool.num_in_use,
                pages_free=self.pool.num_free,
                # allocatable pages (reserved scratch excluded), so
                # pages_in_use / num_pages reaches 1.0 at saturation
                num_pages=self.pool.capacity,
                active_slots=self.num_active,
            )
