# Launch layer: production mesh, dry-run, training/serving drivers.
