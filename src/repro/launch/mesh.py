"""Production mesh definition.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. The single-pod mesh is 16×16 = 256 chips
(data × model); the multi-pod mesh adds a leading pod axis (2 pods = 512
chips). Batch-like dimensions shard over ("pod","data"); tensor-parallel
dimensions over "model" (intra-pod ICI); only data-parallel gradient
reductions cross the pod boundary (DCI) — the standard hierarchy.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh():
    """Single-device mesh for CPU smoke paths (axis sizes 1)."""
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )


def mesh_name(mesh) -> str:
    return "x".join(f"{mesh.shape[a]}" for a in mesh.axis_names)
