"""Production mesh definition.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. The single-pod mesh is 16×16 = 256 chips
(data × model); the multi-pod mesh adds a leading pod axis (2 pods = 512
chips). Batch-like dimensions shard over ("pod","data"); tensor-parallel
dimensions over "model" (intra-pod ICI); only data-parallel gradient
reductions cross the pod boundary (DCI) — the standard hierarchy.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # axis_types only exists on newer jax; plain Auto axes are the default
    # everywhere, so drop the kwarg when the installed version lacks it.
    if hasattr(jax.sharding, "AxisType"):
        try:
            return jax.make_mesh(
                shape, axes,
                axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
            )
        except TypeError:
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(tp: int | None = None):
    """Host (CPU) serving mesh: ``(data=1, model=tp)``.

    ``tp`` > 1 needs forced host devices — run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before jax
    initializes its backend) so ``jax.devices()`` exposes enough CPU
    "chips" to fill the model axis.
    """
    tp = 1 if tp is None else int(tp)
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    n = jax.device_count()
    if tp > n:
        raise ValueError(
            f"tp={tp} needs {tp} devices but only {n} visible; set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={tp} "
            f"before the process starts"
        )
    return _make_mesh((1, tp), ("data", "model"))


def mesh_name(mesh) -> str:
    return "x".join(f"{mesh.shape[a]}" for a in mesh.axis_names)
