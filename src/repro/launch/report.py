"""Generate EXPERIMENTS.md §Dry-run + §Roofline tables from results JSON.

    PYTHONPATH=src python -m repro.launch.report --results results/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Any, Dict, List

from ..configs import SHAPES, list_archs

GB = 2 ** 30


def load_cells(results_dir: str) -> List[Dict[str, Any]]:
    cells = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def _fmt_bytes(b) -> str:
    return f"{b / GB:.2f}"


def dryrun_table(cells: List[Dict[str, Any]], mesh: str) -> str:
    rows = [
        "| arch | shape | status | peak GiB/dev | TPU-est GiB | HLO GFLOPs/dev | HBM GB/dev | coll GB/dev | collectives |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    order = {a: i for i, a in enumerate(list_archs())}
    sorted_cells = sorted(
        (c for c in cells if c["mesh"] == mesh or (c["status"] == "skip" and c.get("mesh") == mesh)),
        key=lambda c: (order.get(c["arch"], 99), list(SHAPES).index(c["shape"])),
    )
    for c in sorted_cells:
        if c["status"] == "skip":
            rows.append(f"| {c['arch']} | {c['shape']} | SKIP (full-attn @500k) | – | – | – | – | – | – |")
            continue
        if c["status"] != "ok":
            rows.append(f"| {c['arch']} | {c['shape']} | ERROR | – | – | – | – | – | – |")
            continue
        r = c["roofline"]
        m = c["memory"]
        est = m.get("tpu_estimate_bytes")
        colls = ", ".join(
            f"{k}×{v}" for k, v in sorted(r["collective_count"].items())
        ) or "none"
        rows.append(
            f"| {c['arch']} | {c['shape']} | ok | {_fmt_bytes(m['peak_per_device_bytes'])} "
            f"| {_fmt_bytes(est) if est else '–'} "
            f"| {r['flops_per_device'] / 1e9:.1f} | {r['memory_bytes_per_device'] / 1e9:.1f} "
            f"| {r['collective_bytes_per_device'] / 1e9:.2f} | {colls} |"
        )
    return "\n".join(rows)


def roofline_table(cells: List[Dict[str, Any]]) -> str:
    rows = [
        "| arch | shape | compute s | memory s | collective s | dominant | MODEL_FLOPS | useful ratio | roofline frac | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    order = {a: i for i, a in enumerate(list_archs())}
    for c in sorted(
        (c for c in cells if c["mesh"] == "16x16"),
        key=lambda c: (order.get(c["arch"], 99), list(SHAPES).index(c["shape"])),
    ):
        if c["status"] == "skip":
            rows.append(f"| {c['arch']} | {c['shape']} | – | – | – | SKIP | – | – | – | full-attention arch at 500k decode |")
            continue
        if c["status"] != "ok":
            continue
        r = c["roofline"]
        hint = _bottleneck_hint(c)
        rows.append(
            f"| {c['arch']} | {c['shape']} | {r['compute_term_s']:.4f} | {r['memory_term_s']:.4f} "
            f"| {r['collective_term_s']:.4f} | **{r['dominant']}** | {r['model_flops']:.3g} "
            f"| {r['model_flops_ratio']:.3f} | {r['roofline_fraction']:.4f} | {hint} |"
        )
    return "\n".join(rows)


def _bottleneck_hint(c: Dict[str, Any]) -> str:
    r = c["roofline"]
    dom = r["dominant"]
    kind = c.get("kind", "")
    if dom == "collective":
        big = max(r["by_collective"], key=r["by_collective"].get) if r["by_collective"] else "?"
        return f"cut {big} volume (sharding/overlap); biggest contributor {big}"
    if dom == "memory":
        if kind == "decode":
            return "decode is KV-cache-bandwidth bound; shrink cache dtype/window or raise batch"
        return "fuse attention HBM traffic into the Pallas kernel (q/acc stay in VMEM); trim fp32 remat copies"
    return "increase per-chip matmul utilization (larger microbatch / less remat recompute)"


def perf_section(results_dir: str) -> str:
    path = os.path.join(results_dir, "..", "perf_log.json")
    if not os.path.exists(path):
        return "_Perf iteration log pending (see §Perf below)._"
    with open(path) as f:
        log = json.load(f)
    out = []
    for entry in log:
        out.append(
            f"**{entry['cell']}** — {entry['hypothesis']}\n\n"
            f"- change: {entry['change']}\n"
            f"- before: {entry['before']}\n"
            f"- after: {entry['after']}\n"
            f"- verdict: {entry['verdict']}\n"
        )
    return "\n".join(out)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun")
    ap.add_argument("--out", default="")
    args = ap.parse_args(argv)
    cells = load_cells(args.results)
    parts = [
        "## §Dry-run — single-pod mesh 16x16 (256 chips)",
        "",
        dryrun_table(cells, "16x16"),
        "",
        "## §Dry-run — multi-pod mesh 2x16x16 (512 chips)",
        "",
        dryrun_table(cells, "2x16x16"),
        "",
        "## §Roofline — per (arch × shape), single-pod",
        "",
        roofline_table(cells),
    ]
    text = "\n".join(parts)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    else:
        print(text)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
