"""Subprocess agent: one MLModelScope agent per process over a JSON-line
socket protocol (the offline stand-in for the paper's gRPC agent service).

Protocol (newline-delimited JSON over TCP):

    -> {"method": "Open",    "params": {...OpenRequest-ish...}}
    <- {"ok": true, "result": {...}}
    -> {"method": "Predict", "params": {"request": {...EvaluationRequest...}}}
    <- {"ok": true, "result": {...metrics...}}
    -> {"method": "Close"}

Semantically the same 3-call interface as Listing 3/4; heartbeats renew the
registry lease file so the server can detect dead agents.

    PYTHONPATH=src python -m repro.launch.agent_main --port 7071 --backend ref
"""
from __future__ import annotations

import argparse
import json
import socketserver
import threading
import time

from ..core.agent import Agent, EvaluationRequest
from ..core.evaldb import EvalDB
from ..core.platform import builtin_manifests
from ..core.registry import KVStore, Registry
from ..core.tracing import TracingServer


def make_agent(backend: str, registry_file: str = "") -> Agent:
    store = KVStore()
    if registry_file:
        try:
            store.load(registry_file)
        except FileNotFoundError:
            pass
    registry = Registry(store)
    agent = Agent(
        backend=backend,
        registry=registry,
        tracing_server=TracingServer(),
        evaldb=EvalDB(),
    )
    agent.register_models(builtin_manifests(reduced=True))
    if registry_file:
        store.dump(registry_file)

        def heartbeat() -> None:
            while True:
                time.sleep(Registry.AGENT_TTL / 3)
                agent.heartbeat()
                store.dump(registry_file)

        threading.Thread(target=heartbeat, daemon=True).start()
    return agent


class Handler(socketserver.StreamRequestHandler):
    def handle(self) -> None:
        agent: Agent = self.server.agent  # type: ignore[attr-defined]
        for line in self.rfile:
            try:
                msg = json.loads(line)
                method = msg.get("method")
                if method == "Predict":
                    req = EvaluationRequest.from_dict(msg["params"]["request"])
                    result = agent.evaluate(req)
                    resp = {"ok": True, "result": result}
                elif method == "Heartbeat":
                    resp = {"ok": agent.heartbeat()}
                elif method == "Info":
                    resp = {"ok": True, "result": {
                        "agent_id": agent.agent_id,
                        "backend": agent.backend,
                        "models": sorted(agent.manifests),
                    }}
                elif method == "Close":
                    resp = {"ok": True}
                    self.wfile.write((json.dumps(resp) + "\n").encode())
                    return
                else:
                    resp = {"ok": False, "error": f"unknown method {method!r}"}
            except Exception as e:  # noqa: BLE001
                resp = {"ok": False, "error": str(e)}
            self.wfile.write((json.dumps(resp) + "\n").encode())
            self.wfile.flush()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=7071)
    ap.add_argument("--backend", default="ref")
    ap.add_argument("--registry-file", default="")
    args = ap.parse_args(argv)
    agent = make_agent(args.backend, args.registry_file)
    with socketserver.ThreadingTCPServer((args.host, args.port), Handler) as srv:
        srv.agent = agent  # type: ignore[attr-defined]
        print(f"[agent] {agent.agent_id} serving on {args.host}:{args.port}")
        srv.serve_forever()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
