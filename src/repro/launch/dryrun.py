import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ the required first two lines: set BEFORE any jax-importing import below.
"""Multi-pod dry-run: AOT lower + compile every (arch × shape × mesh) cell.

The FIRST TWO LINES above run before any other import (jax locks the device
count at first init). Do not import this module from code that needs real
device topology.

For every cell this lowers the right step function (train_step for
``train_*`` shapes, prefill/decode for serving shapes) with
ShapeDtypeStruct inputs (no allocation), compiles for the production mesh,
and records ``memory_analysis()`` / ``cost_analysis()`` / the parsed HLO
roofline terms to a JSON file — the §Dry-run + §Roofline data source.

Usage::

    python -m repro.launch.dryrun --arch glm4-9b --shape train_4k --mesh pod
    python -m repro.launch.dryrun --all --out results/dryrun   # full sweep
                                                               # (subprocess per cell)
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from ..configs import ARCH_IDS, SHAPES, ShapeSpec, get_config, list_archs, shape_applicable
from ..models.lm import build_model
from ..models.params import param_specs as specs_of, tree_map_defs, P
from ..roofline.model import TPU_V5E, model_flops_for, roofline_from_compiled
from ..sharding.specs import (
    ShardingRules,
    batch_axes,
    default_rules,
    param_pspecs,
    set_activation_rules,
)
from ..train.optimizer import OptimizerConfig, opt_state_defs
from ..train.step import make_train_step
from .mesh import make_production_mesh, mesh_name

SERVE_DTYPE = "bfloat16"

# Per-arch policies: dtypes/microbatching chosen so every cell fits 16 GB/chip
# (napkin math in EXPERIMENTS.md §Dry-run). fsdp shards weight embed-dims over
# the data axes (ZeRO-3-style); optimizer states inherit it (ZeRO-1).
DEFAULT_TRAIN = dict(
    param_dtype="float32", microbatches=16, m_dtype="float32",
    v_dtype="float32", accum_dtype="float32", fsdp=True, remat=True,
)
TRAIN_POLICY: Dict[str, Dict[str, Any]] = {
    "llama4-maverick-400b-a17b": dict(
        param_dtype="bfloat16", microbatches=16, m_dtype="bfloat16",
        v_dtype="bfloat16", accum_dtype="bfloat16", fsdp=True, remat=True,
    ),
    "deepseek-67b": dict(DEFAULT_TRAIN, microbatches=32),
    "granite-20b": dict(DEFAULT_TRAIN, microbatches=32),
    "chameleon-34b": dict(DEFAULT_TRAIN, microbatches=32),
    "mamba2-130m": dict(DEFAULT_TRAIN, microbatches=8, fsdp=False),
    # whisper: 20 heads defeat 16-way TP, so weights replicate across the
    # model axis unless FSDP shards their embed dims over data
    "whisper-large-v3": dict(DEFAULT_TRAIN, microbatches=8, fsdp=True),
    "zamba2-2.7b": dict(DEFAULT_TRAIN, microbatches=16, fsdp=False),
}
SERVE_POLICY: Dict[str, Dict[str, Any]] = {
    # 400B weights exceed 16-way TP capacity -> FSDP-style sharding at serve
    "llama4-maverick-400b-a17b": dict(fsdp=True),
    # 67B bf16 = 8.4 GB/chip at TP-16; + a 6 GB 32k cache leaves no headroom
    "deepseek-67b": dict(fsdp=True),
}


def rules_for(cfg, mesh, fsdp: bool, train: bool = False, opts=None) -> ShardingRules:
    rules = default_rules(mesh, fsdp=fsdp)
    if opts:
        rules.opts.update(opts)
    model_size = mesh.shape["model"]
    if cfg.num_kv_heads and cfg.num_kv_heads % model_size != 0:
        # KV heads can't split the model axis -> shard the cache's seq dim
        rules.rules["kv_seq"] = "model"
    if train:
        # Megatron-style sequence parallelism: the residual stream (and thus
        # the remat-saved per-layer activations) shards its seq dim over
        # "model"; GSPMD inserts the all-gather/reduce-scatter pairs around
        # attention. Cuts saved-activation memory by the model-axis size.
        rules.rules["seq"] = "model"
    return rules


def clamp_microbatches(mb: int, global_batch: int, rules: ShardingRules) -> int:
    """Largest mb <= requested s.t. each microbatch still shards the batch
    axes evenly (a microbatch smaller than the batch sharding under-shards)."""
    shards = rules.axis_size(rules.mesh_axes_for("batch", global_batch))
    mb = max(1, min(mb, global_batch // max(shards, 1)))
    while mb > 1 and (global_batch % mb or (global_batch // mb) % shards):
        mb -= 1
    return mb


def named(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )


def batch_specs(cfg, shape: ShapeSpec, rules: ShardingRules):
    """ShapeDtypeStructs + PartitionSpecs for the model inputs of one cell."""
    mesh = rules.mesh
    b_ax = rules.mesh_axes_for("batch", shape.global_batch)
    gb, seq = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.family == "encdec":
            dec = seq - cfg.encoder_seq
            spec = {
                "frames": jax.ShapeDtypeStruct((gb, cfg.encoder_seq, cfg.d_model), jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((gb, dec), jnp.int32),
            }
            pspec = {
                "frames": PartitionSpec(b_ax, None, None),
                "tokens": PartitionSpec(b_ax, None),
            }
        else:
            spec = {"tokens": jax.ShapeDtypeStruct((gb, seq), jnp.int32)}
            pspec = {"tokens": PartitionSpec(b_ax, None)}
        return spec, pspec
    if shape.kind == "prefill":
        if cfg.family == "encdec":
            dec = seq - cfg.encoder_seq
            spec = {
                "frames": jax.ShapeDtypeStruct((gb, cfg.encoder_seq, cfg.d_model), jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((gb, dec), jnp.int32),
            }
            pspec = {
                "frames": PartitionSpec(b_ax, None, None),
                "tokens": PartitionSpec(b_ax, None),
            }
        else:
            spec = {"tokens": jax.ShapeDtypeStruct((gb, seq), jnp.int32)}
            pspec = {"tokens": PartitionSpec(b_ax, None)}
        return spec, pspec
    # decode: one token per sequence
    spec = {"tokens": jax.ShapeDtypeStruct((gb,), jnp.int32)}
    pspec = {"tokens": PartitionSpec(b_ax)}
    return spec, pspec


def input_specs(arch: str, shape_name: str = "train_4k", multi_pod: bool = False):
    """Public helper: ShapeDtypeStruct stand-ins for every model input."""
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(cfg, mesh, fsdp=False)
    spec, _ = batch_specs(cfg, SHAPES[shape_name], rules)
    return spec


def lower_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    overrides: Optional[Dict[str, Any]] = None,
):
    """Lower + compile one cell; returns (compiled, meta)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        return None, {"skipped": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 1
    for a in mesh.axis_names:
        chips *= mesh.shape[a]
    overrides = overrides or {}

    if shape.kind == "train":
        policy = dict(TRAIN_POLICY.get(arch, DEFAULT_TRAIN))
        policy.update(overrides)
        rules = rules_for(cfg, mesh, fsdp=policy["fsdp"], train=True,
                          opts=overrides.get("opts"))
        policy["microbatches"] = clamp_microbatches(
            int(policy["microbatches"]), shape.global_batch, rules
        )
        compute = overrides["compute_dtype"] if "compute_dtype" in overrides else "bfloat16"
        model = build_model(cfg, backend=overrides.get("backend", "flash"),
                            compute_dtype=compute)
        defs = model.param_defs()
        p_specs = specs_of(defs, dtype=policy["param_dtype"])
        p_pspecs = param_pspecs(defs, rules)
        opt_cfg = OptimizerConfig(
            m_dtype=policy["m_dtype"], v_dtype=policy["v_dtype"]
        )
        o_defs = opt_state_defs(defs, opt_cfg)
        o_specs = specs_of(o_defs)
        o_pspecs = param_pspecs(o_defs, rules)
        b_specs, b_pspecs = batch_specs(cfg, shape, rules)
        opts = overrides.get("opts") or {}
        step = make_train_step(
            model, opt_cfg,
            microbatches=policy["microbatches"],
            remat=policy["remat"],
            accum_dtype=policy["accum_dtype"],
            grad_shardings=named(mesh, p_pspecs) if opts.get("rs_grads") else None,
            cast_params_once=bool(opts.get("cast_params_once")),
        )
        with set_activation_rules(rules):
            jitted = jax.jit(
                step,
                in_shardings=(
                    named(mesh, p_pspecs), named(mesh, o_pspecs), named(mesh, b_pspecs)
                ),
                # matching out shardings -> donated params/opt alias in place
                out_shardings=(named(mesh, p_pspecs), named(mesh, o_pspecs), None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(p_specs, o_specs, b_specs)
            compiled = lowered.compile()
        meta = {"kind": "train", "policy": policy, "chips": chips,
                "mesh": mesh_name(mesh)}
        return compiled, meta

    # serving shapes
    policy = dict(SERVE_POLICY.get(arch, {"fsdp": False}))
    policy.update(overrides)
    rules = rules_for(cfg, mesh, fsdp=policy.get("fsdp", False),
                      opts=overrides.get("opts"))
    compute = overrides["compute_dtype"] if "compute_dtype" in overrides else "bfloat16"
    model = build_model(cfg, backend=overrides.get("backend", "flash"),
                        compute_dtype=compute)
    defs = model.param_defs()
    p_specs = specs_of(defs, dtype=overrides.get("param_dtype", SERVE_DTYPE))
    p_pspecs = param_pspecs(defs, rules)
    cache_dtype = overrides.get("cache_dtype", SERVE_DTYPE)
    cache_defs = model.cache_defs(shape.global_batch, shape.seq_len, dtype=cache_dtype)
    c_specs = specs_of(cache_defs)
    c_pspecs = param_pspecs(cache_defs, rules)
    b_specs, b_pspecs = batch_specs(cfg, shape, rules)

    if shape.kind == "prefill":
        fn = lambda p, b, c: model.prefill(p, b, c)
    else:
        fn = lambda p, t, c: model.decode(p, t["tokens"], c)
    args = (p_specs, b_specs, c_specs)
    shardings = (named(mesh, p_pspecs), named(mesh, b_pspecs), named(mesh, c_pspecs))
    # matching output shardings let XLA alias the donated cache in place
    out_shardings = (None, named(mesh, c_pspecs))
    with set_activation_rules(rules):
        jitted = jax.jit(
            fn, in_shardings=shardings, out_shardings=out_shardings,
            donate_argnums=(2,),
        )
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    meta = {"kind": shape.kind, "policy": policy, "chips": chips,
            "mesh": mesh_name(mesh)}
    return compiled, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: Optional[Dict[str, Any]] = None,
             note: str = "") -> Dict[str, Any]:
    t0 = time.time()
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    compiled, meta = lower_cell(arch, shape_name, multi_pod, overrides)
    if compiled is None:
        return {
            "arch": arch, "shape": shape_name,
            "mesh": mesh_name(make_production_mesh(multi_pod=multi_pod)),
            "status": "skip", "reason": meta["skipped"],
        }
    mem = compiled.memory_analysis()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mf = model_flops_for(
        cfg.param_count(active_only=True), tokens,
        "train" if shape.kind == "train" else "serve",
    )
    report = roofline_from_compiled(
        compiled,
        arch=arch, shape=shape_name, mesh_name=meta["mesh"], chips=meta["chips"],
        model_flops=mf, note=note,
    )
    peak = int(
        mem.argument_size_in_bytes + mem.output_size_in_bytes
        + mem.temp_size_in_bytes - mem.alias_size_in_bytes
    )
    out = {
        "arch": arch, "shape": shape_name, "mesh": meta["mesh"],
        "status": "ok", "kind": meta["kind"], "policy": {
            k: str(v) for k, v in meta["policy"].items()
        },
        "compile_s": time.time() - t0,
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "peak_per_device_bytes": peak,
        },
        "roofline": report.to_dict(),
    }
    # CPU-backend artifact correction: the CPU compiler normalizes bf16 dots
    # to f32, materializing f32 copies of bf16 tensors (caches, saved
    # activations) that do NOT exist on TPU. For over-budget cells, re-lower
    # everything in f32 (artifact-free: single dtype) — half its temp is the
    # TPU-bf16 estimate; arguments (params/opt/cache) are taken at their real
    # policy dtypes from the raw run.
    if peak > 16 * 2**30:
        try:
            f32_over = dict(overrides or {})
            f32_over.update(param_dtype="float32", cache_dtype="float32",
                            accum_dtype="float32", compute_dtype=None)
            compiled2, _ = lower_cell(arch, shape_name, multi_pod, f32_over)
            m2 = compiled2.memory_analysis()
            est = int(
                mem.argument_size_in_bytes + mem.output_size_in_bytes
                - mem.alias_size_in_bytes + m2.temp_size_in_bytes / 2
            )
            out["memory"]["tpu_estimate_bytes"] = est
        except Exception as e:  # noqa: BLE001 - estimate is best-effort
            out["memory"]["tpu_estimate_error"] = str(e)
    return out


# ---------------------------------------------------------------------------
# CLI driver
# ---------------------------------------------------------------------------
def cell_path(out_dir: str, arch: str, shape: str, mesh_kind: str) -> str:
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="")
    ap.add_argument("--shape", default="")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--all", action="store_true", help="sweep all cells via subprocesses")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--note", default="")
    ap.add_argument("--override", default="", help="JSON policy overrides")
    args = ap.parse_args(argv)
    os.makedirs(args.out, exist_ok=True)

    if args.all:
        failures = []
        for arch in list_archs():
            for shape in SHAPES:
                for mesh_kind in ("pod", "multipod"):
                    path = cell_path(args.out, arch, shape, mesh_kind)
                    if os.path.exists(path) and not args.force:
                        continue
                    cmd = [
                        sys.executable, "-m", "repro.launch.dryrun",
                        "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                        "--out", args.out,
                    ]
                    if args.force:
                        cmd.append("--force")
                    print(f"[dryrun] {arch} × {shape} × {mesh_kind} ...", flush=True)
                    rc = subprocess.run(cmd).returncode
                    if rc != 0:
                        failures.append((arch, shape, mesh_kind))
                        print(f"[dryrun]   FAILED rc={rc}", flush=True)
        print(f"[dryrun] sweep done; {len(failures)} failures: {failures}")
        return 1 if failures else 0

    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    overrides = json.loads(args.override) if args.override else None
    path = cell_path(args.out, args.arch, args.shape, args.mesh)
    if os.path.exists(path) and not args.force:
        print(f"[dryrun] cached: {path}")
        return 0
    try:
        result = run_cell(
            args.arch, args.shape, args.mesh == "multipod",
            overrides=overrides, note=args.note,
        )
    except Exception:
        result = {
            "arch": args.arch, "shape": args.shape, "mesh": args.mesh,
            "status": "error", "traceback": traceback.format_exc(),
        }
        with open(path, "w") as f:
            json.dump(result, f, indent=2)
        print(result["traceback"], file=sys.stderr)
        return 1
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
    if result["status"] == "ok":
        r = result["roofline"]
        print(
            f"[dryrun] {args.arch} × {args.shape} × {result['mesh']}: "
            f"peak/dev={result['memory']['peak_per_device_bytes']/2**30:.2f} GiB "
            f"terms(s): compute={r['compute_term_s']:.4f} "
            f"memory={r['memory_term_s']:.4f} collective={r['collective_term_s']:.4f} "
            f"dominant={r['dominant']} frac={r['roofline_fraction']:.3f}"
        )
    else:
        print(f"[dryrun] {args.arch} × {args.shape}: {result['status']} ({result.get('reason','')})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
