"""End-to-end training driver.

Runs real optimization steps on the host devices (CPU here; the same code
jits onto a TPU mesh — shardings come from the same rules as the dry-run).
Demonstrates the full fault-tolerant loop: RecordIO/synthetic data with
cursor resume, atomic checkpoints, checkpoint-restart, loss logging into
the platform's evaluation database.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --reduced \
        --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt --ckpt-every 20
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..core.evaldb import EvalDB, EvaluationRecord
from ..models import build_model
from ..train.checkpoint import CheckpointManager
from ..train.data import SyntheticTokenDataset, make_loader
from ..train.optimizer import OptimizerConfig, init_opt_state
from ..train.step import make_train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--backend", default="flash")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--evaldb", default="")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    model = build_model(cfg, backend=args.backend)
    opt_cfg = OptimizerConfig(
        lr=args.lr, warmup_steps=max(args.steps // 20, 1), total_steps=args.steps
    )
    params = model.init(jax.random.PRNGKey(0))
    opt_state = init_opt_state(model.param_defs(), opt_cfg)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n_params:,} params")

    start_step, cursor = 0, 0
    mgr: Optional[CheckpointManager] = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=2)
        if args.resume and mgr.latest_step() is not None:
            params, opt_state, meta = mgr.restore(
                params_template=params, opt_template=opt_state
            )
            start_step = int(meta["step"])
            cursor = int(meta.get("data_cursor", 0))
            print(f"[train] resumed from step {start_step} (cursor {cursor})")

    step_fn = jax.jit(
        make_train_step(model, opt_cfg, microbatches=args.microbatches, remat=True)
    )
    data = SyntheticTokenDataset(cfg.vocab_size, args.seq, seed=0)
    loader = make_loader(data, args.batch, skip=cursor)
    db = EvalDB(args.evaldb) if args.evaldb else None

    losses = []
    t0 = time.perf_counter()
    for step in range(start_step, args.steps):
        cursor, batch = next(loader)
        jbatch = {"tokens": jnp.asarray(batch["tokens"])}
        if cfg.family == "encdec":
            jbatch["frames"] = jnp.zeros(
                (args.batch, cfg.encoder_seq, cfg.d_model), jnp.float32
            )
        params, opt_state, metrics = step_fn(params, opt_state, jbatch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if (step + 1) % args.log_every == 0:
            dt = time.perf_counter() - t0
            tps = args.batch * args.seq * args.log_every / dt
            print(
                f"[train] step {step+1:5d} loss={loss:.4f} "
                f"lr={float(metrics['lr']):.2e} gnorm={float(metrics['grad_norm']):.2f} "
                f"tok/s={tps:,.0f}"
            )
            t0 = time.perf_counter()
        if mgr is not None and (step + 1) % args.ckpt_every == 0:
            path = mgr.save(step + 1, params, opt_state, extra={"data_cursor": cursor})
            print(f"[train] checkpoint -> {path}")
    if db is not None:
        db.insert(
            EvaluationRecord(
                model=cfg.name, model_version="1.0.0", backend=args.backend,
                backend_version="1.0.0", system="local", scenario="train",
                batch_size=args.batch, trace_level="NONE", agent_id="train-driver",
                metrics={"final_loss": losses[-1], "first_loss": losses[0]},
            )
        )
    print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return 0 if losses[-1] < losses[0] else 1


if __name__ == "__main__":
    raise SystemExit(main())
