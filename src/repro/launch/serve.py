"""End-to-end serving driver (the e2e application for this paper's kind).

Serves a model with batched requests through the ServingEngine under a
platform benchmarking scenario: requests arrive (Poisson or batched), get
grouped into engine batches, prefilled and decoded; latency/throughput
metrics flow into the evaluation database.

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --reduced \
        --requests 16 --rate-hz 20 --max-new-tokens 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..core.analysis import latency_summary
from ..core.evaldb import EvalDB, EvaluationRecord
from ..core.workload import PoissonLoad
from ..models import build_model
from ..serve.engine import ServingEngine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--backend", default="flash")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate-hz", type=float, default=20.0)
    ap.add_argument("--engine-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--evaldb", default="")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    model = build_model(cfg, backend=args.backend)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(
        model, params, max_batch=args.engine_batch, max_seq=args.max_seq
    )
    rng = np.random.default_rng(0)

    # generate the request load, group into engine batches as they arrive
    load = list(PoissonLoad(args.requests, args.rate_hz, seed=0).requests())
    latencies, generated = [], 0
    t_start = time.perf_counter()
    pending = []
    for req in load:
        now = time.perf_counter() - t_start
        if req.arrival_s > now:
            time.sleep(req.arrival_s - now)
        pending.append(
            (req, rng.integers(0, cfg.vocab_size, (args.prompt_len,)).astype(np.int32))
        )
        if len(pending) == args.engine_batch:
            batch_reqs, prompts = zip(*pending)
            pending = []
            extra = None
            if cfg.family == "encdec":
                extra = {"frames": np.zeros(
                    (len(prompts), cfg.encoder_seq, cfg.d_model), np.float32)}
            t0 = time.perf_counter()
            res = engine.generate(list(prompts), args.max_new_tokens, extra_inputs=extra)
            t1 = time.perf_counter()
            done = time.perf_counter() - t_start
            generated += res.tokens.size
            for r in batch_reqs:
                latencies.append(done - r.arrival_s)   # queueing + service
            print(
                f"[serve] batch of {len(prompts)}: prefill {res.prefill_s*1e3:.1f} ms, "
                f"decode {res.decode_s*1e3:.1f} ms ({res.tokens_per_s:,.1f} tok/s)"
            )
    wall = time.perf_counter() - t_start
    summary = latency_summary(latencies) if latencies else {}
    summary["tokens_per_s"] = generated / wall
    print(f"[serve] {len(latencies)} requests, {generated} tokens in {wall:.2f}s")
    for k, v in summary.items():
        print(f"[serve]   {k:20s} {v:.2f}")
    if args.evaldb:
        EvalDB(args.evaldb).insert(
            EvaluationRecord(
                model=cfg.name, model_version="1.0.0", backend=args.backend,
                backend_version="1.0.0", system="local", scenario="serve-poisson",
                batch_size=args.engine_batch, trace_level="NONE",
                agent_id="serve-driver", metrics=summary,
            )
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
