"""End-to-end serving driver (the e2e application for this paper's kind).

Serves a model under a Poisson request load through the platform's request
scheduler.  Three executor modes (``--engine``):

* ``static``      — the threaded RequestScheduler coalesces concurrent
                    requests into micro-batches (up to ``--engine-batch``
                    within ``--batch-timeout-ms``) executed by the static
                    prefill/decode engine.
* ``continuous``  — slot-based continuous batching: prompts are admitted
                    into free dense KV slots at decode-step boundaries;
                    reports per-request TTFT and tokens/sec.
* ``paged``       — paged KV cache: a global ``--page-size``-token page pool
                    (``--num-pages``) with per-request page tables, prefill
                    interleaved at decode-step boundaries (``--prefill-mode
                    packed`` coalesces every admissible chunk into one
                    token-packed varlen launch of ``--prefill-budget``
                    tokens; ``chunked`` is the legacy one-chunk-per-slot
                    path), admission keyed on free pages, and youngest-
                    first preemption when the pool is exhausted.
                    ``--spec-k k`` adds self-speculative decoding
                    (prompt-lookup drafting + one paged multi-token
                    verification launch per boundary; greedy tokens stay
                    bit-identical).  Emits ``pages:occupancy`` +
                    ``prefill:packed`` + ``spec:verify`` events and
                    page-occupancy / prefill-saturation / acceptance-rate
                    report sections plus per-request ITL p50/p99.

Latency/throughput metrics and the scheduler's queue/occupancy series flow
into the evaluation database.

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --reduced \
        --requests 16 --rate-hz 20 --max-new-tokens 8 --engine paged
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config
from ..core.analysis import (
    fleet_section,
    latency_summary,
    recovery_section,
    page_occupancy_section,
    prefill_saturation_section,
    prefix_cache_section,
    slo_section,
    spec_decode_section,
    tp_section,
)
from ..core.evaldb import EvalDB, EvaluationRecord
from ..core.manifest import EngineKnobs
from ..core.tracing import Tracer, TracingServer
from ..core.workload import (
    MultiTenantLoad,
    PoissonLoad,
    SharedPrefixLoad,
    shared_prefix_prompts,
)
from ..models import build_model
from ..serve.engine import ServeRequest, ServingEngine
from ..serve.scheduler import (
    PRIORITY_TIERS,
    RequestScheduler,
    SchedulerConfig,
    TenantSpec,
)


def _parse_tenants(s: str):
    """Parse ``--tenants``: semicolon-separated tenants, each
    ``name[,key=value...]`` with keys ``prio`` (tier index or name),
    ``weight``, ``rate`` (bucket refill tokens/s), ``burst`` (bucket
    depth), ``hz`` (arrival rate), ``slo`` (ms), ``prompt``/``gen``
    (token shape).  Example::

        --tenants "prem,prio=2,weight=2,hz=20;best,prio=0,rate=400,burst=120"
    """
    out = []
    for chunk in s.split(";"):
        chunk = chunk.strip()
        if not chunk:
            continue
        parts = [p.strip() for p in chunk.split(",")]
        t = {"name": parts[0]}
        for kv in parts[1:]:
            k, _, v = kv.partition("=")
            k = k.strip()
            v = v.strip()
            field, conv = _TENANT_KEYS.get(k, (None, None))
            if field is None:
                raise ValueError(f"unknown tenant key {k!r} in {chunk!r}")
            try:
                t[field] = conv(v)
            except ValueError:
                raise ValueError(
                    f"bad tenant value {k}={v!r} in {chunk!r}") from None
        out.append(t)
    return out


def _parse_priority(v: str) -> int:
    return PRIORITY_TIERS.index(v) if v in PRIORITY_TIERS else int(v)


_TENANT_KEYS = {
    "prio": ("priority", _parse_priority),
    "priority": ("priority", _parse_priority),
    "weight": ("weight", float),
    "rate": ("rate_tokens_per_s", float),
    "burst": ("burst_tokens", float),
    "hz": ("rate_hz", float),
    "slo": ("slo_ms", float),
    "prompt": ("prompt_len", int),
    "gen": ("gen_tokens", int),
}


def _parse_priority_mix(s: str):
    """Parse ``--priority-mix``: ``tier=frac`` pairs, e.g.
    ``best_effort=0.25,standard=0.5,premium=0.25``."""
    out = {}
    for kv in s.split(","):
        k, _, v = kv.partition("=")
        out[k.strip()] = float(v)
    return out


def _serve_static(engine, cfg, args, load, prompts):
    """Poisson arrivals -> threaded micro-batching scheduler -> engine."""
    extra = None
    if cfg.family == "encdec":
        extra = {
            "frames": np.zeros((args.engine_batch, cfg.encoder_seq, cfg.d_model), np.float32)
        }

    def execute(batch):
        ps = [r.payload for r in batch]
        ex = None
        if extra is not None:
            ex = {"frames": extra["frames"][: len(ps)]}
        res = engine.generate(ps, args.max_new_tokens, extra_inputs=ex)
        print(
            f"[serve] batch of {len(ps)}: prefill {res.prefill_s*1e3:.1f} ms, "
            f"decode {res.decode_s*1e3:.1f} ms ({res.tokens_per_s:,.1f} tok/s)"
        )

    sched = RequestScheduler(
        execute,
        SchedulerConfig(
            max_batch=args.engine_batch, batch_timeout_ms=args.batch_timeout_ms
        ),
    ).start()
    t_start = time.perf_counter()
    futs = []
    for req, prompt in zip(load, prompts):
        now = time.perf_counter() - t_start
        if req.arrival_s > now:
            time.sleep(req.arrival_s - now)
        futs.append(sched.submit(payload=prompt))
    for f in futs:
        f.result()
    sched.stop()
    wall = time.perf_counter() - t_start
    latencies = [f.request.latency_s for f in futs]
    generated = len(futs) * args.max_new_tokens
    summary = latency_summary(latencies) if latencies else {}
    summary.update(
        {
            "tokens_per_s": generated / wall,
            **{f"sched_{k}": v for k, v in sched.stats().items()},
        }
    )
    return summary, generated, wall


def _serve_continuous(engine, cfg, args, load, prompts):
    """Offline continuous batching over the same request set."""
    reqs = [
        ServeRequest(request_id=i, prompt=p, max_new_tokens=args.max_new_tokens)
        for i, p in enumerate(prompts)
    ]
    stats = engine.serve_continuous(reqs, num_slots=args.engine_batch)
    for r in stats.results:
        print(
            f"[serve] req {r.request_id}: slot {r.slot} "
            f"(admitted step {r.admit_step}), ttft {r.ttft_s*1e3:.1f} ms, "
            f"{r.tokens_per_s:,.1f} tok/s"
        )
    latencies = [r.latency_s for r in stats.results]
    summary = latency_summary(latencies) if latencies else {}
    summary.update(
        {
            "tokens_per_s": stats.throughput_tps,
            "ttft_mean_ms": float(
                np.mean([r.ttft_s for r in stats.results]) * 1e3
            ),
            "mean_slot_occupancy": stats.mean_slot_occupancy,
            "decode_steps": stats.steps,
        }
    )
    return summary, stats.total_tokens, stats.wall_s


def _tagged_requests(args, load, prompts):
    """Build engine requests carrying each workload request's tenant tags."""
    reqs = []
    for i, (req, p) in enumerate(zip(load, prompts)):
        tags = getattr(req, "tags", None) or {}
        reqs.append(ServeRequest(
            request_id=i, prompt=p, max_new_tokens=args.max_new_tokens,
            tenant=str(tags.get("tenant", "default")),
            priority=int(tags.get("priority", 1)),
            slo_ms=float(tags.get("slo_ms", 0.0) or args.slo_ms),
        ))
    return reqs


def _serve_paged(engine, cfg, args, load, prompts):
    """Offline paged-KV continuous batching with chunked prefill."""
    reqs = _tagged_requests(args, load, prompts)
    tenant_dicts = _parse_tenants(args.tenants) if args.tenants else []
    server = TracingServer()
    tracer = Tracer("serve-paged", server)
    stats = engine.serve_paged(
        reqs,
        num_slots=args.engine_batch,
        page_size=args.page_size,
        num_pages=args.num_pages or None,
        prefill_chunk=args.prefill_chunk or None,
        overcommit=args.overcommit,
        prefill_mode=args.prefill_mode,
        prefill_budget=args.prefill_budget or None,
        spec_k=args.spec_k,
        spec_ngram=args.spec_ngram,
        prefix_cache=args.prefix_cache == "on",
        deadline_ms=args.deadline_ms,
        tenants=[TenantSpec.from_dict(t) for t in tenant_dicts] or None,
        fairness=args.fairness == "on",
        tracer=tracer,
    )
    for r in stats.results:
        if r.status != "completed":
            print(f"[serve] req {r.request_id}: {r.status} ({r.reason})")
            continue
        print(
            f"[serve] req {r.request_id}: slot {r.slot} "
            f"(admitted step {r.admit_step}), ttft {r.ttft_s*1e3:.1f} ms, "
            f"{r.tokens_per_s:,.1f} tok/s"
        )
    section = page_occupancy_section(server.timeline("serve-paged"))
    if section:
        print("[serve] page occupancy:")
        for line in section.splitlines():
            print(f"[serve]   {line}")
    section = prefill_saturation_section(server.timeline("serve-paged"))
    if section:
        print("[serve] prefill saturation:")
        for line in section.splitlines():
            print(f"[serve]   {line}")
    section = spec_decode_section(server.timeline("serve-paged"))
    if section:
        print("[serve] speculative decoding:")
        for line in section.splitlines():
            print(f"[serve]   {line}")
    section = prefix_cache_section(server.timeline("serve-paged"))
    if section:
        print("[serve] prefix cache:")
        for line in section.splitlines():
            print(f"[serve]   {line}")
    section = tp_section(server.timeline("serve-paged"))
    if section:
        print("[serve] tensor-parallel collectives:")
        for line in section.splitlines():
            print(f"[serve]   {line}")
    section = slo_section(server.timeline("serve-paged"))
    if section:
        print("[serve] multi-tenant SLO:")
        for line in section.splitlines():
            print(f"[serve]   {line}")
    done = [r for r in stats.results if r.status == "completed"]
    latencies = [r.latency_s for r in done]
    summary = latency_summary(latencies) if latencies else {}
    summary.update(
        {
            "tokens_per_s": stats.throughput_tps,
            "ttft_mean_ms": float(
                np.mean([r.ttft_s for r in done]) * 1e3
            ) if done else 0.0,
            "completed": float(stats.completed),
            "rejected": float(stats.rejected),
            "deferred": float(stats.deferred),
            "goodput": stats.goodput,
            "mean_slot_occupancy": stats.mean_slot_occupancy,
            "peak_slot_occupancy": float(stats.peak_slot_occupancy),
            "decode_steps": stats.steps,
            "page_size": float(stats.page_size),
            "num_pages": float(stats.num_pages),
            "mean_pages_in_use": stats.mean_pages_in_use,
            "peak_pages_in_use": float(stats.peak_pages_in_use),
            "preemptions": float(stats.preemptions),
            "prefill_chunks": float(stats.prefill_chunks),
            "prefill_launches": float(stats.prefill_launches),
            "prefill_s": stats.prefill_s,
            "prefill_tokens": float(stats.prefill_tokens),
            "prefill_padded_tokens": float(stats.prefill_padded_tokens),
            "decode_s": stats.decode_s,
            "itl_p50_ms": stats.itl_p50_ms,
            "itl_p99_ms": stats.itl_p99_ms,
            "tp": float(stats.tp),
            "spec_k": float(stats.spec_k),
            "prefix_cache": float(stats.prefix_cache),
            "kv_bytes_per_token": stats.kv_bytes_per_token,
            "prompt_tokens_admitted": float(stats.prompt_tokens_admitted),
            "saved_prefill_tokens": float(stats.saved_prefill_tokens),
            "prefill_tokens_dropped": float(stats.prefill_tokens_dropped),
            "cow_copies": float(stats.cow_copies),
            "cache_evictions": float(stats.cache_evictions),
            **{f"compiles_{k}": float(v) for k, v in stats.compile_stats.items()},
            **{f"budget_{k}": v for k, v in stats.prefill_budget_stats.items()},
            **{f"prefix_{k}": v for k, v in stats.prefix_stats.items()},
            **{k: v for k, v in stats.spec_stats.items()},
        }
    )
    return summary, stats.total_tokens, stats.wall_s


def _serve_fleet(engines, cfg, args, load, prompts):
    """Fault-tolerant fleet: N paged workers behind the FleetRouter."""
    from ..serve.faults import FaultPlan
    from ..serve.fleet import FleetConfig, FleetRouter

    reqs = _tagged_requests(args, load, prompts)
    tenant_dicts = _parse_tenants(args.tenants) if args.tenants else []
    server = TracingServer()
    tracer = Tracer("serve-fleet", server)
    plan = FaultPlan.parse(args.fault_plan) if args.fault_plan else FaultPlan()
    if plan:
        print(f"[serve] fault plan: {plan.describe()}")
    router = FleetRouter(
        engines,
        FleetConfig(
            deadline_s=args.deadline_ms / 1e3,
            max_retries=args.retries,
            lease_ttl_s=args.lease_ttl_s,
            fairness=args.fairness == "on",
            recovery=args.recovery,
            checkpoint_every=args.checkpoint_every,
        ),
        tenants=[TenantSpec.from_dict(t) for t in tenant_dicts],
        engine_kwargs=dict(
            num_slots=args.engine_batch,
            page_size=args.page_size,
            num_pages=args.num_pages or None,
            prefill_chunk=args.prefill_chunk or None,
            overcommit=args.overcommit,
            prefill_mode=args.prefill_mode,
            prefill_budget=args.prefill_budget or None,
            spec_k=args.spec_k,
            spec_ngram=args.spec_ngram,
            prefix_cache=args.prefix_cache == "on",
        ),
        fault_plan=plan,
        tracer=tracer,
    )
    if args.drain_at:
        for item in args.drain_at.split(","):
            try:
                wtok, stok = item.strip().split(":")
                router.drain(int(wtok), int(stok))
            except ValueError:
                raise SystemExit(
                    f"[serve] bad --drain-at item {item!r} "
                    f"(expected worker:step)"
                )
    stats = router.serve(reqs)
    for r in stats.results:
        tail = (
            f"{len(r.tokens)} tokens" if r.status == "completed"
            else f"reason={r.reason}"
        )
        print(
            f"[serve] req {r.request_id}: {r.status} on worker {r.worker} "
            f"after {r.attempts} attempt(s), {tail}"
        )
    section = fleet_section(server.timeline("serve-fleet"))
    if section:
        print("[serve] fleet robustness:")
        for line in section.splitlines():
            print(f"[serve]   {line}")
    rsection = recovery_section(server.timeline("serve-fleet"))
    if rsection:
        print("[serve] KV-migration recovery:")
        for line in rsection.splitlines():
            print(f"[serve]   {line}")
    latencies = [
        r.latency_s for r in stats.results if r.status == "completed"
    ]
    summary = latency_summary(latencies) if latencies else {}
    summary.update(
        {
            "tokens_per_s": stats.throughput_tps,
            "fleet_workers": float(stats.num_workers),
            "rounds": float(stats.rounds),
            "completed": float(stats.completed),
            "failed": float(stats.failed),
            "rejected": float(stats.rejected),
            "deaths": float(stats.deaths),
            "requeued": float(stats.requeued),
            "hedged": float(stats.hedged),
            "duplicate_commits": float(stats.duplicate_commits),
            "goodput": stats.goodput,
            "max_degrade_level": float(stats.max_degrade_level),
            "migrated": float(stats.migrated),
            "migrated_tokens": float(stats.migrated_tokens),
            "recomputed_prefill_tokens": float(
                stats.recomputed_prefill_tokens),
            "bytes_moved": float(stats.bytes_moved),
            "checkpoints_saved": float(stats.checkpoints_saved),
            "checksum_failures": float(stats.checksum_failures),
            "drains": float(stats.drains),
            "joins": float(stats.joins),
        }
    )
    if stats.recovery_s:
        summary["recovery_max_s"] = max(stats.recovery_s)
    return summary, stats.total_tokens, stats.wall_s


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--backend", default="flash")
    ap.add_argument(
        "--engine", "--mode", dest="engine", default="static",
        choices=["static", "continuous", "paged"],
    )
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate-hz", type=float, default=20.0)
    ap.add_argument("--engine-batch", type=int, default=4)
    ap.add_argument("--batch-timeout-ms", type=float, default=10.0)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged engine)")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="global KV page pool size (0 = num_slots * max_pages)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked-prefill tokens per decode boundary (0 = 4 pages)")
    ap.add_argument("--prefill-mode", default="packed",
                    choices=["packed", "chunked"],
                    help="packed: one token-packed varlen launch per boundary "
                         "(one compile); chunked: legacy per-slot chunks")
    ap.add_argument("--prefill-budget", type=int, default=0,
                    help="packed-prefill tokens per decode boundary "
                         "(0 = 4x prefill chunk); bounds decode latency")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative draft depth: prompt-lookup proposes up "
                         "to k tokens per slot per boundary, one paged "
                         "verify launch scores all k+1 (0 = disabled)")
    ap.add_argument("--spec-ngram", type=int, default=3,
                    help="prompt-lookup n-gram match length for drafting")
    ap.add_argument("--overcommit", type=float, default=1.0,
                    help="paged admission overcommit factor (>1 admits past "
                         "worst-case page commitment; preemption is the valve)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree over the mesh 'model' axis "
                         "(1 = single device; CPU testing needs XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--rs-block-outputs", action="store_true",
                    help="reduce-scatter block outputs instead of all-reduce "
                         "on seq-shardable (prefill) launches")
    ap.add_argument("--kv-dtype", default="",
                    choices=["", "float32", "bfloat16", "int8", "fp8"],
                    help="paged KV pool storage dtype: int8/fp8 store "
                         "quantized pages + per-page-per-head scales and "
                         "fuse dequantization into the attention kernels "
                         "for 2-4x effective pool capacity (empty = full "
                         "precision, bit-identical to before the flag)")
    ap.add_argument("--prefix-cache", default="on", choices=["on", "off"],
                    help="automatic prefix caching (paged engine): share "
                         "committed KV pages across requests with common "
                         "prompt prefixes (copy-on-write on append)")
    ap.add_argument("--prefix-len", type=int, default=0,
                    help="shared-prefix workload: tokens of common prompt "
                         "prefix (0 = independent random prompts)")
    ap.add_argument("--prefix-share", type=float, default=0.75,
                    help="fraction of requests reusing a shared prefix")
    ap.add_argument("--prefix-groups", type=int, default=1,
                    help="distinct shared prefixes in the workload")
    ap.add_argument("--fleet", type=int, default=0,
                    help="fault-tolerant fleet: run N paged workers behind "
                         "the FleetRouter (load balancing, requeue-on-death, "
                         "graceful degradation; 0 = single engine)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="per-request TTL from submit (fleet AND single-"
                         "engine paged): late completions fall out of "
                         "goodput, work expired before execution is "
                         "rejected with attribution (0 = none)")
    ap.add_argument("--tenants", default="",
                    help="multi-tenant serving mix: semicolon-separated "
                         "'name[,prio=T][,weight=W][,rate=TOK/S][,burst=TOK]"
                         "[,hz=QPS][,slo=MS][,prompt=N][,gen=N]' entries; "
                         "rate/burst arm a per-tenant token bucket, prio "
                         "picks the tier (0=best_effort 1=standard "
                         "2=premium), weight the fair share "
                         "(requires --engine paged)")
    ap.add_argument("--priority-mix", default="",
                    help="tier fractions for a single-tenant load, e.g. "
                         "'best_effort=0.25,standard=0.5,premium=0.25' "
                         "(ignored when --tenants is set)")
    ap.add_argument("--slo-ms", type=float, default=0.0,
                    help="per-request latency SLO for goodput accounting "
                         "and SLO-aware admission shedding (0 = none; "
                         "distinct from --deadline-ms, which is a hard TTL)")
    ap.add_argument("--fairness", default="on", choices=["on", "off"],
                    help="tenant-fair scheduling (token buckets + priority "
                         "tiers + weighted fair dequeue); off = pure FIFO "
                         "baseline for A/B comparison")
    ap.add_argument("--retries", type=int, default=2,
                    help="fleet requeues per request after a worker death "
                         "before the request is failed")
    ap.add_argument("--lease-ttl-s", type=float, default=30.0,
                    help="fleet worker heartbeat lease TTL; a worker that "
                         "misses renewal past the TTL is treated as dead")
    ap.add_argument("--fault-plan", default="",
                    help="scripted fault injection, e.g. "
                         "'crash@1:2,stall@0:3:0.5,pressure@2:1:8x4' "
                         "(kind@worker:step[:arg]; corrupt@W:S flips bytes "
                         "in worker W's latest KV checkpoint at step S; "
                         "empty = no faults)")
    ap.add_argument("--recovery", default="migrate",
                    choices=["replay", "migrate"],
                    help="fleet orphan recovery: migrate restores the "
                         "latest KV checkpoint on a survivor (O(bytes) "
                         "failover, bit-identical continuation); replay "
                         "re-prefills from the prompt (also the fallback "
                         "when no checkpoint exists or checksums fail)")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="decode steps between KV page checkpoints on each "
                         "fleet worker (0 = none: only planned drains "
                         "migrate; requires --recovery migrate to matter)")
    ap.add_argument("--drain-at", default="",
                    help="planned elasticity: comma-separated worker:step "
                         "items, e.g. '1:4' drains worker 1 at boundary "
                         "step 4 — every live slot migrates with zero "
                         "recompute before the worker is removed")
    ap.add_argument("--evaldb", default="")
    args = ap.parse_args(argv)

    if args.prefix_len > 0 and args.prefix_len >= args.prompt_len:
        ap.error(
            f"--prefix-len {args.prefix_len} must be smaller than "
            f"--prompt-len {args.prompt_len} (the shared prefix is a strict "
            f"prefix; every prompt keeps a unique tail)"
        )
    cfg = get_config(args.arch, reduced=args.reduced)
    model = build_model(cfg, backend=args.backend)
    params = model.init(jax.random.PRNGKey(0))
    rules = None
    if args.tp > 1:
        if args.engine != "paged":
            ap.error("--tp > 1 requires --engine paged")
        from ..sharding.specs import serve_rules
        from .mesh import make_host_mesh

        rules = serve_rules(
            make_host_mesh(tp=args.tp),
            rs_block_outputs=args.rs_block_outputs,
        )
    if args.kv_dtype and args.engine != "paged":
        ap.error("--kv-dtype requires --engine paged (only the paged pool "
                 "stores quantized KV pages)")
    if args.fleet > 0 and args.engine != "paged":
        ap.error("--fleet requires --engine paged (the fleet routes over "
                 "paged workers)")
    if args.tenants and args.engine != "paged":
        ap.error("--tenants requires --engine paged (tenant-aware admission "
                 "lives in the paged engine and the fleet router)")
    if args.tenants:
        try:
            _parse_tenants(args.tenants)
        except ValueError as e:
            ap.error(str(e))
    if args.priority_mix:
        try:
            _parse_priority_mix(args.priority_mix)
        except ValueError as e:
            ap.error(f"bad --priority-mix {args.priority_mix!r}: {e}")

    def make_engine():
        return ServingEngine(
            model, params, max_batch=args.engine_batch, max_seq=args.max_seq,
            page_size=args.page_size, rules=rules,
            kv_dtype=args.kv_dtype or None,
        )

    engine = make_engine()
    # report header: the engine knobs this evaluation ran under, so the run
    # is self-describing (same block lands in the evaldb record)
    knobs = EngineKnobs(
        engine=args.engine,
        kv_dtype=args.kv_dtype or engine.cache_dtype,
        page_size=args.page_size if args.engine == "paged" else 0,
        spec_k=args.spec_k if args.engine == "paged" else 0,
        prefix_cache=args.engine == "paged" and args.prefix_cache == "on",
        tp=engine.tp,
        # recovery knobs are fleet-level: single-engine runs keep the
        # pre-fleet header byte-for-byte
        recovery=args.recovery if args.fleet else "replay",
        checkpoint_every=args.checkpoint_every if args.fleet else 0,
    )
    print(f"[serve] {knobs.describe()}")
    if args.tp > 1:
        print(f"[serve] tensor parallelism: requested tp={args.tp}, "
              f"effective tp={engine.tp} "
              f"({'heads split' if engine.tp > 1 else 'replication fallback'})")
    rng = np.random.default_rng(0)
    if args.tenants:
        # multi-tenant mix: superposed per-tenant Poisson streams carrying
        # tenant identity / tier / SLO / token shape in each request's tags
        tenant_dicts = _parse_tenants(args.tenants)
        for t in tenant_dicts:
            t.setdefault("rate_hz", args.rate_hz / len(tenant_dicts))
            t.setdefault("slo_ms", args.slo_ms)
            t.setdefault("prompt_len", args.prompt_len)
            t.setdefault("gen_tokens", args.max_new_tokens)
        load = list(
            MultiTenantLoad(args.requests, tenant_dicts, seed=0).requests()
        )
        prompts = [
            rng.integers(
                0, cfg.vocab_size,
                (int(r.tags.get("prompt_len") or args.prompt_len),),
            ).astype(np.int32)
            for r in load
        ]
    elif args.prefix_len > 0:
        # shared-prefix serving mix: same-group prompts share their first
        # prefix_len tokens bit-for-bit — the workload the prefix cache eats
        load = list(
            SharedPrefixLoad(
                args.requests, rate_hz=args.rate_hz,
                prefix_len=args.prefix_len,
                suffix_len=args.prompt_len - args.prefix_len,
                share_ratio=args.prefix_share,
                num_groups=args.prefix_groups, seed=0,
            ).requests()
        )
        prompts = shared_prefix_prompts(load, cfg.vocab_size, seed=0)
    else:
        load = list(PoissonLoad(args.requests, args.rate_hz, seed=0).requests())
        prompts = [
            rng.integers(0, cfg.vocab_size, (args.prompt_len,)).astype(np.int32)
            for _ in load
        ]

    if args.priority_mix and not args.tenants:
        # stamp tiers onto a single-tenant load by fraction (seeded draw)
        import random as _random

        mix = _parse_priority_mix(args.priority_mix)
        tiers = [PRIORITY_TIERS.index(k) if k in PRIORITY_TIERS else int(k)
                 for k in mix]
        weights = [float(v) for v in mix.values()]
        mrng = _random.Random(0)
        for r in load:
            r.tags["priority"] = mrng.choices(tiers, weights)[0]

    if args.fleet > 0:
        # workers share model+params (weights are read-only under serving);
        # each gets its own engine => its own KV page pool + slot state
        engines = [engine] + [make_engine() for _ in range(args.fleet - 1)]
        summary, generated, wall = _serve_fleet(engines, cfg, args, load, prompts)
    elif args.engine == "continuous":
        summary, generated, wall = _serve_continuous(engine, cfg, args, load, prompts)
    elif args.engine == "paged":
        summary, generated, wall = _serve_paged(engine, cfg, args, load, prompts)
    else:
        summary, generated, wall = _serve_static(engine, cfg, args, load, prompts)

    print(f"[serve] {len(load)} requests, {generated} tokens in {wall:.2f}s")
    for k, v in summary.items():
        print(f"[serve]   {k:24s} {v:.2f}")
    if args.evaldb:
        EvalDB(args.evaldb).insert(
            EvaluationRecord(
                model=cfg.name, model_version="1.0.0", backend=args.backend,
                backend_version="1.0.0", system="local",
                scenario=f"serve-fleet{args.fleet}" if args.fleet > 0
                else f"serve-{args.engine}",
                batch_size=args.engine_batch, trace_level="NONE",
                agent_id="serve-driver",
                metrics={**summary, "engine_knobs": knobs.to_dict()},
            )
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
