"""Quantized KV page storage: per-row scale quantization for the paged pool.

The paged KV pool can optionally store K/V in a narrow dtype (``int8`` or
``fp8`` = float8_e4m3fn) with a parallel float32 *scale pool* of shape
``(num_pages, page_size, kvh)`` — one scale per page row per kv head, the
finest granularity at which the serving scatter paths write.  Per-row (not
per-page) scales mean an append never has to requantize previously written
rows: every quantize-on-append site mirrors the existing K/V scatter exactly
(same indices, one extra pool), pages stay append-only, and copy-on-write
just moves the scale rows with the page.

Scale layout trade-off: a float32 scale per row per kv head costs 4 bytes
against ``head_dim`` payload bytes, so the effective capacity win over
bf16 is ``2 * head_dim / (head_dim + 4)`` — 1.88x at head_dim 64, 1.94x at
head_dim 128.  Dequantization (``q * scale``) is fused into the inner loops
of the three serving kernels and their fallbacks; quantized K/V never
materializes in full precision outside a kernel block.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

__all__ = [
    "KV_DTYPES",
    "is_quantized",
    "pool_dtype",
    "quant_max",
    "quantize",
    "dequantize",
    "kv_bytes_per_token",
]

# user-facing kv_dtype name -> (pool dtype string, max representable magnitude)
KV_DTYPES = {
    "int8": ("int8", 127.0),
    "fp8": ("float8_e4m3fn", 448.0),
}


def is_quantized(kv_dtype: Optional[str]) -> bool:
    """True when ``kv_dtype`` names a quantized pool mode (None/f32/bf16
    style dtype strings are the full-precision modes)."""
    if kv_dtype is None:
        return False
    if kv_dtype in KV_DTYPES:
        return True
    if kv_dtype in ("float32", "bfloat16", "float16", "f32", "bf16"):
        return False
    raise ValueError(
        f"unknown kv_dtype {kv_dtype!r}; expected one of "
        f"{sorted(KV_DTYPES)} or a full-precision dtype"
    )


def pool_dtype(kv_dtype: str) -> str:
    """Storage dtype string for the K/V page pools under ``kv_dtype``."""
    return KV_DTYPES[kv_dtype][0]


def quant_max(dtype) -> float:
    """Max representable magnitude of a quantized pool dtype."""
    dt = jnp.dtype(dtype)
    for name, (pool, qmax) in KV_DTYPES.items():
        if dt == jnp.dtype(pool):
            return qmax
    raise ValueError(f"{dt} is not a quantized KV pool dtype")


def quantize(x: jnp.ndarray, dtype) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize K/V rows to the pool dtype with one scale per (row, head).

    ``x``: (..., kvh, d) full-precision rows.  Returns ``(q, scales)`` with
    ``q`` of ``dtype`` and ``scales`` float32 of shape (..., kvh); all-zero
    rows get scale 0 so they dequantize back to exact zeros (fresh pool
    pages are zero-initialized and masked by length anyway).
    """
    dt = jnp.dtype(dtype)
    qmax = quant_max(dt)
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)                    # (..., kvh)
    scales = amax / qmax
    inv = jnp.where(scales > 0, 1.0 / jnp.maximum(scales, 1e-37), 0.0)
    scaled = xf * inv[..., None]
    if dt == jnp.dtype(jnp.int8):
        q = jnp.clip(jnp.round(scaled), -qmax, qmax).astype(jnp.int8)
    else:
        q = scaled.astype(dt)
    return q, scales


def dequantize(q: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`quantize`: (..., kvh, d) x (..., kvh) -> float32."""
    return q.astype(jnp.float32) * scales.astype(jnp.float32)[..., None]


def kv_bytes_per_token(
    num_layers: int, num_kv_heads: int, head_dim: int, kv_dtype: str
) -> int:
    """KV-pool bytes one token costs across all layers (K + V + scales)."""
    if is_quantized(kv_dtype):
        itemsize = jnp.dtype(pool_dtype(kv_dtype)).itemsize
        per_head = head_dim * itemsize + 4                  # payload + f32 scale
    else:
        per_head = head_dim * jnp.dtype(kv_dtype).itemsize
    return 2 * num_layers * num_kv_heads * per_head
