"""Pure-jnp oracles for every kernel in this package.

These are the semantic ground truth: naive, memory-hungry, but obviously
correct. Pallas kernels (and the chunked/flash pure-JAX implementations in
``ops.py``) are validated against these in tests across shape/dtype sweeps.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _soft_cap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return cap * jnp.tanh(x / cap) if cap > 0 else x


def repeat_kv(k: jnp.ndarray, num_q_heads: int) -> jnp.ndarray:
    """Expand (b, s, kvh, d) -> (b, s, h, d) for GQA."""
    b, s, kvh, d = k.shape
    if kvh == num_q_heads:
        return k
    reps = num_q_heads // kvh
    return jnp.repeat(k, reps, axis=2)


def attention(
    q: jnp.ndarray,          # (b, sq, h, d)
    k: jnp.ndarray,          # (b, sk, kvh, d)
    v: jnp.ndarray,          # (b, sk, kvh, d)
    *,
    causal: bool = True,
    window=None,              # None = unlimited; int or traced scalar window
    softcap: float = 0.0,
    q_offset: int = 0,        # absolute position of q[0] (for cached decode)
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Naive full-materialization GQA attention oracle. Returns (b, sq, h, d)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    k = repeat_kv(k, h)
    v = repeat_kv(v, h)
    scale = scale if scale is not None else d ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores * scale
    scores = _soft_cap(scores, softcap)
    q_pos = q_offset + jnp.arange(sq)[:, None]          # (sq, 1)
    k_pos = jnp.arange(sk)[None, :]                      # (1, sk)
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,           # (b, 1, h, d) — one new token
    k_cache: jnp.ndarray,     # (b, S, kvh, d)
    v_cache: jnp.ndarray,     # (b, S, kvh, d)
    lengths: jnp.ndarray,     # (b,) valid cache lengths (incl. the new token)
    *,
    softcap: float = 0.0,
    window=None,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Single-token decode attention vs a (possibly ring-buffered) cache.

    Grouped-einsum form (no ``repeat_kv`` materialization): the cache keeps
    its native layout/sharding — with a seq-sharded cache GSPMD computes
    partial softmax stats per shard instead of regathering the cache.
    """
    b, _, h, d = q.shape
    S, kvh = k_cache.shape[1], k_cache.shape[2]
    rep = h // kvh
    qg = q.reshape(b, 1, kvh, rep, d)
    scale = scale if scale is not None else d ** -0.5
    scores = jnp.einsum(
        "bqgrd,bkgd->bgrqk", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale                                            # (b, kvh, rep, 1, S)
    scores = _soft_cap(scores, softcap)
    k_pos = jnp.arange(S)[None, None, None, None, :]
    valid = k_pos < lengths[:, None, None, None, None]
    if window is not None:
        valid &= k_pos >= (lengths[:, None, None, None, None] - window)
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bgrqk,bkgd->bqgrd", probs.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, 1, h, d).astype(q.dtype)


def paged_attention(
    q: jnp.ndarray,           # (b, 1, h, d) — one new token
    k_pages: jnp.ndarray,     # (num_pages, page_size, kvh, d) global page pool
    v_pages: jnp.ndarray,
    page_table: jnp.ndarray,  # (b, max_pages) int32 page ids per request
    lengths: jnp.ndarray,     # (b,) valid lengths (incl. the new token)
    *,
    softcap: float = 0.0,
    window=None,
    scale: Optional[float] = None,
    k_scales: Optional[jnp.ndarray] = None,  # (num_pages, page_size, kvh) f32
    v_scales: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Paged decode-attention oracle: gather each request's pages back into a
    contiguous cache, then run the dense decode oracle.  Memory-hungry (it
    rematerializes ``max_pages * page_size`` per request) but obviously
    equivalent to dense attention over the live tokens.  With a quantized
    pool (``k_scales``/``v_scales`` given) the gathered pages dequantize via
    the gathered per-row scales before the dense oracle runs."""
    _, page_size, kvh, d = k_pages.shape
    b, max_pages = page_table.shape
    k = k_pages[page_table].reshape(b, max_pages * page_size, kvh, d)
    v = v_pages[page_table].reshape(b, max_pages * page_size, kvh, d)
    if k_scales is not None:
        ks = k_scales[page_table].reshape(b, max_pages * page_size, kvh)
        vs = v_scales[page_table].reshape(b, max_pages * page_size, kvh)
        k = k.astype(jnp.float32) * ks.astype(jnp.float32)[..., None]
        v = v.astype(jnp.float32) * vs.astype(jnp.float32)[..., None]
    return decode_attention(
        q, k, v, lengths, softcap=softcap, window=window, scale=scale
    )


def varlen_prefill(
    q: jnp.ndarray,           # (T, h, d)   token-packed queries (many chunks)
    k: jnp.ndarray,           # (T, kvh, d) packed K for the chunks' own tokens
    v: jnp.ndarray,           # (T, kvh, d)
    k_pages: jnp.ndarray,     # (num_pages, page_size, kvh, d) global page pool
    v_pages: jnp.ndarray,
    cu_seqlens,               # (C+1,) int: chunk c occupies packed rows
                              #   [cu_seqlens[c], cu_seqlens[c+1])
    chunk_lens,               # (C,) int: real (unpadded) tokens per chunk
    chunk_pos0,               # (C,) int: absolute position of each chunk's
                              #   first token (page-aligned)
    page_tables,              # (C, max_pages) int32: the owning request's pages
    *,
    softcap: float = 0.0,
    window=None,
    scale: Optional[float] = None,
    k_scales: Optional[jnp.ndarray] = None,  # (num_pages, page_size, kvh) f32
    v_scales: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Packed ragged-prefill oracle: per chunk, gather the request's
    committed context pages back into a contiguous cache and run the dense
    causal attention oracle over ``context + chunk``.  Rows outside any
    chunk's real tokens (chunk pad and buffer tail pad) come back zero.
    With a quantized pool only the gathered context dequantizes — the
    chunk's own packed K/V stay full precision, matching the kernel.
    Host-side loop over chunks — obviously correct, test/benchmark only.
    """
    import numpy as np

    page_size = int(k_pages.shape[1])
    cu = np.asarray(cu_seqlens, np.int64)
    lens = np.asarray(chunk_lens, np.int64)
    pos0 = np.asarray(chunk_pos0, np.int64)
    tables = np.asarray(page_tables, np.int64)
    out = jnp.zeros_like(q)
    for c in range(len(lens)):
        n = int(lens[c])
        if n == 0:
            continue
        s0 = int(cu[c])
        ctx = int(pos0[c])
        qc, kc, vc = q[s0 : s0 + n], k[s0 : s0 + n], v[s0 : s0 + n]
        if ctx:
            n_ctx = (ctx + page_size - 1) // page_size
            kctx = k_pages[tables[c, :n_ctx]].reshape(
                n_ctx * page_size, *k_pages.shape[2:]
            )[:ctx]
            vctx = v_pages[tables[c, :n_ctx]].reshape(
                n_ctx * page_size, *v_pages.shape[2:]
            )[:ctx]
            if k_scales is not None:
                ksc = k_scales[tables[c, :n_ctx]].reshape(
                    n_ctx * page_size, k_scales.shape[-1]
                )[:ctx]
                vsc = v_scales[tables[c, :n_ctx]].reshape(
                    n_ctx * page_size, v_scales.shape[-1]
                )[:ctx]
                kctx = kctx.astype(jnp.float32) * ksc[..., None]
                vctx = vctx.astype(jnp.float32) * vsc[..., None]
            kc = jnp.concatenate([kctx.astype(kc.dtype), kc], axis=0)
            vc = jnp.concatenate([vctx.astype(vc.dtype), vc], axis=0)
        o = attention(
            qc[None], kc[None], vc[None],
            causal=True, window=window, softcap=softcap, q_offset=ctx,
            scale=scale,
        )[0]
        out = out.at[s0 : s0 + n].set(o)
    return out


def spec_verify(
    q: jnp.ndarray,           # (b, W, h, d) — one in-flight window per slot
    k_pages: jnp.ndarray,     # (num_pages, page_size, kvh, d) global page pool
    v_pages: jnp.ndarray,
    page_table: jnp.ndarray,  # (b, max_pages) int32 page ids per request
    lengths: jnp.ndarray,     # (b,) committed tokens BEFORE the window
    window_lens: jnp.ndarray, # (b,) real tokens in each row's window (0..W)
    *,
    softcap: float = 0.0,
    window=None,
    scale: Optional[float] = None,
    k_scales: Optional[jnp.ndarray] = None,  # (num_pages, page_size, kvh) f32
    v_scales: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Speculative multi-token verification oracle.

    Row ``b`` holds a window of ``window_lens[b]`` in-flight tokens
    (``[next_token, draft_1..draft_k]``) whose K/V the caller has ALREADY
    scattered into the request's pages at positions
    ``[lengths[b], lengths[b] + window_lens[b])`` — window starts are NOT
    page-aligned.  Query ``w`` sits at absolute position ``lengths[b] + w``
    and attends every position ``<= lengths[b] + w`` (committed context plus
    the causal prefix of its own window).  Host-side loop over rows: gather
    the request's pages back into a contiguous cache and run the dense
    causal attention oracle with ``q_offset = lengths[b]``.  Rows past
    ``window_lens[b]`` (window pad) come back zero.  Test/benchmark only.
    """
    import numpy as np

    b, W, h, d = q.shape
    page_size = int(k_pages.shape[1])
    lens = np.asarray(lengths, np.int64)
    wlens = np.asarray(window_lens, np.int64)
    tables = np.asarray(page_table, np.int64)
    out = jnp.zeros_like(q)
    for i in range(b):
        n = int(wlens[i])
        if n == 0:
            continue
        L = int(lens[i])
        total = L + n
        n_pg = (total + page_size - 1) // page_size
        kc = k_pages[tables[i, :n_pg]].reshape(
            n_pg * page_size, *k_pages.shape[2:]
        )[:total]
        vc = v_pages[tables[i, :n_pg]].reshape(
            n_pg * page_size, *v_pages.shape[2:]
        )[:total]
        if k_scales is not None:
            ksc = k_scales[tables[i, :n_pg]].reshape(
                n_pg * page_size, k_scales.shape[-1]
            )[:total]
            vsc = v_scales[tables[i, :n_pg]].reshape(
                n_pg * page_size, v_scales.shape[-1]
            )[:total]
            kc = kc.astype(jnp.float32) * ksc[..., None]
            vc = vc.astype(jnp.float32) * vsc[..., None]
        o = attention(
            q[i, :n][None], kc[None].astype(q.dtype), vc[None].astype(q.dtype),
            causal=True, window=window, softcap=softcap, q_offset=L,
            scale=scale,
        )[0]
        out = out.at[i, :n].set(o)
    return out


def rmsnorm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm oracle: x * w / sqrt(mean(x^2) + eps), stats in fp32."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


def ssd(
    x: jnp.ndarray,       # (b, s, h, p)  inner activations split into heads
    dt: jnp.ndarray,      # (b, s, h)     softplus'd time deltas (>0)
    A: jnp.ndarray,       # (h,)          negative decay rates (A < 0)
    B: jnp.ndarray,       # (b, s, n)     input projection (single group)
    C: jnp.ndarray,       # (b, s, n)     output projection
    *,
    initial_state: Optional[jnp.ndarray] = None,   # (b, h, p, n)
    return_state: bool = False,
) -> jnp.ndarray:
    """Mamba-2 SSD oracle: sequential recurrence over time (fp32).

        S_t = exp(dt_t * A) * S_{t-1} + dt_t * x_t B_t^T
        y_t = S_t C_t
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    decay = jnp.exp(dtf * Af[None, None, :])                    # (b, s, h)
    state0 = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((b, h, p, n), jnp.float32)
    )

    def step(S, inputs):
        x_t, dt_t, dec_t, B_t, C_t = inputs
        # dB: (b, h, p, n) = dt * x outer B
        dB = jnp.einsum("bh,bhp,bn->bhpn", dt_t, x_t, B_t)
        S = dec_t[..., None, None] * S + dB
        y = jnp.einsum("bhpn,bn->bhp", S, C_t)
        return S, y

    xs = (
        jnp.moveaxis(xf, 1, 0),       # (s, b, h, p)
        jnp.moveaxis(dtf, 1, 0),      # (s, b, h)
        jnp.moveaxis(decay, 1, 0),    # (s, b, h)
        jnp.moveaxis(Bf, 1, 0),       # (s, b, n)
        jnp.moveaxis(Cf, 1, 0),       # (s, b, n)
    )
    final_state, ys = jax.lax.scan(step, state0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)                  # (b, s, h, p)
    if return_state:
        return y, final_state.astype(x.dtype)
    return y


def ssd_step(
    x: jnp.ndarray,       # (b, h, p)
    dt: jnp.ndarray,      # (b, h)
    A: jnp.ndarray,       # (h,)
    B: jnp.ndarray,       # (b, n)
    C: jnp.ndarray,       # (b, n)
    state: jnp.ndarray,   # (b, h, p, n)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One decode step of the SSD recurrence. Returns (y, new_state)."""
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    dec = jnp.exp(dtf * A.astype(jnp.float32)[None, :])        # (b, h)
    dB = jnp.einsum("bh,bhp,bn->bhpn", dtf, xf, B.astype(jnp.float32))
    new_state = dec[..., None, None] * state.astype(jnp.float32) + dB
    y = jnp.einsum("bhpn,bn->bhp", new_state, C.astype(jnp.float32))
    return y.astype(x.dtype), new_state.astype(state.dtype)
