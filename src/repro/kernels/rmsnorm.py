"""Pallas TPU fused RMSNorm.

One pass over rows: grid tiles the (flattened) row dimension; each program
normalizes a (block_rows, D) tile in VMEM with fp32 statistics. D sits on
the lane dimension (multiple-of-128 friendly for every assigned arch).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                     # (rows, D)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    normed = x * jax.lax.rsqrt(var + eps)
    w = 1.0 + w_ref[...].astype(jnp.float32)
    o_ref[...] = (normed * w[None, :]).astype(o_ref.dtype)


def rmsnorm(
    x: jnp.ndarray,
    weight: jnp.ndarray,       # (D,)
    eps: float = 1e-6,
    *,
    block_rows: int = 256,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    orig_shape = x.shape
    D = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, D)
    block_rows = max(min(block_rows, rows), 1)
    nr = pl.cdiv(rows, block_rows)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda ri: (ri, 0)),
            pl.BlockSpec((D,), lambda ri: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda ri: (ri, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, D), x.dtype),
        interpret=interpret,
    )(x2, weight)
    return out.reshape(orig_shape)
