"""Pallas TPU speculative-decoding verification: score a whole in-flight
window of ``[next_token, draft_1..draft_k]`` tokens per decoding slot against
the paged KV pool in ONE launch.

Decode is memory-bound: a one-token step streams the request's entire live
KV working set to emit a single token.  Scoring ``k + 1`` positions per
request in one launch costs nearly the same HBM traffic (the pages stream
once; only the tiny q block grows), which is the classic speculative-
decoding win.  The caller has ALREADY scattered the window's K/V into the
request's pages at positions ``[lengths[b], lengths[b] + window_lens[b])``
— window starts are NOT page-aligned (they sit wherever decode left off),
so per-query causal masking is on *absolute* positions: query ``w`` of row
``b`` sits at ``lengths[b] + w`` and attends every position ``<= lengths[b]
+ w`` (committed context plus the causal prefix of its own window).

Grid = (batch, q_heads, kv_pages) with the page dimension innermost and
sequential so the online-softmax state (one row per window position) lives
in VMEM scratch — the same flash-decode layout as
:mod:`.paged_attention`, with a (W, d) q block instead of (1, d).  The page
table, committed ``lengths`` and per-row ``window_lens`` arrive as scalar
prefetch: the k/v BlockSpec index maps dereference the page table so only
pages holding live-or-in-flight tokens stream HBM->VMEM; trailing dead
blocks clamp to the last live page (a revisit — no new DMA).  ``W`` is
static (one jit variant per draft depth k), rows with fewer real drafts
mask the tail and emit exact zeros there.  Pallas wants block minor dims at
8x128 multiples on real TPUs; the engine's small test/CI window and head
sizes rely on interpret mode exactly like the paged decode kernel.

Quantized pools (``k_scales``/``v_scales`` given): the float32 per-row
per-kv-head scale blocks stream through the same page-table index map as
their K/V pages and dequantization is fused right after the block load,
exactly as in :mod:`.paged_attention`.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across versions; bridge both
if not hasattr(pltpu, "CompilerParams"):  # pragma: no cover - version compat
    pltpu.CompilerParams = pltpu.TPUCompilerParams

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(
    pt_ref,                    # scalar prefetch: (b, max_pages) int32 page table
    lens_ref,                  # scalar prefetch: (b,) committed tokens
    wlens_ref,                 # scalar prefetch: (b,) real window tokens
    w_ref,                     # scalar prefetch: (1,) int32 window (0 = none)
    q_ref,                     # (1, W, 1, d)
    k_ref, v_ref,              # (1, page_size, 1, d) — one page
    *rest,                     # [ks_ref, vs_ref (1, page_size, 1)], o_ref, scratch
    softcap: float,
    page_size: int,
    win: int,                  # static window rows W
    scale: float,
    quantized: bool,
):
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    bi = pl.program_id(0)
    pj = pl.program_id(2)
    np_ = pl.num_programs(2)

    @pl.when(pj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :]                                   # (W, d)
    k = k_ref[0, :, 0, :]                                   # (page_size, d)
    v = v_ref[0, :, 0, :]
    if quantized:
        # fused dequant: one f32 scale per page row for this kv head
        k = k.astype(jnp.float32) * ks_ref[0, :, 0][:, None]
        v = v.astype(jnp.float32) * vs_ref[0, :, 0][:, None]
    L = lens_ref[bi]
    wl = wlens_ref[bi]
    # positions are *logical*: page pj of this request covers
    # [pj*page_size, (pj+1)*page_size) regardless of the physical page the
    # index map streamed in.  Query w sits at absolute position L + w.
    k_pos = pj * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (win, page_size), 1
    )
    w_idx = jax.lax.broadcasted_iota(jnp.int32, (win, page_size), 0)
    q_pos = L + w_idx
    valid = (k_pos <= q_pos) & (w_idx < wl)
    w = w_ref[0]
    valid &= jnp.where(w > 0, (q_pos - k_pos) < w, True)
    # zero invalid V rows: dead pages hold undefined memory and fully-masked
    # q rows accumulate p=1 over dead stages — 0-valued V keeps them inert
    row_valid = jnp.max(valid, axis=0)
    v = jnp.where(row_valid[:, None], v, 0.0)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                               # (W, page_size)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    # explicit p mask: a fully-masked q row (window pad / idle slot) has
    # every score at NEG_INF, so exp(s - m) would be 1 everywhere; masked p
    # keeps l at 0 -> output exactly 0 for those rows
    p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pj == np_ - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def spec_verify(
    q: jnp.ndarray,            # (b, W, h, d) in-flight windows
    k_pages: jnp.ndarray,      # (num_pages, page_size, kvh, d) global pool
    v_pages: jnp.ndarray,
    page_table: jnp.ndarray,   # (b, max_pages) int32 page ids per request
    lengths: jnp.ndarray,      # (b,) committed tokens BEFORE the window
    window_lens: jnp.ndarray,  # (b,) real window tokens per row (0..W)
    *,
    softcap: float = 0.0,
    window=None,
    scale: Optional[float] = None,
    pages_bound: Optional[int] = None,
    interpret: Optional[bool] = None,
    k_scales: Optional[jnp.ndarray] = None,  # (num_pages, page_size, kvh) f32
    v_scales: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    b, W, h, d = q.shape
    page_size, kvh = k_pages.shape[1], k_pages.shape[2]
    max_pages = page_table.shape[1]
    rep = h // kvh
    quantized = k_scales is not None
    scale = scale if scale is not None else d ** -0.5
    # static bound on pages per request INCLUDING the in-flight window (the
    # window may straddle into a freshly-opened page)
    ns = max_pages if pages_bound is None else min(pages_bound, max_pages)
    ns = max(ns, 1)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    wval = jnp.asarray([0], jnp.int32) if window is None else jnp.asarray(
        [window], jnp.int32
    ).reshape((1,))

    def _page(pj, pt, lens, wlens, bi):
        # clamp dead trailing blocks to the row's last live-or-in-flight
        # page: the index map returns the same block as the previous step,
        # so Pallas skips the DMA instead of streaming an arbitrary page
        total = lens[bi] + wlens[bi]
        last = jnp.maximum((total + page_size - 1) // page_size - 1, 0)
        return pt[bi, jnp.minimum(pj, last)]

    kernel = functools.partial(
        _kernel, softcap=float(softcap), page_size=page_size, win=W,
        scale=float(scale), quantized=quantized,
    )
    page_spec = pl.BlockSpec(
        (1, page_size, 1, d),
        lambda bi, hi, pj, pt, lens, wlens, w: (
            _page(pj, pt, lens, wlens, bi), 0, hi // rep, 0
        ),
    )
    in_specs = [
        pl.BlockSpec(
            (1, W, 1, d),
            lambda bi, hi, pj, pt, lens, wlens, w: (bi, 0, hi, 0),
        ),
        page_spec,
        page_spec,
    ]
    operands = [q, k_pages, v_pages]
    if quantized:
        # scale blocks ride the same page-table index map as their pages
        scale_spec = pl.BlockSpec(
            (1, page_size, 1),
            lambda bi, hi, pj, pt, lens, wlens, w: (
                _page(pj, pt, lens, wlens, bi), 0, hi // rep
            ),
        )
        in_specs += [scale_spec, scale_spec]
        operands += [k_scales, v_scales]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(b, h, ns),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, W, 1, d),
            lambda bi, hi, pj, pt, lens, wlens, w: (bi, 0, hi, 0),
        ),
        scratch_shapes=[
            pltpu.VMEM((W,), jnp.float32),
            pltpu.VMEM((W,), jnp.float32),
            pltpu.VMEM((W, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, W, h, d), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(
        jnp.asarray(page_table, jnp.int32),
        jnp.asarray(lengths, jnp.int32),
        jnp.asarray(window_lens, jnp.int32),
        wval,
        *operands,
    )
