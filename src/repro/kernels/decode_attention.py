"""Pallas TPU decode attention: one new token vs a (ring-buffered) KV cache.

Grid = (batch, q_heads, kv_blocks); the kv dimension is innermost and
sequential so the online-softmax state persists in VMEM scratch (flash-
decode structure — on TPU the kv blocks stream HBM→VMEM at full bandwidth,
which is the roofline of decode). Per-batch ``lengths`` arrive as a
scalar-prefetch operand so the mask needs no HBM traffic; an optional
window re-creates the ring-cache semantics of long-context serving.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across versions; bridge both
if not hasattr(pltpu, "CompilerParams"):  # pragma: no cover - version compat
    pltpu.CompilerParams = pltpu.TPUCompilerParams

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(
    lens_ref,                  # scalar prefetch: (b,) int32 valid lengths
    w_ref,                     # scalar prefetch: (1,) int32 window (0 = none)
    q_ref,                     # (1, 1, 1, d)
    k_ref, v_ref,              # (1, block_s, 1, d)
    o_ref,                     # (1, 1, 1, d)
    m_ref, l_ref, acc_ref,     # VMEM scratch
    *,
    softcap: float,
    block_s: int,
    S: int,
    scale: float,
):
    bi = pl.program_id(0)
    sj = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(sj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, 0, :]                                   # (d,)
    k = k_ref[0, :, 0, :]                                   # (bs, d)
    v = v_ref[0, :, 0, :]
    length = lens_ref[bi]
    k_pos = sj * block_s + jax.lax.iota(jnp.int32, block_s)
    valid = (k_pos < length) & (k_pos < S)
    w = w_ref[0]
    valid &= jnp.where(w > 0, k_pos >= length - w, True)
    v = jnp.where(valid[:, None], v, 0.0)
    s = jnp.sum(
        q[None, :].astype(jnp.float32) * k.astype(jnp.float32), axis=-1
    ) * scale                                               # (bs,)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                                  # (bs,)
    l_ref[0] = l_ref[0] * alpha + jnp.sum(p)
    m_ref[0] = m_new
    acc_ref[...] = acc_ref[...] * alpha + jnp.sum(
        p[:, None].astype(jnp.float32) * v.astype(jnp.float32), axis=0
    )[None]

    @pl.when(sj == ns - 1)
    def _finish():
        l = jnp.maximum(l_ref[0], 1e-37)
        o_ref[0, 0, 0, :] = (acc_ref[0] / l).astype(o_ref.dtype)


def decode_attention(
    q: jnp.ndarray,            # (b, 1, h, d)
    k_cache: jnp.ndarray,      # (b, S, kvh, d)
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,      # (b,) int32
    *,
    softcap: float = 0.0,
    window=None,
    scale: Optional[float] = None,
    block_s: int = 512,
    kv_bound: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """``kv_bound``: static upper bound on ``lengths`` (host-known).  The kv
    grid covers only ``ceil(kv_bound/block_s)`` blocks instead of the padded
    ``S``, so short-context decodes stop streaming fully-masked blocks."""
    b, _, h, d = q.shape
    S, kvh = k_cache.shape[1], k_cache.shape[2]
    rep = h // kvh
    scale = scale if scale is not None else d ** -0.5
    s_eff = S if kv_bound is None else max(min(S, int(kv_bound)), 1)
    # shrink the block to the bound too: a 16-token live context must not
    # stream a full 512-token block just because the grid has one step
    block_s = min(block_s, s_eff)
    ns = pl.cdiv(s_eff, block_s)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    wval = jnp.asarray([0], jnp.int32) if window is None else jnp.asarray(
        [window], jnp.int32
    ).reshape((1,))

    kernel = functools.partial(
        _kernel, softcap=float(softcap), block_s=block_s, S=S, scale=float(scale)
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h, ns),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda bi, hi, sj, lens, w: (bi, 0, hi, 0)),
            pl.BlockSpec((1, block_s, 1, d), lambda bi, hi, sj, lens, w: (bi, sj, hi // rep, 0)),
            pl.BlockSpec((1, block_s, 1, d), lambda bi, hi, sj, lens, w: (bi, sj, hi // rep, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, 1, d), lambda bi, hi, sj, lens, w: (bi, 0, hi, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, 1, h, d), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(jnp.asarray(lengths, jnp.int32), wval, q, k_cache, v_cache)
