"""Pallas TPU paged decode attention: one new token vs a paged KV cache.

The KV cache is a global pool of ``page_size``-token pages shared by every
request; each request owns a list of pages recorded in a per-request page
table.  Grid = (batch, q_heads, kv_pages) with the page dimension innermost
and sequential so the flash-decode online-softmax state lives in VMEM
scratch.  The page table and per-request ``lengths`` arrive as
scalar-prefetch operands: the k/v BlockSpec index maps dereference the page
table so only a request's *live* pages stream HBM->VMEM — pages beyond
``ceil(len/page_size)`` are clamped to the request's last live page, which
Pallas recognises as a revisit (no new DMA).  The caller additionally bounds
the grid with ``pages_bound`` (host-known max live pages, bucketed), so the
kernel never iterates the padded page-table width.

Quantized pools (``k_scales``/``v_scales`` given): pages hold int8/fp8 K/V
and a parallel ``(num_pages, page_size, kvh)`` float32 scale pool carries
one scale per row per kv head.  The scale blocks stream through the same
page-table index map as their K/V pages and dequantization (``q * scale``)
is fused right after the block load — quantized K/V never materializes in
full precision outside the kernel.  With scales absent the trace is
bit-identical to the unquantized kernel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across versions; bridge both
if not hasattr(pltpu, "CompilerParams"):  # pragma: no cover - version compat
    pltpu.CompilerParams = pltpu.TPUCompilerParams

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(
    pt_ref,                    # scalar prefetch: (b, max_pages) int32 page table
    lens_ref,                  # scalar prefetch: (b,) int32 valid lengths
    w_ref,                     # scalar prefetch: (1,) int32 window (0 = none)
    q_ref,                     # (1, 1, 1, d)
    k_ref, v_ref,              # (1, page_size, 1, d) — one page
    *rest,                     # [ks_ref, vs_ref (1, page_size, 1)], o_ref, scratch
    softcap: float,
    page_size: int,
    scale: float,
    quantized: bool,
):
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    bi = pl.program_id(0)
    pj = pl.program_id(2)
    np_ = pl.num_programs(2)

    @pl.when(pj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0, 0, :]                                   # (d,)
    k = k_ref[0, :, 0, :]                                   # (page_size, d)
    v = v_ref[0, :, 0, :]
    if quantized:
        # fused dequant: one f32 scale per page row for this kv head
        k = k.astype(jnp.float32) * ks_ref[0, :, 0][:, None]
        v = v.astype(jnp.float32) * vs_ref[0, :, 0][:, None]
    length = lens_ref[bi]
    # positions are *logical*: page pj of this request covers
    # [pj*page_size, (pj+1)*page_size) regardless of which physical page
    # the index map streamed in
    k_pos = pj * page_size + jax.lax.iota(jnp.int32, page_size)
    valid = k_pos < length
    w = w_ref[0]
    valid &= jnp.where(w > 0, k_pos >= length - w, True)
    v = jnp.where(valid[:, None], v, 0.0)
    s = jnp.sum(
        q[None, :].astype(jnp.float32) * k.astype(jnp.float32), axis=-1
    ) * scale                                               # (page_size,)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[0] = l_ref[0] * alpha + jnp.sum(p)
    m_ref[0] = m_new
    acc_ref[...] = acc_ref[...] * alpha + jnp.sum(
        p[:, None].astype(jnp.float32) * v.astype(jnp.float32), axis=0
    )[None]

    @pl.when(pj == np_ - 1)
    def _finish():
        l = jnp.maximum(l_ref[0], 1e-37)
        o_ref[0, 0, 0, :] = (acc_ref[0] / l).astype(o_ref.dtype)


def paged_attention(
    q: jnp.ndarray,            # (b, 1, h, d)
    k_pages: jnp.ndarray,      # (num_pages, page_size, kvh, d) global pool
    v_pages: jnp.ndarray,
    page_table: jnp.ndarray,   # (b, max_pages) int32 page ids per request
    lengths: jnp.ndarray,      # (b,) int32 live tokens per request
    *,
    softcap: float = 0.0,
    window=None,
    scale: Optional[float] = None,
    pages_bound: Optional[int] = None,
    interpret: Optional[bool] = None,
    k_scales: Optional[jnp.ndarray] = None,  # (num_pages, page_size, kvh) f32
    v_scales: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    b, _, h, d = q.shape
    page_size, kvh = k_pages.shape[1], k_pages.shape[2]
    max_pages = page_table.shape[1]
    rep = h // kvh
    quantized = k_scales is not None
    scale = scale if scale is not None else d ** -0.5
    ns = max_pages if pages_bound is None else min(pages_bound, max_pages)
    ns = max(ns, 1)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    wval = jnp.asarray([0], jnp.int32) if window is None else jnp.asarray(
        [window], jnp.int32
    ).reshape((1,))

    def _page(pj, pt, lens, bi):
        # clamp dead trailing blocks to the request's last live page: the
        # index map returns the same block as the previous step, so Pallas
        # skips the DMA instead of streaming an arbitrary page
        last = jnp.maximum((lens[bi] + page_size - 1) // page_size - 1, 0)
        return pt[bi, jnp.minimum(pj, last)]

    kernel = functools.partial(
        _kernel, softcap=float(softcap), page_size=page_size,
        scale=float(scale), quantized=quantized,
    )
    page_spec = pl.BlockSpec(
        (1, page_size, 1, d),
        lambda bi, hi, pj, pt, lens, w: (_page(pj, pt, lens, bi), 0, hi // rep, 0),
    )
    in_specs = [
        pl.BlockSpec((1, 1, 1, d), lambda bi, hi, pj, pt, lens, w: (bi, 0, hi, 0)),
        page_spec,
        page_spec,
    ]
    operands = [q, k_pages, v_pages]
    if quantized:
        # scale blocks ride the same page-table index map as their pages
        scale_spec = pl.BlockSpec(
            (1, page_size, 1),
            lambda bi, hi, pj, pt, lens, w: (_page(pj, pt, lens, bi), 0, hi // rep),
        )
        in_specs += [scale_spec, scale_spec]
        operands += [k_scales, v_scales]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, h, ns),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, 1, 1, d), lambda bi, hi, pj, pt, lens, w: (bi, 0, hi, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, 1, h, d), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(
        jnp.asarray(page_table, jnp.int32),
        jnp.asarray(lengths, jnp.int32),
        wval,
        *operands,
    )
