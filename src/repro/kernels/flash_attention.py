"""Pallas TPU flash attention (forward).

TPU-native blocking: grid = (batch, q_heads, q_blocks, kv_blocks) with the
kv dimension innermost and sequential, so the online-softmax state
(m, l, acc) lives in VMEM scratch across kv steps and the output block is
written once on the last kv step. Block shapes keep the MXU busy (q/kv
blocks are multiples of 128 on the lane dim; head_dim is the contraction)
and the working set well under VMEM (~16 MB on v5e):

    q (bq, d) + k,v (bk, d) + acc (bq, d) fp32
    ≈ 128·128·(2+2·2+4) B ≈ 0.16 MB per step

GQA is expressed in the k/v index_map (query head h reads kv head h//rep).
The sliding window arrives as a scalar-prefetch operand so one compiled
kernel serves alternating local/global layers (gemma2). Validated against
:mod:`.ref` in interpret mode on CPU (tests sweep shapes/dtypes/options).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across versions; bridge both
if not hasattr(pltpu, "CompilerParams"):  # pragma: no cover - version compat
    pltpu.CompilerParams = pltpu.TPUCompilerParams

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(
    w_ref,                     # scalar prefetch: (1,) int32 window (0 = none)
    q_ref, k_ref, v_ref,       # (1, block_q, 1, d), (1, block_k, 1, d)
    o_ref,                     # (1, block_q, 1, d)
    m_ref, l_ref, acc_ref,     # VMEM scratch
    *,
    causal: bool,
    softcap: float,
    q_offset: int,
    block_q: int,
    block_k: int,
    sk: int,
    scale: float,
):
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, :, 0, :]                                   # (bq, d)
    k = k_ref[0, :, 0, :]                                   # (bk, d)
    v = v_ref[0, :, 0, :]
    # zero padded kv rows: partial trailing blocks are filled with undefined
    # values (NaN in interpret mode; garbage on TPU) and 0 * NaN = NaN
    kv_valid = (kj * block_k + jax.lax.iota(jnp.int32, block_k)) < sk
    v = jnp.where(kv_valid[:, None], v, 0.0)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                               # (bq, bk)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0
    )
    k_pos = kj * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1
    )
    mask = k_pos < sk
    if causal:
        mask &= q_pos >= k_pos
    w = w_ref[0]
    mask &= jnp.where(w > 0, (q_pos - k_pos) < w, True)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(kj == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,            # (b, sq, h, d)
    k: jnp.ndarray,            # (b, sk, kvh, d)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window=None,
    softcap: float = 0.0,
    q_offset: int = 0,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    scale = scale if scale is not None else d ** -0.5
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    nq = pl.cdiv(sq, block_q)
    nk = pl.cdiv(sk, block_k)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    wval = jnp.asarray([0], jnp.int32) if window is None else jnp.asarray(
        [window], jnp.int32
    ).reshape((1,))

    kernel = functools.partial(
        _kernel,
        causal=causal, softcap=float(softcap), q_offset=int(q_offset),
        block_q=block_q, block_k=block_k, sk=sk, scale=float(scale),
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, d), lambda bi, hi, qi, kj, w: (bi, qi, hi, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda bi, hi, qi, kj, w: (bi, kj, hi // rep, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda bi, hi, qi, kj, w: (bi, kj, hi // rep, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, block_q, 1, d), lambda bi, hi, qi, kj, w: (bi, qi, hi, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, sq, h, d), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(wval, q, k, v)
