"""Pallas TPU packed varlen prefill attention over a paged KV pool.

One launch runs flash attention for prompt chunks from *many* requests at
once: queries (and the chunks' own K/V) live in a single token-packed
buffer, and each chunk additionally attends its request's already-committed
context pages in the global page pool — no per-request pow2 padding, no
cross-request attention leakage, one compile for a fixed packed-buffer size
regardless of how lengths mix.

Packing contract (shared with ``ref.varlen_prefill`` / ``ops.varlen_prefill``
and the serving engine):

* chunk ``c`` occupies packed rows ``[cu_seqlens[c], cu_seqlens[c+1])``; the
  first ``chunk_lens[c]`` rows are real tokens, the rest pad.  Chunk spans
  are ``block``-aligned (the engine pads each chunk to a page multiple and
  the kernel block equals ``page_size``), so every q block belongs to
  exactly one chunk.
* ``chunk_pos0[c]`` is the absolute position of the chunk's first token
  (page-aligned); the request's committed context is exactly positions
  ``[0, chunk_pos0[c])``, held in the first ``chunk_pos0[c]/page_size``
  entries of ``page_tables[c]``.

Grid = (q_blocks, heads, stages) with the stage dimension innermost and
sequential so the online-softmax state lives in VMEM scratch.  Stage
``s < ctx_bound`` streams context page ``page_tables[c, s]`` from the pool;
stage ``s >= ctx_bound`` streams the chunk's own packed K/V block
``start_blk[c] + (s - ctx_bound)``.  All per-chunk metadata arrives via
scalar prefetch so the BlockSpec index maps dereference only live
pages/blocks — dead stages clamp to the previously streamed block, which
Pallas recognises as a revisit (no new DMA).  Pallas wants the block minor
dims at 8×128 multiples on real TPUs; the engine's small test/CI page sizes
rely on interpret mode exactly like the paged decode kernel.

Quantized pools (``k_scales``/``v_scales`` given): only the CONTEXT page
stages dequantize — the packed chunk K/V (current activations) stay full
precision.  The float32 per-row per-kv-head scale blocks stream through the
same context-page index map as their K/V pages and dequantization is fused
right after the block load.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across versions; bridge both
if not hasattr(pltpu, "CompilerParams"):  # pragma: no cover - version compat
    pltpu.CompilerParams = pltpu.TPUCompilerParams

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(
    blk_chunk_ref,             # scalar prefetch: (nqb,) chunk id per q block
    start_blk_ref,             # scalar prefetch: (C,) first packed block
    pos0_ref,                  # scalar prefetch: (C,) absolute chunk start
    lens_ref,                  # scalar prefetch: (C,) real tokens per chunk
    pt_ref,                    # scalar prefetch: (C, max_pages) page tables
    w_ref,                     # scalar prefetch: (1,) window (0 = none)
    q_ref,                     # (1, block, 1, d)
    kc_ref, vc_ref,            # (1, block, 1, d) — packed chunk K/V block
    kp_ref, vp_ref,            # (1, block, 1, d) — one context page
    *rest,                     # [kps_ref, vps_ref (1, block, 1)], o_ref, scratch
    softcap: float,
    block: int,
    ctx_bound: int,
    scale: float,
    quantized: bool,
):
    if quantized:
        kps_ref, vps_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    qj = pl.program_id(0)
    s = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    c = blk_chunk_ref[qj]
    seq_len = lens_ref[c]
    pos0 = pos0_ref[c]
    # chunk-local offset / absolute position of each q row in this block
    off_q = (qj - start_blk_ref[c]) * block + jax.lax.broadcasted_iota(
        jnp.int32, (block, block), 0
    )
    q_pos = pos0 + off_q
    q_valid = off_q < seq_len

    is_ctx = s < ctx_bound
    # context stage: page s covers logical positions [s*block, (s+1)*block)
    ctx_pos = s * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    ctx_valid = ctx_pos < pos0
    # intra stage: packed block t of this chunk covers chunk-local offsets
    # [t*block, (t+1)*block) at absolute positions pos0 + those offsets
    t = s - ctx_bound
    off_k = t * block + jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)
    k_pos_in = pos0 + off_k
    intra_valid = (off_k < seq_len) & (q_pos >= k_pos_in)

    k_pos = jnp.where(is_ctx, ctx_pos, k_pos_in)
    valid = q_valid & jnp.where(is_ctx, ctx_valid, intra_valid)
    w = w_ref[0]
    valid &= jnp.where(w > 0, (q_pos - k_pos) < w, True)

    q = q_ref[0, :, 0, :]                                   # (block, d)
    if quantized:
        # fused dequant of the CONTEXT page only (packed chunk K/V are the
        # current activations and stay full precision)
        kp = kp_ref[0, :, 0, :].astype(jnp.float32) * kps_ref[0, :, 0][:, None]
        vp = vp_ref[0, :, 0, :].astype(jnp.float32) * vps_ref[0, :, 0][:, None]
        k = jnp.where(is_ctx, kp, kc_ref[0, :, 0, :].astype(jnp.float32))
        v = jnp.where(is_ctx, vp, vc_ref[0, :, 0, :].astype(jnp.float32))
    else:
        k = jnp.where(is_ctx, kp_ref[0, :, 0, :], kc_ref[0, :, 0, :])
        v = jnp.where(is_ctx, vp_ref[0, :, 0, :], vc_ref[0, :, 0, :])
    # zero invalid V rows: dead blocks hold undefined memory and pad q rows
    # accumulate p=1 over fully-masked stages — 0-valued V keeps them inert
    row_valid = jnp.max(valid, axis=0)
    v = jnp.where(row_valid[:, None], v, 0.0)
    s_qk = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                               # (block, block)
    if softcap > 0:
        s_qk = softcap * jnp.tanh(s_qk / softcap)
    s_qk = jnp.where(valid, s_qk, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s_qk, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    # explicit p mask: a fully-masked q row (chunk/buffer pad) has every
    # score at NEG_INF, so exp(s - m) would be 1 everywhere and accumulate
    # the OTHER rows' valid V columns; masked p keeps l at 0 -> output 0
    p = jnp.where(valid, jnp.exp(s_qk - m_new[:, None]), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(s == ns - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def varlen_prefill(
    q: jnp.ndarray,            # (T, h, d)   packed queries
    k: jnp.ndarray,            # (T, kvh, d) packed chunk K
    v: jnp.ndarray,            # (T, kvh, d)
    k_pages: jnp.ndarray,      # (num_pages, page_size, kvh, d) global pool
    v_pages: jnp.ndarray,
    cu_seqlens: jnp.ndarray,   # (C+1,) int32 packed chunk boundaries
    chunk_lens: jnp.ndarray,   # (C,) int32 real tokens per chunk
    chunk_pos0: jnp.ndarray,   # (C,) int32 absolute chunk starts (page-aligned)
    page_tables: jnp.ndarray,  # (C, max_pages) int32
    *,
    softcap: float = 0.0,
    window=None,
    scale: Optional[float] = None,
    pages_bound: Optional[int] = None,
    interpret: Optional[bool] = None,
    k_scales: Optional[jnp.ndarray] = None,  # (num_pages, page_size, kvh) f32
    v_scales: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    T, h, d = q.shape
    page_size, kvh = k_pages.shape[1], k_pages.shape[2]
    C, max_pages = page_tables.shape
    rep = h // kvh
    quantized = k_scales is not None
    block = page_size                  # chunk spans are page multiples
    if T % block:
        raise ValueError(f"packed length {T} not a multiple of page {block}")
    nqb = T // block
    scale = scale if scale is not None else d ** -0.5
    # static bound on context pages per chunk (>=1 so dead-stage clamping in
    # the index maps never indexes the table at -1)
    ctx_bound = max_pages if pages_bound is None else min(pages_bound, max_pages)
    ctx_bound = max(ctx_bound, 1)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    wval = jnp.asarray([0], jnp.int32) if window is None else jnp.asarray(
        [window], jnp.int32
    ).reshape((1,))

    cu = jnp.asarray(cu_seqlens, jnp.int32)
    start_blk = cu[:-1] // block
    # q block -> owning chunk: the last chunk whose start is <= the block
    # (trailing buffer pad maps to the last chunk and is masked by lens)
    blk_chunk = jnp.clip(
        jnp.searchsorted(start_blk, jnp.arange(nqb, dtype=jnp.int32),
                         side="right").astype(jnp.int32) - 1,
        0, C - 1,
    )

    def _ctx_page(qj, s, blkc, sblk, pos0, lens, pt):
        # clamp dead context stages to the chunk's last live page so Pallas
        # sees a revisit (no new DMA); chunks with no context clamp to the
        # table's first entry (the engine points it at the scratch page)
        c = blkc[qj]
        last = jnp.maximum(pos0[c] // block - 1, 0)
        return pt[c, jnp.minimum(jnp.minimum(s, ctx_bound - 1), last)]

    def _intra_blk(qj, s, blkc, sblk):
        # context stages and post-causal stages clamp to an already-streamed
        # packed block of the same chunk
        c = blkc[qj]
        return sblk[c] + jnp.clip(s - ctx_bound, 0, qj - sblk[c])

    kernel = functools.partial(
        _kernel, softcap=float(softcap), block=block, ctx_bound=ctx_bound,
        scale=float(scale), quantized=quantized,
    )
    in_specs = [
        pl.BlockSpec(
            (1, block, 1, d),
            lambda qj, hi, s, blkc, sblk, pos0, lens, pt, w: (0, qj, hi, 0),
        ),
        pl.BlockSpec(
            (1, block, 1, d),
            lambda qj, hi, s, blkc, sblk, pos0, lens, pt, w: (
                0, _intra_blk(qj, s, blkc, sblk), hi // rep, 0
            ),
        ),
        pl.BlockSpec(
            (1, block, 1, d),
            lambda qj, hi, s, blkc, sblk, pos0, lens, pt, w: (
                0, _intra_blk(qj, s, blkc, sblk), hi // rep, 0
            ),
        ),
        pl.BlockSpec(
            (1, block, 1, d),
            lambda qj, hi, s, blkc, sblk, pos0, lens, pt, w: (
                _ctx_page(qj, s, blkc, sblk, pos0, lens, pt), 0, hi // rep, 0
            ),
        ),
        pl.BlockSpec(
            (1, block, 1, d),
            lambda qj, hi, s, blkc, sblk, pos0, lens, pt, w: (
                _ctx_page(qj, s, blkc, sblk, pos0, lens, pt), 0, hi // rep, 0
            ),
        ),
    ]
    operands = [q[None], k[None], v[None], k_pages, v_pages]
    if quantized:
        # scale blocks ride the same context-page index map as their pages
        scale_spec = pl.BlockSpec(
            (1, block, 1),
            lambda qj, hi, s, blkc, sblk, pos0, lens, pt, w: (
                _ctx_page(qj, s, blkc, sblk, pos0, lens, pt), 0, hi // rep
            ),
        )
        in_specs += [scale_spec, scale_spec]
        operands += [k_scales, v_scales]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=6,
        grid=(nqb, h, ctx_bound + nqb),
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (1, block, 1, d),
            lambda qj, hi, s, blkc, sblk, pos0, lens, pt, w: (0, qj, hi, 0),
        ),
        scratch_shapes=[
            pltpu.VMEM((block,), jnp.float32),
            pltpu.VMEM((block,), jnp.float32),
            pltpu.VMEM((block, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((1, T, h, d), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(
        blk_chunk,
        start_blk,
        jnp.asarray(chunk_pos0, jnp.int32),
        jnp.asarray(chunk_lens, jnp.int32),
        jnp.asarray(page_tables, jnp.int32),
        wval,
        *operands,
    )
    return out[0]
