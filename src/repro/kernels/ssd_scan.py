"""Pallas TPU Mamba-2 SSD (state-space duality) chunked scan.

Grid = (batch, chunks); the chunk dimension is innermost and sequential so
the recurrent state S (h, p, n) lives in VMEM scratch across chunks — the
inter-chunk linear recurrence — while each chunk's intra-chunk quadratic
term runs on the MXU. This mirrors the Mamba-2 SSD algorithm's chunked
decomposition, retiled for the TPU memory hierarchy: per-chunk working set

    x (Q, h·p) + B,C (Q, n) + decay (Q, Q, h) + state (h, p, n) fp32
    ≈ 64·64·(h + …)·4 B  ≈ 1–2 MB  « 16 MB VMEM

All accumulation in fp32. The (optional) initial state streams in as a
normal operand; the final state streams out (serving prefill→decode
handoff). Validated against the sequential :func:`repro.kernels.ref.ssd`
oracle in interpret mode.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across versions; bridge both
if not hasattr(pltpu, "CompilerParams"):  # pragma: no cover - version compat
    pltpu.CompilerParams = pltpu.TPUCompilerParams


def _kernel(
    x_ref,      # (1, Q, h, p)
    dt_ref,     # (1, Q, h)
    A_ref,      # (h,)
    B_ref,      # (1, Q, n)
    C_ref,      # (1, Q, n)
    s0_ref,     # (1, h, p, n) initial state
    y_ref,      # (1, Q, h, p)
    sf_ref,     # (1, h, p, n) final state
    state_ref,  # VMEM scratch (h, p, n) fp32
    *,
    chunk: int,
    seq_len: int,
):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)           # (Q, h, p)
    dt = dt_ref[0].astype(jnp.float32)         # (Q, h)
    A = A_ref[...].astype(jnp.float32)         # (h,)
    B = B_ref[0].astype(jnp.float32)           # (Q, n)
    C = C_ref[0].astype(jnp.float32)           # (Q, n)

    # zero padded timesteps in the trailing partial chunk
    t_pos = ci * chunk + jax.lax.iota(jnp.int32, chunk)
    t_valid = t_pos < seq_len
    dt = jnp.where(t_valid[:, None], dt, 0.0)  # decay exp(0)=1, no input

    a = dt * A[None, :]                        # (Q, h) log-decays
    cum = jnp.cumsum(a, axis=0)                # inclusive
    # intra-chunk quadratic term
    decay_qk = jnp.exp(cum[:, None, :] - cum[None, :, :])       # (Q, K, h)
    causal = (
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    )
    decay_qk = jnp.where(causal[:, :, None], decay_qk, 0.0)
    cb = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                            # (Q, K)
    # y_intra[q,h,p] = sum_k cb[q,k] * decay_qk[q,k,h] * dt[k,h] * x[k,h,p]
    w = cb[:, :, None] * decay_qk * dt[None, :, :]               # (Q, K, h)
    y_intra = jnp.einsum("qkh,khp->qhp", w, x)
    # inter-chunk contribution from the carried state
    S = state_ref[...]                                           # (h, p, n)
    decay_q = jnp.exp(cum)                                       # (Q, h)
    y_inter = jnp.einsum("qn,hpn,qh->qhp", C, S, decay_q)
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)
    # state update
    chunk_decay = jnp.exp(cum[-1])                               # (h,)
    decay_k = jnp.exp(cum[-1][None, :] - cum)                    # (K, h)
    dS = jnp.einsum("kh,khp,kn->hpn", decay_k * dt, x, B)
    state_ref[...] = chunk_decay[:, None, None] * S + dS

    @pl.when(ci == nc - 1)
    def _finish():
        sf_ref[0] = state_ref[...].astype(sf_ref.dtype)


def ssd(
    x: jnp.ndarray,       # (b, s, h, p)
    dt: jnp.ndarray,      # (b, s, h)
    A: jnp.ndarray,       # (h,)
    B: jnp.ndarray,       # (b, s, n)
    C: jnp.ndarray,       # (b, s, n)
    *,
    chunk: int = 64,
    initial_state: Optional[jnp.ndarray] = None,
    return_state: bool = False,
    interpret: Optional[bool] = None,
):
    b, s, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    nc = pl.cdiv(s, chunk)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    s0 = (
        initial_state
        if initial_state is not None
        else jnp.zeros((b, h, p, n), jnp.float32)
    )

    kernel = functools.partial(_kernel, chunk=chunk, seq_len=s)
    y, sf = pl.pallas_call(
        kernel,
        grid=(b, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, h, p), lambda bi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, chunk, h), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((h,), lambda bi, ci: (0,)),
            pl.BlockSpec((1, chunk, n), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, h, p, n), lambda bi, ci: (bi, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, h, p), lambda bi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, h, p, n), lambda bi, ci: (bi, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s if s % chunk == 0 else nc * chunk, h, p), x.dtype)
            if False
            else jax.ShapeDtypeStruct((b, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), s0.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((h, p, n), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dt, A, B, C, s0)
    if return_state:
        return y, sf.astype(x.dtype)
    return y
