"""Kernel dispatch layer.

Models call these ops with a ``backend`` string:

* ``ref``    — the naive oracles in :mod:`.ref` (correct, memory-hungry).
* ``flash``  — chunked/online pure-JAX implementations (memory-efficient,
               lowers on any backend; the dry-run default — mirrors the
               Pallas kernels' blocking so the compiled memory behaviour is
               representative of the TPU target).
* ``pallas`` — the Pallas TPU kernels (``interpret=True`` on CPU for tests).

All ops are shape/dtype-polymorphic and jit-friendly.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref

DEFAULT_BACKEND = "flash"
NEG_INF = ref.NEG_INF


def _soft_cap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return cap * jnp.tanh(x / cap) if cap > 0 else x


# ---------------------------------------------------------------------------
# Tensor-parallel head-split wrapping of the serving kernels
# ---------------------------------------------------------------------------
def _heads_shard_info(heads: int, kv_heads: int):
    """(mesh, axis) when the active sharding rules head-split the serving
    kernels, else None (no rules, or the replication fallback)."""
    # lazy: sharding.specs pulls in the model param helpers; importing it at
    # kernel-import time would cycle through models/__init__
    from ..sharding.specs import heads_shard_axis

    return heads_shard_axis(heads, kv_heads)


def _shard_heads(body, mesh, axis, in_specs, out_specs):
    """shard_map a serving-kernel body with heads-split blocks.

    Every rank runs the identical attention program on its own head slice —
    attention never mixes heads, so per-shard outputs are bit-exact slices
    of the unsharded result and no collective is needed until the o-proj
    contraction outside the kernel.  ``check_rep=False``: the replicated
    page tables/lengths feed gathers whose replication the checker can't
    prove."""
    from jax.experimental.shard_map import shard_map

    return shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False,
    )


# ---------------------------------------------------------------------------
# Attention (training / prefill)
# ---------------------------------------------------------------------------
def _kv_blocks(t: jnp.ndarray, block_k: int):
    """(b, sk, kvh, d) -> (nblk, b, block_k, kvh, d) with zero padding."""
    b, sk, kvh, d = t.shape
    nblk = (sk + block_k - 1) // block_k
    pad = nblk * block_k - sk
    if pad:
        t = jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return jnp.moveaxis(t.reshape(b, nblk, block_k, kvh, d), 1, 0)


def _block_mask(j, block_k, sk, q_pos, causal, window):
    k_pos = j * block_k + jnp.arange(block_k)
    mask = k_pos[None, :] < sk
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    return mask                                             # (sq, block_k)


def _flash_fwd_core(q, k, v, causal, window, softcap, q_offset, block_k, scale):
    """Returns (out (b,sq,h,d), m, l with shape (b,kvh,rep,sq) fp32)."""
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    kb = _kv_blocks(k, block_k)
    vb = _kv_blocks(v, block_k)
    nblk = kb.shape[0]
    qr = q.reshape(b, sq, kvh, rep, d)
    q_pos = q_offset + jnp.arange(sq)

    def step(carry, inputs):
        m, l, acc = carry
        j, k_j, v_j = inputs
        s = jnp.einsum(
            "bqgrd,bkgd->bgrqk", qr, k_j, preferred_element_type=jnp.float32
        ) * scale
        s = _soft_cap(s, softcap)
        mask = _block_mask(j, block_k, sk, q_pos, causal, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_j = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_j)
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum(
            "bgrqk,bkgd->bqgrd", p.astype(v_j.dtype), v_j,
            preferred_element_type=jnp.float32,
        )
        acc_new = acc * jnp.moveaxis(alpha, 3, 1)[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, rep, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, rep, sq), jnp.float32)
    acc0 = jnp.zeros((b, sq, kvh, rep, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (jnp.arange(nblk), kb, vb))
    l = jnp.maximum(l, 1e-37)
    out = (acc / jnp.moveaxis(l, 3, 1)[..., None]).reshape(b, sq, h, d)
    return out.astype(q.dtype), m, l


def _flash_bwd_core(
    q, k, v, o, m, l, do, causal, window, softcap, q_offset, block_k, scale
):
    """True flash backward: recompute P per KV block (no saved scores)."""
    b, sq, h, d = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    rep = h // kvh
    kb = _kv_blocks(k, block_k)
    vb = _kv_blocks(v, block_k)
    nblk = kb.shape[0]
    qr = q.reshape(b, sq, kvh, rep, d)
    dor = do.reshape(b, sq, kvh, rep, d)
    q_pos = q_offset + jnp.arange(sq)
    # D = rowsum(dO * O): (b, kvh, rep, sq)
    D = jnp.moveaxis(
        jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
        .reshape(b, sq, kvh, rep),
        1, 3,
    )

    def step(dq, inputs):
        j, k_j, v_j = inputs
        s = jnp.einsum(
            "bqgrd,bkgd->bgrqk", qr, k_j, preferred_element_type=jnp.float32
        ) * scale
        sc = _soft_cap(s, softcap)
        mask = _block_mask(j, block_k, sk, q_pos, causal, window)
        sc_masked = jnp.where(mask[None, None, None], sc, NEG_INF)
        p = jnp.exp(sc_masked - m[..., None]) / l[..., None]    # (b,g,r,sq,bk)
        dv_j = jnp.einsum(
            "bgrqk,bqgrd->bkgd", p.astype(do.dtype), dor,
            preferred_element_type=jnp.float32,
        )
        dp = jnp.einsum(
            "bqgrd,bkgd->bgrqk", dor, v_j, preferred_element_type=jnp.float32
        )
        ds = p * (dp - D[..., None])
        if softcap > 0:
            ds = ds * (1.0 - jnp.square(sc / softcap))
        ds = jnp.where(mask[None, None, None], ds, 0.0) * scale
        dsl = ds.astype(q.dtype)
        dq = dq + jnp.einsum(
            "bgrqk,bkgd->bqgrd", dsl, k_j, preferred_element_type=jnp.float32
        )
        dk_j = jnp.einsum(
            "bgrqk,bqgrd->bkgd", dsl, qr.astype(q.dtype),
            preferred_element_type=jnp.float32,
        )
        return dq, (dk_j, dv_j)

    dq0 = jnp.zeros((b, sq, kvh, rep, d), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(step, dq0, (jnp.arange(nblk), kb, vb))
    dq = dq.reshape(b, sq, h, d).astype(q.dtype)

    def unblock(t):  # (nblk, b, block_k, kvh, d) -> (b, sk, kvh, d)
        t = jnp.moveaxis(t, 0, 1).reshape(b, nblk * block_k, kvh, d)
        return t[:, :sk]

    dk = unblock(dks).astype(k.dtype)
    dv = unblock(dvs).astype(v.dtype)
    return dq, dk, dv


@functools.lru_cache(maxsize=None)
def _flash_vjp(causal, windowed, softcap, q_offset, block_k, scale_key):
    """custom_vjp instance per static-option set (window passed as operand)."""

    @jax.custom_vjp
    def fa(q, k, v, window):
        out, _, _ = _flash_fwd_core(
            q, k, v, causal, window if windowed else None, softcap,
            q_offset, block_k, scale_key,
        )
        return out

    def fwd(q, k, v, window):
        out, m, l = _flash_fwd_core(
            q, k, v, causal, window if windowed else None, softcap,
            q_offset, block_k, scale_key,
        )
        return out, (q, k, v, window, out, m, l)

    def bwd(res, do):
        q, k, v, window, out, m, l = res
        dq, dk, dv = _flash_bwd_core(
            q, k, v, out, m, l, do, causal, window if windowed else None,
            softcap, q_offset, block_k, scale_key,
        )
        return dq, dk, dv, None

    fa.defvjp(fwd, bwd)
    return fa


def flash_attention_jnp(
    q: jnp.ndarray,          # (b, sq, h, d)
    k: jnp.ndarray,          # (b, sk, kvh, d)
    v: jnp.ndarray,          # (b, sk, kvh, d)
    *,
    causal: bool = True,
    window=None,
    softcap: float = 0.0,
    q_offset: int = 0,
    block_k: int = 512,
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Online-softmax attention, scanned over KV blocks, O(sq) memory, with a
    true flash ``custom_vjp`` (backward recomputes scores blockwise — nothing
    quadratic is ever saved)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else d ** -0.5
    block_k = min(block_k, sk)
    windowed = window is not None
    fa = _flash_vjp(causal, windowed, float(softcap), int(q_offset), int(block_k), float(scale))
    wval = jnp.asarray(window, jnp.int32) if windowed else jnp.int32(0)
    return fa(q, k, v, wval)


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window=None,
    softcap: float = 0.0,
    q_offset: int = 0,
    scale: Optional[float] = None,
    backend: str = DEFAULT_BACKEND,
    block_k: int = 512,
) -> jnp.ndarray:
    if backend == "ref":
        return ref.attention(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset, scale=scale,
        )
    if backend == "flash":
        return flash_attention_jnp(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset, block_k=block_k, scale=scale,
        )
    if backend == "pallas":
        from . import flash_attention as fa  # lazy: pallas import cost

        return fa.flash_attention(
            q, k, v, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset, scale=scale,
        )
    raise ValueError(f"unknown attention backend {backend!r}")


# ---------------------------------------------------------------------------
# Decode attention (single new token vs KV cache)
# ---------------------------------------------------------------------------
def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    softcap: float = 0.0,
    window=None,
    scale: Optional[float] = None,
    backend: str = DEFAULT_BACKEND,
    kv_bound: Optional[int] = None,
) -> jnp.ndarray:
    """``kv_bound`` is a static host-known upper bound on ``lengths``: decode
    only reads the first ``kv_bound`` cache slots instead of streaming all
    ``S`` padded blocks (serving buckets it to a power of two so short
    contexts stop paying the full-cache bandwidth tax).  Invalid for ring
    caches, whose live tokens wrap the whole buffer."""
    if backend == "pallas":
        from . import decode_attention as da

        # the kernel bounds its own kv grid: the cache operand stays whole
        # (no slice copy), blocks past the bound are simply never streamed
        return da.decode_attention(
            q, k_cache, v_cache, lengths, softcap=softcap, window=window,
            scale=scale, kv_bound=kv_bound,
        )
    if kv_bound is not None and kv_bound < k_cache.shape[1]:
        k_cache = k_cache[:, :kv_bound]
        v_cache = v_cache[:, :kv_bound]
    # ref and flash share the same (already memory-light) computation
    return ref.decode_attention(
        q, k_cache, v_cache, lengths, softcap=softcap, window=window, scale=scale
    )


# ---------------------------------------------------------------------------
# Packed varlen prefill (many prompt chunks, one launch, paged context)
# ---------------------------------------------------------------------------
def varlen_prefill_jnp(
    q: jnp.ndarray,            # (T, h, d)   packed queries
    k: jnp.ndarray,            # (T, kvh, d) packed chunk K
    v: jnp.ndarray,            # (T, kvh, d)
    k_pages: jnp.ndarray,      # (num_pages, page_size, kvh, d)
    v_pages: jnp.ndarray,
    cu_seqlens: jnp.ndarray,   # (C+1,) int32
    chunk_lens: jnp.ndarray,   # (C,) int32
    chunk_pos0: jnp.ndarray,   # (C,) int32 (page-aligned)
    page_tables: jnp.ndarray,  # (C, max_pages) int32
    *,
    softcap: float = 0.0,
    window=None,
    scale: Optional[float] = None,
    pages_bound: Optional[int] = None,
    k_scales: Optional[jnp.ndarray] = None,  # (num_pages, page_size, kvh) f32
    v_scales: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Masked one-shot packed prefill (jit-friendly, any backend).

    Scores are the concatenation of a per-token gathered context block
    (``ctx_bound`` pages of the owning chunk's request) and the packed
    buffer itself, masked so a token sees exactly its request's committed
    positions plus the causal prefix of its own chunk.  Rows outside any
    chunk's real tokens come back zero (a manual safe softmax — not
    ``jax.nn.softmax``, which would go uniform on fully-masked rows).  With
    a quantized pool (``k_scales``/``v_scales`` given) only the gathered
    context dequantizes — the packed chunk K/V stay full precision.
    """
    T, h, d = q.shape
    page_size, kvh = k_pages.shape[1], k_pages.shape[2]
    C, max_pages = page_tables.shape
    rep = h // kvh
    scale = scale if scale is not None else d ** -0.5
    ctx_pages = max_pages if pages_bound is None else min(pages_bound, max_pages)
    ctx_pages = max(ctx_pages, 1)
    Lc = ctx_pages * page_size

    cu = jnp.asarray(cu_seqlens, jnp.int32)
    lens = jnp.asarray(chunk_lens, jnp.int32)
    pos0 = jnp.asarray(chunk_pos0, jnp.int32)
    tok = jnp.arange(T, dtype=jnp.int32)
    # token -> owning chunk (trailing buffer pad maps to the last chunk and
    # is masked out by its real length)
    tc = jnp.clip(
        jnp.searchsorted(cu[:-1], tok, side="right").astype(jnp.int32) - 1,
        0, C - 1,
    )
    off = tok - cu[tc]                       # chunk-local offset
    q_valid = off < lens[tc]
    q_pos = pos0[tc] + off                   # absolute positions

    qg = q.reshape(T, kvh, rep, d)
    # context score/value gathers: when chunk spans are page-aligned (the
    # packed layout contract, enforced by the Pallas kernel) the gather runs
    # per page-sized BLOCK — a ``page_size``× smaller index set than per
    # token.  A block straddling two chunks would gather the wrong request's
    # pages, so the fast path additionally requires page-aligned
    # ``cu_seqlens``: checked when the boundaries are concrete (free-form
    # test inputs fall back to the exact per-token gather); under jit the
    # boundaries are traced and the engine's packing contract guarantees
    # alignment.
    blocked = T % page_size == 0
    if blocked:
        try:
            import numpy as _np

            blocked = bool((_np.asarray(cu_seqlens) % page_size == 0).all())
        except Exception:  # traced under jit: trust the packing contract
            pass
    if blocked:
        nqb = T // page_size
        blk_chunk = jnp.clip(
            jnp.searchsorted(
                cu[:-1] // page_size, jnp.arange(nqb, dtype=jnp.int32),
                side="right",
            ).astype(jnp.int32) - 1,
            0, C - 1,
        )
        blk_tables = page_tables[blk_chunk][:, :ctx_pages]
        kctx = k_pages[blk_tables].reshape(nqb, Lc, kvh, d)
        vctx = v_pages[blk_tables].reshape(nqb, Lc, kvh, d)
        if k_scales is not None:
            ksc = k_scales[blk_tables].reshape(nqb, Lc, kvh)
            vsc = v_scales[blk_tables].reshape(nqb, Lc, kvh)
            kctx = kctx.astype(jnp.float32) * ksc[..., None]
            vctx = vctx.astype(jnp.float32) * vsc[..., None]
        qb = qg.reshape(nqb, page_size, kvh, rep, d)
        s_ctx = (
            jnp.einsum(
                "nbgrd,nlgd->nbgrl", qb, kctx,
                preferred_element_type=jnp.float32,
            ) * scale
        ).reshape(T, kvh, rep, Lc)
    else:
        kctx_c = k_pages[page_tables[:, :ctx_pages]].reshape(C, Lc, kvh, d)
        kctx = kctx_c[tc]
        vctx = v_pages[page_tables[:, :ctx_pages]].reshape(C, Lc, kvh, d)[tc]
        if k_scales is not None:
            ksc = k_scales[page_tables[:, :ctx_pages]].reshape(C, Lc, kvh)[tc]
            vsc = v_scales[page_tables[:, :ctx_pages]].reshape(C, Lc, kvh)[tc]
            kctx = kctx.astype(jnp.float32) * ksc[..., None]
            vctx = vctx.astype(jnp.float32) * vsc[..., None]
        s_ctx = jnp.einsum(
            "tgrd,tlgd->tgrl", qg, kctx, preferred_element_type=jnp.float32
        ) * scale                            # (T, kvh, rep, Lc)
    s_in = jnp.einsum(
        "tgrd,ugd->tgru", qg, k, preferred_element_type=jnp.float32
    ) * scale                                # (T, kvh, rep, T)
    s_all = _soft_cap(jnp.concatenate([s_ctx, s_in], axis=-1), softcap)

    ctx_pos = jnp.arange(Lc, dtype=jnp.int32)
    m_ctx = q_valid[:, None] & (ctx_pos[None, :] < pos0[tc][:, None])
    if window is not None:
        m_ctx &= (q_pos[:, None] - ctx_pos[None, :]) < window
    m_in = (
        q_valid[:, None]
        & q_valid[None, :]                   # keys must be real tokens too
        & (tc[:, None] == tc[None, :])       # no cross-request leakage
        & (q_pos[:, None] >= q_pos[None, :])
    )
    if window is not None:
        m_in &= (q_pos[:, None] - q_pos[None, :]) < window
    mask = jnp.concatenate(
        [m_ctx[:, None, None, :], m_in[:, None, None, :]], axis=-1
    )                                         # (T, 1, 1, Lc+T)
    s_all = jnp.where(mask, s_all, NEG_INF)
    m = jnp.max(s_all, axis=-1, keepdims=True)
    p = jnp.where(mask, jnp.exp(s_all - m), 0.0)
    l = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-37)
    p = p / l
    p_ctx = p[..., :Lc].astype(vctx.dtype)
    if blocked:
        out_ctx = jnp.einsum(
            "nbgrl,nlgd->nbgrd",
            p_ctx.reshape(nqb, page_size, kvh, rep, Lc), vctx,
            preferred_element_type=jnp.float32,
        ).reshape(T, kvh, rep, d)
    else:
        out_ctx = jnp.einsum(
            "tgrl,tlgd->tgrd", p_ctx, vctx,
            preferred_element_type=jnp.float32,
        )
    out = out_ctx + jnp.einsum(
        "tgru,ugd->tgrd", p[..., Lc:].astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(T, h, d).astype(q.dtype)


def varlen_prefill(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    k_pages: jnp.ndarray,
    v_pages: jnp.ndarray,
    cu_seqlens: jnp.ndarray,
    chunk_lens: jnp.ndarray,
    chunk_pos0: jnp.ndarray,
    page_tables: jnp.ndarray,
    *,
    softcap: float = 0.0,
    window=None,
    scale: Optional[float] = None,
    backend: str = DEFAULT_BACKEND,
    pages_bound: Optional[int] = None,
    k_scales: Optional[jnp.ndarray] = None,
    v_scales: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Packed ragged-prefill attention: chunks from many requests share one
    token-packed buffer; each chunk attends its request's committed pages
    plus the causal prefix of its own tokens.  ``pages_bound`` statically
    bounds context pages per chunk (host-known, bucketed)."""
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    quantized = k_scales is not None

    def body(q, k, v, k_pages, v_pages, cu_seqlens, chunk_lens, chunk_pos0,
             page_tables, *scales):
        sc = dict(zip(("k_scales", "v_scales"), scales))
        if backend == "pallas":
            from . import varlen_prefill as vp  # lazy: pallas import cost

            return vp.varlen_prefill(
                q, k, v, k_pages, v_pages, cu_seqlens, chunk_lens,
                chunk_pos0, page_tables, softcap=softcap, window=window,
                scale=scale, pages_bound=pages_bound, **sc,
            )
        # ref and flash share the masked one-shot computation (jit-friendly;
        # ref.varlen_prefill is the host-loop oracle used by tests)
        return varlen_prefill_jnp(
            q, k, v, k_pages, v_pages, cu_seqlens, chunk_lens, chunk_pos0,
            page_tables, softcap=softcap, window=window, scale=scale,
            pages_bound=pages_bound, **sc,
        )

    extra = (k_scales, v_scales) if quantized else ()
    tp = _heads_shard_info(q.shape[1], k_pages.shape[2])
    if tp is None:
        return body(
            q, k, v, k_pages, v_pages, cu_seqlens, chunk_lens, chunk_pos0,
            page_tables, *extra,
        )
    mesh, ax = tp
    P = jax.sharding.PartitionSpec
    tok = P(None, ax, None)                                 # (T, heads, d)
    pool = P(None, None, ax, None)
    in_specs = (tok, tok, tok, pool, pool, P(None), P(None), P(None),
                P(None, None))
    if quantized:
        # scale pools shard on the kv-head axis with their pages
        in_specs += (P(None, None, ax), P(None, None, ax))
    return _shard_heads(
        body, mesh, ax,
        in_specs=in_specs,
        out_specs=tok,
    )(q, k, v, k_pages, v_pages, cu_seqlens, chunk_lens, chunk_pos0,
      page_tables, *extra)


# ---------------------------------------------------------------------------
# Paged decode attention (single new token vs a paged KV pool)
# ---------------------------------------------------------------------------
def paged_attention(
    q: jnp.ndarray,
    k_pages: jnp.ndarray,
    v_pages: jnp.ndarray,
    page_table: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    softcap: float = 0.0,
    window=None,
    scale: Optional[float] = None,
    backend: str = DEFAULT_BACKEND,
    pages_bound: Optional[int] = None,
    k_scales: Optional[jnp.ndarray] = None,
    v_scales: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Decode attention over a paged KV cache (global page pool + per-request
    page table).  ``pages_bound`` statically bounds the live pages per
    request (host-known, bucketed), so neither path iterates the padded
    page-table width."""
    if pages_bound is not None and pages_bound < page_table.shape[1]:
        page_table = page_table[:, :pages_bound]
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    quantized = k_scales is not None

    def body(q, k_pages, v_pages, page_table, lengths, *scales):
        sc = dict(zip(("k_scales", "v_scales"), scales))
        if backend == "pallas":
            from . import paged_attention as pa

            return pa.paged_attention(
                q, k_pages, v_pages, page_table, lengths,
                softcap=softcap, window=window, scale=scale, **sc,
            )
        # ref and flash share the gather-based computation
        return ref.paged_attention(
            q, k_pages, v_pages, page_table, lengths,
            softcap=softcap, window=window, scale=scale, **sc,
        )

    extra = (k_scales, v_scales) if quantized else ()
    tp = _heads_shard_info(q.shape[2], k_pages.shape[2])
    if tp is None:
        return body(q, k_pages, v_pages, page_table, lengths, *extra)
    mesh, ax = tp
    P = jax.sharding.PartitionSpec
    hsplit = P(None, None, ax, None)
    in_specs = (hsplit, hsplit, hsplit, P(None, None), P(None))
    if quantized:
        # scale pools shard on the kv-head axis with their pages
        in_specs += (P(None, None, ax), P(None, None, ax))
    return _shard_heads(
        body, mesh, ax,
        in_specs=in_specs,
        out_specs=hsplit,
    )(q, k_pages, v_pages, page_table, lengths, *extra)


# ---------------------------------------------------------------------------
# Page copy (copy-on-write sharing in the paged KV pool)
# ---------------------------------------------------------------------------
def copy_pages(
    k_pages: jnp.ndarray,      # (L, num_pages, page_size, kvh, d)
    v_pages: jnp.ndarray,
    src: jnp.ndarray,          # (n,) int32 physical source pages
    dst: jnp.ndarray,          # (n,) int32 physical destination pages
    k_scales: Optional[jnp.ndarray] = None,  # (L, num_pages, page_size, kvh)
    v_scales: Optional[jnp.ndarray] = None,
):
    """Device-side physical page copy across every layer of the paged KV
    pool: the copy-on-write primitive behind automatic prefix caching.

    When a request is about to append a token into a page that other
    holders (the prefix cache / other requests) still reference, the engine
    first duplicates that page into a private one and remaps the request's
    page table — committed cache content is never mutated, so greedy tokens
    stay bit-identical to a cache-off run.  A gather + scatter on the page
    axis (jit-friendly, donation-safe: callers donate the pools so XLA
    copies in place).  With a quantized pool the scale rows move with their
    pages (4-tuple return); otherwise the 2-tuple return is unchanged."""
    src = jnp.asarray(src, jnp.int32)
    dst = jnp.asarray(dst, jnp.int32)
    out = (
        k_pages.at[:, dst].set(k_pages[:, src]),
        v_pages.at[:, dst].set(v_pages[:, src]),
    )
    if k_scales is None:
        return out
    return out + (
        k_scales.at[:, dst].set(k_scales[:, src]),
        v_scales.at[:, dst].set(v_scales[:, src]),
    )


# ---------------------------------------------------------------------------
# Page export / import (live KV migration between page pools)
# ---------------------------------------------------------------------------
def export_pages(
    k_pages: jnp.ndarray,      # (L, num_pages, page_size, kvh, d)
    v_pages: jnp.ndarray,
    idx: jnp.ndarray,          # (n,) int32 physical pages to export
    k_scales: Optional[jnp.ndarray] = None,  # (L, num_pages, page_size, kvh)
    v_scales: Optional[jnp.ndarray] = None,
):
    """Gather a request's live pages out of the pool into a CONTIGUOUS
    snapshot ``(L, n, page_size, kvh, d)`` — the transferable half of live
    KV migration.  Duplicate indices are legal (callers pow2-pad ``idx``
    with repeats to bound jit variants; the padded rows are sliced off on
    the host).  With a quantized pool the per-page scale rows travel with
    their pages (4-tuple return), so the snapshot is exact stored bytes —
    no dequantize/requantize round trip on the migration path."""
    idx = jnp.asarray(idx, jnp.int32)
    out = (k_pages[:, idx], v_pages[:, idx])
    if k_scales is None:
        return out
    return out + (k_scales[:, idx], v_scales[:, idx])


def import_pages(
    k_pages: jnp.ndarray,      # (L, num_pages, page_size, kvh, d)
    v_pages: jnp.ndarray,
    dst: jnp.ndarray,          # (n,) int32 freshly allocated destination pages
    k_snap: jnp.ndarray,       # (L, n, page_size, kvh, d) exported snapshot
    v_snap: jnp.ndarray,
    k_scales: Optional[jnp.ndarray] = None,
    v_scales: Optional[jnp.ndarray] = None,
    k_scale_snap: Optional[jnp.ndarray] = None,  # (L, n, page_size, kvh)
    v_scale_snap: Optional[jnp.ndarray] = None,
):
    """Scatter an :func:`export_pages` snapshot into a destination pool's
    freshly allocated pages (donation-safe on the pools, like
    :func:`copy_pages`).  Duplicate ``dst`` indices are legal when the
    matching snapshot rows are identical (the pow2-padding contract:
    callers repeat the LAST real page in both ``dst`` and the snapshot, so
    the duplicate write is idempotent)."""
    dst = jnp.asarray(dst, jnp.int32)
    out = (
        k_pages.at[:, dst].set(k_snap),
        v_pages.at[:, dst].set(v_snap),
    )
    if k_scales is None:
        return out
    return out + (
        k_scales.at[:, dst].set(k_scale_snap),
        v_scales.at[:, dst].set(v_scale_snap),
    )


# ---------------------------------------------------------------------------
# Speculative-decoding verification (k+1-token windows vs a paged KV pool)
# ---------------------------------------------------------------------------
def spec_verify_jnp(
    q: jnp.ndarray,            # (b, W, h, d) in-flight windows
    k_pages: jnp.ndarray,      # (num_pages, page_size, kvh, d)
    v_pages: jnp.ndarray,
    page_table: jnp.ndarray,   # (b, max_pages) int32
    lengths: jnp.ndarray,      # (b,) committed tokens BEFORE the window
    window_lens: jnp.ndarray,  # (b,) real window tokens per row (0..W)
    *,
    softcap: float = 0.0,
    window=None,
    scale: Optional[float] = None,
    k_scales: Optional[jnp.ndarray] = None,  # (num_pages, page_size, kvh) f32
    v_scales: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Masked one-shot verification (jit-friendly, any backend).

    Gathers each row's pages back into a contiguous cache (the caller slices
    ``page_table`` to ``pages_bound`` first) and scores all ``W`` window
    positions at once: query ``w`` at absolute position ``lengths[b] + w``
    attends every position ``<= lengths[b] + w`` — the window's own K/V are
    already in the pages, so per-query causal masking on absolute positions
    is the whole story.  Rows past ``window_lens[b]`` come back exactly zero
    (manual safe softmax, not ``jax.nn.softmax``, which would go uniform on
    fully-masked rows).
    """
    b, W, h, d = q.shape
    page_size, kvh = k_pages.shape[1], k_pages.shape[2]
    max_pages = page_table.shape[1]
    rep = h // kvh
    scale = scale if scale is not None else d ** -0.5
    Lk = max_pages * page_size
    k = k_pages[page_table].reshape(b, Lk, kvh, d)
    v = v_pages[page_table].reshape(b, Lk, kvh, d)
    if k_scales is not None:
        ks = k_scales[page_table].reshape(b, Lk, kvh)
        vs = v_scales[page_table].reshape(b, Lk, kvh)
        k = k.astype(jnp.float32) * ks[..., None]
        v = v.astype(jnp.float32) * vs[..., None]
    qg = q.reshape(b, W, kvh, rep, d)
    s = jnp.einsum(
        "bwgrd,bkgd->bgrwk", qg, k, preferred_element_type=jnp.float32
    ) * scale                                  # (b, kvh, rep, W, Lk)
    s = _soft_cap(s, softcap)
    lens = jnp.asarray(lengths, jnp.int32)
    wlens = jnp.asarray(window_lens, jnp.int32)
    k_pos = jnp.arange(Lk, dtype=jnp.int32)[None, None, :]
    q_pos = lens[:, None, None] + jnp.arange(W, dtype=jnp.int32)[None, :, None]
    valid = (k_pos <= q_pos) & (
        jnp.arange(W, dtype=jnp.int32)[None, :, None] < wlens[:, None, None]
    )
    if window is not None:
        valid &= (q_pos - k_pos) < window
    mask = valid[:, None, None]                # (b, 1, 1, W, Lk)
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(mask, jnp.exp(s - m), 0.0)
    l = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-37)
    p = p / l
    out = jnp.einsum(
        "bgrwk,bkgd->bwgrd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, W, h, d).astype(q.dtype)


def spec_verify(
    q: jnp.ndarray,
    k_pages: jnp.ndarray,
    v_pages: jnp.ndarray,
    page_table: jnp.ndarray,
    lengths: jnp.ndarray,
    window_lens: jnp.ndarray,
    *,
    softcap: float = 0.0,
    window=None,
    scale: Optional[float] = None,
    backend: str = DEFAULT_BACKEND,
    pages_bound: Optional[int] = None,
    k_scales: Optional[jnp.ndarray] = None,
    v_scales: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Speculative multi-token verification over a paged KV cache: one
    ``(b, W)`` launch scores each slot's ``[next_token, draft_1..draft_k]``
    window against its committed pages plus the window's own causal prefix
    (the window K/V are scattered into the pages first).  ``pages_bound``
    statically bounds live+in-flight pages per request (host-known,
    bucketed) so neither path iterates the padded page-table width."""
    if pages_bound is not None and pages_bound < page_table.shape[1]:
        page_table = page_table[:, :pages_bound]
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    quantized = k_scales is not None

    def body(q, k_pages, v_pages, page_table, lengths, window_lens, *scales):
        sc = dict(zip(("k_scales", "v_scales"), scales))
        if backend == "pallas":
            from . import spec_verify as sv  # lazy: pallas import cost

            return sv.spec_verify(
                q, k_pages, v_pages, page_table, lengths, window_lens,
                softcap=softcap, window=window, scale=scale, **sc,
            )
        # ref and flash share the gather-based one-shot computation (jit-
        # friendly; ref.spec_verify is the host-loop oracle used by tests)
        return spec_verify_jnp(
            q, k_pages, v_pages, page_table, lengths, window_lens,
            softcap=softcap, window=window, scale=scale, **sc,
        )

    extra = (k_scales, v_scales) if quantized else ()
    tp = _heads_shard_info(q.shape[2], k_pages.shape[2])
    if tp is None:
        return body(q, k_pages, v_pages, page_table, lengths, window_lens,
                    *extra)
    mesh, ax = tp
    P = jax.sharding.PartitionSpec
    hsplit = P(None, None, ax, None)
    in_specs = (hsplit, hsplit, hsplit, P(None, None), P(None), P(None))
    if quantized:
        # scale pools shard on the kv-head axis with their pages
        in_specs += (P(None, None, ax), P(None, None, ax))
    return _shard_heads(
        body, mesh, ax,
        in_specs=in_specs,
        out_specs=hsplit,
    )(q, k_pages, v_pages, page_table, lengths, window_lens, *extra)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rmsnorm(
    x: jnp.ndarray,
    weight: jnp.ndarray,
    eps: float = 1e-6,
    *,
    backend: str = DEFAULT_BACKEND,
) -> jnp.ndarray:
    if backend == "pallas":
        from . import rmsnorm as rn

        return rn.rmsnorm(x, weight, eps=eps)
    return ref.rmsnorm(x, weight, eps=eps)


# ---------------------------------------------------------------------------
# Mamba-2 SSD
# ---------------------------------------------------------------------------
def ssd_chunked_jnp(
    x: jnp.ndarray,       # (b, s, h, p)
    dt: jnp.ndarray,      # (b, s, h)
    A: jnp.ndarray,       # (h,)
    B: jnp.ndarray,       # (b, s, n)
    C: jnp.ndarray,       # (b, s, n)
    *,
    chunk: int = 64,
    initial_state: Optional[jnp.ndarray] = None,
    return_state: bool = False,
):
    """Chunked state-space duality: quadratic intra-chunk attention-like
    computation + linear inter-chunk recurrence (the Mamba-2 algorithm),
    scanned over chunks so peak memory is O(chunk^2) not O(s^2)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    nchunk = (s + chunk - 1) // chunk
    pad = nchunk * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def to_chunks(t):  # (b, s, ...) -> (nchunk, b, chunk, ...)
        return jnp.moveaxis(t.reshape((b, nchunk, chunk) + t.shape[2:]), 1, 0)

    xs = (to_chunks(xf), to_chunks(dtf), to_chunks(Bf), to_chunks(Cf))
    state0 = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((b, h, p, n), jnp.float32)
    )

    def step(S, inputs):
        x_c, dt_c, B_c, C_c = inputs                 # (b, chunk, ...)
        a = dt_c * Af[None, None, :]                 # (b, chunk, h)  log decays
        cum = jnp.cumsum(a, axis=1)                  # inclusive
        # intra-chunk: y[q] += C_q · sum_{k<=q} exp(cum_q - cum_k) dt_k x_k B_k
        decay_qk = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])   # (b, q, k, h)
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        decay_qk = jnp.where(causal[None, :, :, None], decay_qk, 0.0)
        cb = jnp.einsum("bqn,bkn->bqk", C_c, B_c)                      # (b, q, k)
        y_intra = jnp.einsum("bqk,bqkh,bkh,bkhp->bqhp", cb, decay_qk, dt_c, x_c)
        # inter-chunk: contribution of carried state
        decay_q = jnp.exp(cum)                                         # (b, q, h)
        y_inter = jnp.einsum("bqn,bhpn,bqh->bqhp", C_c, S, decay_q)
        # state update: S' = exp(sum a) S + sum_k exp(cum_last - cum_k) dt_k x_k B_k
        chunk_decay = jnp.exp(cum[:, -1, :])                           # (b, h)
        decay_k = jnp.exp(cum[:, -1, None, :] - cum)                   # (b, k, h)
        dS = jnp.einsum("bkh,bkh,bkhp,bkn->bhpn", decay_k, dt_c, x_c, B_c)
        S_new = chunk_decay[:, :, None, None] * S + dS
        return S_new, y_intra + y_inter

    final_state, ys = jax.lax.scan(step, state0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nchunk * chunk, h, p)[:, :s]
    y = y.astype(x.dtype)
    if return_state:
        return y, final_state.astype(x.dtype)
    return y


def ssd(
    x, dt, A, B, C, *,
    chunk: int = 64,
    initial_state=None,
    return_state: bool = False,
    backend: str = DEFAULT_BACKEND,
):
    if backend == "ref":
        return ref.ssd(x, dt, A, B, C, initial_state=initial_state, return_state=return_state)
    if backend == "flash":
        return ssd_chunked_jnp(
            x, dt, A, B, C, chunk=chunk, initial_state=initial_state,
            return_state=return_state,
        )
    if backend == "pallas":
        from . import ssd_scan

        return ssd_scan.ssd(
            x, dt, A, B, C, chunk=chunk, initial_state=initial_state,
            return_state=return_state,
        )
    raise ValueError(f"unknown ssd backend {backend!r}")


def ssd_step(x, dt, A, B, C, state, *, backend: str = DEFAULT_BACKEND):
    """Decode step — shared implementation (already O(1) in seq)."""
    return ref.ssd_step(x, dt, A, B, C, state)
