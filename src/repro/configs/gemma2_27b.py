"""gemma2-27b — alternating local/global attention, logit softcaps
[arXiv:2408.00118; hf].

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000; 4096-token sliding
window on local layers (every other layer global), attn softcap 50, final
logit softcap 30, tied + scaled embeddings, pre+post norms.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    vocab_size=256000,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    sliding_window=4096,
    global_every=2,
    attn_softcap=50.0,
    logit_softcap=30.0,
    post_norms=True,
    tie_embeddings=True,
    scale_embed=True,
)

REDUCED = CONFIG.replace(
    name="gemma2-27b-reduced",
    num_layers=4,
    d_model=64,
    vocab_size=256,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    sliding_window=8,
)
