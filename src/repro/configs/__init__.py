"""Assigned-architecture configs (+ the paper's own ResNet-50).

Each ``<id>.py`` exports ``CONFIG`` (the exact published configuration) and
``REDUCED`` (a same-family small config for CPU smoke tests). ``SHAPES``
defines the assigned input-shape set; :func:`input_specs` in
``repro.launch.dryrun`` materializes them as ShapeDtypeStructs.
"""
from __future__ import annotations

from dataclasses import dataclass
from importlib import import_module
from typing import Dict, List, Optional, Tuple

from ..models.config import ArchConfig

ARCH_IDS = [
    "zamba2_2p7b",
    "qwen3_moe_30b_a3b",
    "llama4_maverick_400b_a17b",
    "deepseek_67b",
    "granite_20b",
    "glm4_9b",
    "gemma2_27b",
    "chameleon_34b",
    "mamba2_130m",
    "whisper_large_v3",
]

# canonical ids as assigned (dashes) -> module names
_ALIASES = {
    "zamba2-2.7b": "zamba2_2p7b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "deepseek-67b": "deepseek_67b",
    "granite-20b": "granite_20b",
    "glm4-9b": "glm4_9b",
    "gemma2-27b": "gemma2_27b",
    "chameleon-34b": "chameleon_34b",
    "mamba2-130m": "mamba2_130m",
    "whisper-large-v3": "whisper_large_v3",
}


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def _module(arch: str):
    mod_name = _ALIASES.get(arch, arch.replace("-", "_").replace(".", "p"))
    return import_module(f".{mod_name}", __package__)


def get_config(arch: str, reduced: bool = False) -> ArchConfig:
    mod = _module(arch)
    return mod.REDUCED if reduced else mod.CONFIG


def list_archs() -> List[str]:
    return list(_ALIASES)


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs; else the documented skip reason."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "SKIP: full-attention arch at 500k decode (see DESIGN.md)"
    return True, ""
