"""mamba2-130m — attention-free SSD (state-space duality) [arXiv:2405.21060; unverified].

24L d_model=768 vocab=50280, ssm_state=128, d_ff=0 (the Mamba2 block is both
mixer and channel path); d_inner=1536, head_dim=64 -> 24 SSD heads.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    vocab_size=50280,
    d_ff=0,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=64,
    tie_embeddings=True,
)

REDUCED = CONFIG.replace(
    name="mamba2-130m-reduced",
    num_layers=3,
    d_model=64,
    vocab_size=256,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_chunk=8,
)
