"""qwen3-moe-30b-a3b — 128 experts, top-8 routing [hf:Qwen/Qwen3-30B-A3B; hf].

48L d_model=2048 32H (GQA kv=4) d_ff=768 (per expert) vocab=151936.
head_dim=128 and QK-norm per the HF config.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    vocab_size=151936,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    num_experts=128,
    experts_per_token=8,
    capacity_factor=1.25,
    qk_norm=True,
    rope_theta=1_000_000.0,
)

REDUCED = CONFIG.replace(
    name="qwen3-moe-30b-a3b-reduced",
    num_layers=2,
    d_model=64,
    vocab_size=256,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=32,
    num_experts=8,
    experts_per_token=2,
    capacity_factor=2.0,
)
