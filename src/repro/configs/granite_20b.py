"""granite-20b — llama-arch dense, code model, MQA [arXiv:2405.04324; hf].

52L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    num_layers=52,
    d_model=6144,
    vocab_size=49152,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24576,
)

REDUCED = CONFIG.replace(
    name="granite-20b-reduced",
    num_layers=3,
    d_model=64,
    vocab_size=256,
    num_heads=4,
    num_kv_heads=1,
    head_dim=16,
    d_ff=128,
)
