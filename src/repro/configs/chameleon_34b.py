"""chameleon-34b — early-fusion VLM with VQ image tokens [arXiv:2405.09818; unverified].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536. The modality
frontend is a STUB: VQ-VAE image codes are token ids inside the 65536
vocabulary, so ``input_specs()`` provides interleaved text+image token ids.
QK-norm as in the published training recipe.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="dense",
    num_layers=48,
    d_model=8192,
    vocab_size=65536,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    qk_norm=True,
)

REDUCED = CONFIG.replace(
    name="chameleon-34b-reduced",
    num_layers=3,
    d_model=64,
    vocab_size=256,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
)
