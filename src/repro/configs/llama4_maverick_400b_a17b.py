"""llama4-maverick-400b-a17b — MoE, early fusion [hf:meta-llama/Llama-4-*; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 (per expert) vocab=202048,
MoE 128 experts top-1. Early-fusion multimodality enters as token ids
(frontend stub); text-only token stream here. Per the published model,
MoE layers interleave with dense layers (every other layer, dense FFN
16384), which lands the totals at ~400B / ~17B-active.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    vocab_size=202048,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    num_experts=128,
    experts_per_token=1,
    capacity_factor=1.25,
    moe_every=2,
    dense_d_ff=16384,
    rope_theta=500_000.0,
)

REDUCED = CONFIG.replace(
    name="llama4-maverick-400b-a17b-reduced",
    num_layers=2,
    d_model=64,
    vocab_size=256,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=64,
    num_experts=8,
    experts_per_token=1,
    capacity_factor=2.0,
    moe_every=2,
    dense_d_ff=128,
)
