"""whisper-large-v3 — encoder-decoder ASR backbone [arXiv:2212.04356; unverified].

32L (enc) + 32L (dec), d_model=1280 20H (kv=20) d_ff=5120 vocab=51866.
The conv/audio frontend is a STUB: ``input_specs()`` provides 1500
precomputed log-mel frame embeddings (b, 1500, d_model). Sinusoidal
positions on both stacks (adaptation: the decoder's learned positions are
replaced by sinusoidal — DESIGN.md).
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="encdec",
    num_layers=32,
    d_model=1280,
    vocab_size=51866,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    encoder_layers=32,
    encoder_seq=1500,
)

REDUCED = CONFIG.replace(
    name="whisper-large-v3-reduced",
    num_layers=2,
    d_model=64,
    vocab_size=256,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    encoder_layers=2,
    encoder_seq=16,
)
