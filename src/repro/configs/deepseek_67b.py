"""deepseek-67b — llama-arch dense [arXiv:2401.02954; hf].

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    vocab_size=102400,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    rope_theta=10_000.0,
)

REDUCED = CONFIG.replace(
    name="deepseek-67b-reduced",
    num_layers=3,
    d_model=64,
    vocab_size=256,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
)
