"""glm4-9b — RoPE + GQA dense [hf:THUDM/glm-4-9b; hf].

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b",
    family="dense",
    num_layers=40,
    d_model=4096,
    vocab_size=151552,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
)

REDUCED = CONFIG.replace(
    name="glm4-9b-reduced",
    num_layers=3,
    d_model=64,
    vocab_size=256,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
)
