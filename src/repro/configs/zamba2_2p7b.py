"""zamba2-2.7b — Mamba2 backbone + shared attention blocks [arXiv:2411.15242; hf].

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64.
Adaptations (DESIGN.md §Arch-applicability): the shared transformer block is
applied after every 6th Mamba2 layer with a single shared parameter set; at
>64k-token decode its attention runs on a ``long_context_window`` ring cache
(the sub-quadratic long-context path).
"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    vocab_size=32000,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=64,
    hybrid_attn_every=6,
    long_context_window=4096,
)

REDUCED = CONFIG.replace(
    name="zamba2-2.7b-reduced",
    num_layers=4,
    d_model=64,
    vocab_size=256,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_chunk=8,
    hybrid_attn_every=2,
    long_context_window=64,
)
