"""ResNet-50 (v1.5) in JAX — the paper's own flagship workload.

MLModelScope's case studies (Table 2/3, Figs 4-8) revolve around TF-Slim
image-classification models with ResNet-50 as the representative. We carry
a ResNet-50 config so the Table-2/3/Fig-8 analogue benchmarks exercise the
same model family the paper measured. Reduced configs shrink width/depth
for CPU benchmarking.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .params import P, init_params, param_specs


@dataclass(frozen=True)
class ResNetConfig:
    name: str = "resnet50"
    stage_sizes: Tuple[int, ...] = (3, 4, 6, 3)
    width: int = 64
    num_classes: int = 1000
    img_size: int = 224

    def reduced(self) -> "ResNetConfig":
        return ResNetConfig(
            name=self.name + "-reduced",
            stage_sizes=(1, 1, 1, 1),
            width=16,
            num_classes=64,
            img_size=32,
        )


def _conv_defs(cin: int, cout: int, k: int) -> P:
    std = math.sqrt(2.0 / (k * k * cin))
    return P((k, k, cin, cout), std=std, axes=(None, None, None, "ffn"))


def _bn_defs(c: int) -> Dict[str, P]:
    # inference-mode batchnorm folded to scale+bias
    return {"scale": P((c,), "ones", axes=("ffn",)), "bias": P((c,), "zeros", axes=("ffn",))}


class ResNet:
    """Functional ResNet-50 v1.5 (stride-2 in the 3x3 of downsampling blocks)."""

    def __init__(self, cfg: ResNetConfig) -> None:
        self.cfg = cfg

    def param_defs(self):
        cfg = self.cfg
        w = cfg.width
        defs: Dict[str, Any] = {
            "stem": {"conv": _conv_defs(3, w, 7), "bn": _bn_defs(w)},
            "stages": [],
            "head": P((8 * w * 4, cfg.num_classes), std=0.01, axes=(None, "vocab")),
        }
        stages: List[Any] = []
        cin = w
        for i, n_blocks in enumerate(cfg.stage_sizes):
            cmid = w * (2 ** i)
            cout = cmid * 4
            blocks = []
            for b in range(n_blocks):
                blk = {
                    "conv1": _conv_defs(cin, cmid, 1), "bn1": _bn_defs(cmid),
                    "conv2": _conv_defs(cmid, cmid, 3), "bn2": _bn_defs(cmid),
                    "conv3": _conv_defs(cmid, cout, 1), "bn3": _bn_defs(cout),
                }
                if b == 0:
                    blk["proj"] = _conv_defs(cin, cout, 1)
                    blk["proj_bn"] = _bn_defs(cout)
                blocks.append(blk)
                cin = cout
            stages.append(blocks)
        defs["stages"] = {str(i): {str(b): blk for b, blk in enumerate(st)} for i, st in enumerate(stages)}
        return defs

    def init(self, rng, dtype=jnp.float32):
        return init_params(rng, self.param_defs(), dtype)

    def param_specs(self, dtype=jnp.float32):
        return param_specs(self.param_defs(), dtype)

    @staticmethod
    def _conv(x, w, stride=1):
        return jax.lax.conv_general_dilated(
            x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )

    @staticmethod
    def _bn(x, p):
        return x * p["scale"] + p["bias"]

    def forward(self, params, images: jnp.ndarray) -> jnp.ndarray:
        """images: (b, H, W, 3) float -> logits (b, num_classes)."""
        cfg = self.cfg
        x = self._conv(images, params["stem"]["conv"], stride=2)
        x = jax.nn.relu(self._bn(x, params["stem"]["bn"]))
        x = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 2, 2, 1), "SAME"
        )
        for i in range(len(cfg.stage_sizes)):
            stage = params["stages"][str(i)]
            for b in range(cfg.stage_sizes[i]):
                blk = stage[str(b)]
                stride = 2 if (b == 0 and i > 0) else 1
                residual = x
                y = jax.nn.relu(self._bn(self._conv(x, blk["conv1"]), blk["bn1"]))
                y = jax.nn.relu(self._bn(self._conv(y, blk["conv2"], stride), blk["bn2"]))
                y = self._bn(self._conv(y, blk["conv3"]), blk["bn3"])
                if "proj" in blk:
                    residual = self._bn(self._conv(x, blk["proj"], stride), blk["proj_bn"])
                x = jax.nn.relu(y + residual)
        x = jnp.mean(x, axis=(1, 2))
        return x @ params["head"]
