from .config import ArchConfig
from .lm import BaseModel, DecoderLM, EncDecLM, build_model
from .params import P, count_params, init_params, param_specs

__all__ = [
    "ArchConfig",
    "BaseModel",
    "DecoderLM",
    "EncDecLM",
    "P",
    "build_model",
    "count_params",
    "init_params",
    "param_specs",
]
