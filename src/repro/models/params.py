"""Parameter definition trees.

Models describe their parameters once as a tree of :class:`P` leaves; the
same tree yields (a) materialized params for smoke-scale runs, and (b)
``jax.ShapeDtypeStruct`` stand-ins for AOT dry-runs (no allocation), and
(c) a matching PartitionSpec tree via name-based sharding rules.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class P:
    """One parameter leaf definition.

    ``axes`` names each dimension with a *logical* axis (``embed``, ``heads``,
    ``ffn``, ``vocab``, ``experts``, …); :mod:`repro.sharding.specs` maps
    logical axes to mesh axes to derive PartitionSpecs without the model
    knowing anything about meshes.
    """

    shape: Tuple[int, ...]
    init: str = "normal"      # normal | zeros | ones | ssm_a | dt_bias
    std: float = 0.02         # stddev for `normal`
    dtype: Optional[str] = None  # override the model dtype for this leaf
    axes: Optional[Tuple[Optional[str], ...]] = None  # logical axis names per dim


def _is_leaf(x: Any) -> bool:
    return isinstance(x, P)


def tree_map_defs(fn, defs):
    """Map ``fn(path, P) -> value`` over a def tree, preserving structure."""

    def walk(path, node):
        if _is_leaf(node):
            return fn(path, node)
        if isinstance(node, dict):
            return {k: walk(f"{path}/{k}", v) for k, v in node.items()}
        raise TypeError(f"bad def node at {path}: {type(node)}")

    return walk("", defs)


def _leaf_key(path: str) -> int:
    return int.from_bytes(hashlib.sha256(path.encode()).digest()[:4], "little")


def init_params(rng: jax.Array, defs, dtype=jnp.float32):
    """Materialize a def tree (deterministic per-leaf folding of ``rng``)."""

    def make(path: str, p: P):
        ldtype = jnp.dtype(p.dtype) if p.dtype else jnp.dtype(dtype)
        key = jax.random.fold_in(rng, _leaf_key(path))
        if p.init == "normal":
            return (jax.random.normal(key, p.shape, jnp.float32) * p.std).astype(ldtype)
        if p.init == "zeros":
            return jnp.zeros(p.shape, ldtype)
        if p.init == "ones":
            return jnp.ones(p.shape, ldtype)
        if p.init == "ssm_a":
            # A_log in [log(1), log(16)] as in Mamba-2
            u = jax.random.uniform(key, p.shape, jnp.float32, 1.0, 16.0)
            return jnp.log(u).astype(ldtype)
        if p.init == "dt_bias":
            # bias such that softplus(dt_bias) spans [1e-3, 1e-1]
            u = jax.random.uniform(key, p.shape, jnp.float32, 1e-3, 1e-1)
            return jnp.log(jnp.expm1(u)).astype(ldtype)
        raise ValueError(f"unknown init {p.init!r} at {path}")

    return tree_map_defs(make, defs)


def param_specs(defs, dtype=jnp.float32):
    """ShapeDtypeStruct tree matching ``init_params`` (no allocation)."""

    def make(path: str, p: P):
        ldtype = jnp.dtype(p.dtype) if p.dtype else jnp.dtype(dtype)
        return jax.ShapeDtypeStruct(p.shape, ldtype)

    return tree_map_defs(make, defs)


def count_params(defs) -> int:
    total = 0

    def add(path: str, p: P):
        nonlocal total
        total += int(np.prod(p.shape))
        return None

    tree_map_defs(add, defs)
    return total


def tree_paths(defs) -> Dict[str, P]:
    """Flatten the def tree to {path: P}."""
    flat: Dict[str, P] = {}

    def grab(path: str, p: P):
        flat[path] = p
        return None

    tree_map_defs(grab, defs)
    return flat
