"""Block-level model components, functional style.

Every component provides ``*_defs(cfg, Lp)`` (a :class:`~repro.models.params.P`
tree, optionally stacked with leading dims ``Lp`` for scan-over-layers) and
apply functions for the full-sequence (train/prefill) and single-token
(decode) paths. All attention/SSD math routes through
:mod:`repro.kernels.ops` so the kernel backend is selectable per evaluation
(the platform's "framework" axis).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import kvquant, ops
from ..sharding.specs import opt_enabled, shard_act
from .config import ArchConfig
from .params import P


# ---------------------------------------------------------------------------
# Positional embeddings
# ---------------------------------------------------------------------------
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: (b, s, h, d); positions: (s,) or (b, s)."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)   # (half,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs           # (b, s, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    """Whisper-style fixed sinusoidal embedding. positions: (s,) -> (s, D)."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Norm + MLP
# ---------------------------------------------------------------------------
def norm_defs(cfg: ArchConfig, Lp: Tuple[int, ...]) -> P:
    return P(Lp + (cfg.d_model,), "zeros", axes=_ax(Lp) + ("embed",))


def _ax(Lp: Tuple[int, ...]) -> Tuple[Optional[str], ...]:
    return ("layer",) * len(Lp)


def mlp_defs(
    cfg: ArchConfig, Lp: Tuple[int, ...], gated: bool = True, d_ff: int = 0
) -> Dict[str, P]:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    std_in = 0.02
    std_out = 0.02 / math.sqrt(2 * max(cfg.num_layers, 1))
    la = _ax(Lp)
    defs = {
        "w_up": P(Lp + (D, F), std=std_in, axes=la + ("embed", "ffn")),
        "w_down": P(Lp + (F, D), std=std_out, axes=la + ("ffn", "embed")),
    }
    if gated:
        defs["w_gate"] = P(Lp + (D, F), std=std_in, axes=la + ("embed", "ffn"))
    return defs


def mlp_apply(p: Dict[str, jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    up = x @ p["w_up"]
    if "w_gate" in p:
        h = jax.nn.silu(x @ p["w_gate"]) * up
    else:
        h = jax.nn.gelu(up)
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def attn_defs(cfg: ArchConfig, Lp: Tuple[int, ...], cross: bool = False) -> Dict[str, P]:
    D, H, KV, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    std_in = 0.02
    std_out = 0.02 / math.sqrt(2 * max(cfg.num_layers, 1))
    la = _ax(Lp)
    defs = {
        "wq": P(Lp + (D, H, dh), std=std_in, axes=la + ("embed", "heads", "head_dim")),
        "wk": P(Lp + (D, KV, dh), std=std_in, axes=la + ("embed", "kv", "head_dim")),
        "wv": P(Lp + (D, KV, dh), std=std_in, axes=la + ("embed", "kv", "head_dim")),
        "wo": P(Lp + (H, dh, D), std=std_out, axes=la + ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm and not cross:
        defs["q_norm"] = P(Lp + (dh,), "zeros", axes=la + ("head_dim",))
        defs["k_norm"] = P(Lp + (dh,), "zeros", axes=la + ("head_dim",))
    return defs


def _project_qkv(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,
    cfg: ArchConfig,
    positions: Optional[jnp.ndarray],
    backend: str,
    kv_from: Optional[jnp.ndarray] = None,
):
    """Project q (from x) and k/v (from kv_from or x); apply qk-norm + RoPE."""
    src = x if kv_from is None else kv_from
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if "q_norm" in p:
        q = ops.rmsnorm(q, p["q_norm"], cfg.norm_eps, backend=backend)
        k = ops.rmsnorm(k, p["k_norm"], cfg.norm_eps, backend=backend)
    if positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        kv_positions = positions if kv_from is None else jnp.arange(src.shape[1])
        k = rope(k, kv_positions, cfg.rope_theta)
    return q, k, v


def attn_full(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,                       # (b, s, D)
    cfg: ArchConfig,
    *,
    backend: str,
    causal: bool = True,
    window=None,
    use_rope: bool = True,
    kv_from: Optional[jnp.ndarray] = None,   # cross-attention source
    q_offset: int = 0,
    return_kv: bool = False,
):
    """Full-sequence attention (training / prefill)."""
    b, s, _ = x.shape
    positions = (q_offset + jnp.arange(s)) if use_rope else None
    q, k, v = _project_qkv(p, x, cfg, positions, backend, kv_from)
    if opt_enabled("gather_kv_once"):
        # with a seq-sharded residual (SP), K/V inherit the seq sharding and
        # the flash KV-block scan would all-gather them once PER BLOCK;
        # constraining them seq-replicated here gathers once per layer
        k = shard_act(k, ("batch", None, "act_kv", None))
        v = shard_act(v, ("batch", None, "act_kv", None))
    out = ops.attention(
        q, k, v,
        causal=causal and kv_from is None,
        window=window,
        softcap=cfg.attn_softcap,
        q_offset=q_offset,
        backend=backend,
    )
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if return_kv:
        return y, (k, v)
    return y


def attn_decode(
    p: Dict[str, jnp.ndarray],
    x1: jnp.ndarray,                      # (b, 1, D) — one new token
    k_cache: jnp.ndarray,                 # (b, S, kv, dh)
    v_cache: jnp.ndarray,
    pos: jnp.ndarray,                     # (b,) absolute position of the new token
    cfg: ArchConfig,
    *,
    backend: str,
    window=None,
    use_rope: bool = True,
    ring: bool = False,                   # ring-buffer cache (windowed long context)
    uniform_pos: bool = True,             # all rows share one decode position
    kv_bound: Optional[int] = None,       # static bound on lengths (serving)
):
    """Single-token attention against a KV cache; returns (y, k_cache, v_cache)."""
    b = x1.shape[0]
    S = k_cache.shape[1]
    positions = pos[:, None] if use_rope else None
    q, k, v = _project_qkv(p, x1, cfg, positions, backend)
    slot = (pos % S) if ring else pos
    if uniform_pos:
        # dynamic-update-slice at a scalar offset: GSPMD partitions it on any
        # cache sharding AND XLA aliases it in-place inside the layer scan
        # (no second cache buffer). Batched serving left-pads so positions
        # are uniform; ragged continuous batching uses the masked path below.
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k[:, :1].astype(k_cache.dtype), (0, slot[0], 0, 0)
        )
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v[:, :1].astype(v_cache.dtype), (0, slot[0], 0, 0)
        )
    else:
        # masked (elementwise) update: GSPMD-native for per-row positions
        sel = (jnp.arange(S)[None, :] == slot[:, None])[:, :, None, None]
        k_cache = jnp.where(sel, k[:, :1].astype(k_cache.dtype), k_cache)
        v_cache = jnp.where(sel, v[:, :1].astype(v_cache.dtype), v_cache)
    lengths = jnp.minimum(pos + 1, S) if ring else pos + 1
    out = ops.decode_attention(
        q, k_cache, v_cache, lengths,
        softcap=cfg.attn_softcap,
        window=window if not ring else None,   # ring cache is already windowed
        backend=backend,
        # a ring cache's live tokens wrap the whole buffer: never bound it
        kv_bound=None if ring else kv_bound,
    )
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, k_cache, v_cache


def attn_decode_paged(
    p: Dict[str, jnp.ndarray],
    x1: jnp.ndarray,                      # (b, 1, D) — one new token per slot
    k_pages: jnp.ndarray,                 # (num_pages, page_size, kv, dh)
    v_pages: jnp.ndarray,
    page_table: jnp.ndarray,              # (b, max_pages) int32
    pos: jnp.ndarray,                     # (b,) position of the new token
    cfg: ArchConfig,
    *,
    backend: str,
    window=None,
    use_rope: bool = True,
    pages_bound: Optional[int] = None,
    k_scales: Optional[jnp.ndarray] = None,  # (num_pages, page_size, kv) f32
    v_scales: Optional[jnp.ndarray] = None,
):
    """Single-token attention against a paged KV pool.

    The new token's K/V are appended to the page holding logical position
    ``pos`` (a per-row scatter through the page table); attention then runs
    over only the request's live pages.  With a quantized pool the append
    quantizes the new rows and scatters their scales at the same indices.
    Returns (y, k_pages, v_pages) — plus the scale pools when quantized.
    """
    b = x1.shape[0]
    page_size = k_pages.shape[1]
    positions = pos[:, None] if use_rope else None
    q, k, v = _project_qkv(p, x1, cfg, positions, backend)
    page_ids = page_table[jnp.arange(b), pos // page_size]    # (b,)
    offsets = pos % page_size
    if k_scales is not None:
        kq, ks = kvquant.quantize(k[:, 0], k_pages.dtype)
        vq, vs = kvquant.quantize(v[:, 0], v_pages.dtype)
        k_pages = k_pages.at[page_ids, offsets].set(kq)
        v_pages = v_pages.at[page_ids, offsets].set(vq)
        k_scales = k_scales.at[page_ids, offsets].set(ks)
        v_scales = v_scales.at[page_ids, offsets].set(vs)
    else:
        k_pages = k_pages.at[page_ids, offsets].set(k[:, 0].astype(k_pages.dtype))
        v_pages = v_pages.at[page_ids, offsets].set(v[:, 0].astype(v_pages.dtype))
    out = ops.paged_attention(
        q, k_pages, v_pages, page_table, pos + 1,
        softcap=cfg.attn_softcap,
        window=window,
        backend=backend,
        pages_bound=pages_bound,
        k_scales=k_scales,
        v_scales=v_scales,
    )
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if opt_enabled("rs_block_outputs"):
        y = shard_act(y, ("batch", "seq", "act_embed"))
    if k_scales is not None:
        return y, k_pages, v_pages, k_scales, v_scales
    return y, k_pages, v_pages


def attn_decode_spec(
    p: Dict[str, jnp.ndarray],
    xw: jnp.ndarray,                      # (b, W, D) — one in-flight window/slot
    k_pages: jnp.ndarray,                 # (num_pages, page_size, kv, dh)
    v_pages: jnp.ndarray,
    page_table: jnp.ndarray,              # (b, max_pages) int32
    lengths: jnp.ndarray,                 # (b,) committed tokens before window
    window_lens: jnp.ndarray,             # (b,) real window tokens (0..W)
    cfg: ArchConfig,
    *,
    backend: str,
    window=None,
    use_rope: bool = True,
    pages_bound: Optional[int] = None,
    k_scales: Optional[jnp.ndarray] = None,  # (num_pages, page_size, kv) f32
    v_scales: Optional[jnp.ndarray] = None,
):
    """Speculative-verification attention: score a whole ``[next_token,
    draft_1..draft_k]`` window per slot against the paged pool in one launch.

    The window's K/V are scattered into the request's pages FIRST (positions
    ``lengths[b] + w`` through the page table — the multi-token form of the
    decode append), then every query attends its absolute-position causal
    prefix, so the window's own tokens are visible exactly like a sequence
    of one-token decode steps.  Rows past ``window_lens[b]`` (window pad /
    idle slots) scatter into positions the length mask never reads — pages
    are append-only, so a rejected suffix rolls back by just rewinding
    ``lengths``.  Returns (y, k_pages, v_pages) — plus the scale pools when
    quantized.
    """
    b, W, _ = xw.shape
    page_size = k_pages.shape[1]
    max_pages = page_table.shape[1]
    tok_pos = lengths[:, None] + jnp.arange(W, dtype=jnp.int32)[None, :]
    positions = tok_pos if use_rope else None
    q, k, v = _project_qkv(p, xw, cfg, positions, backend)
    # clamp pad positions that overhang the table width; real window tokens
    # always have a page (the engine grows tables before the launch)
    pidx = jnp.minimum(tok_pos // page_size, max_pages - 1)
    page_ids = jnp.take_along_axis(page_table, pidx, axis=1)   # (b, W)
    offsets = tok_pos % page_size
    if k_scales is not None:
        kq, ks = kvquant.quantize(k, k_pages.dtype)
        vq, vs = kvquant.quantize(v, v_pages.dtype)
        k_pages = k_pages.at[page_ids, offsets].set(kq)
        v_pages = v_pages.at[page_ids, offsets].set(vq)
        k_scales = k_scales.at[page_ids, offsets].set(ks)
        v_scales = v_scales.at[page_ids, offsets].set(vs)
    else:
        k_pages = k_pages.at[page_ids, offsets].set(k.astype(k_pages.dtype))
        v_pages = v_pages.at[page_ids, offsets].set(v.astype(v_pages.dtype))
    out = ops.spec_verify(
        q, k_pages, v_pages, page_table, lengths, window_lens,
        softcap=cfg.attn_softcap,
        window=window,
        backend=backend,
        pages_bound=pages_bound,
        k_scales=k_scales,
        v_scales=v_scales,
    )
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if opt_enabled("rs_block_outputs"):
        y = shard_act(y, ("batch", "seq", "act_embed"))
    if k_scales is not None:
        return y, k_pages, v_pages, k_scales, v_scales
    return y, k_pages, v_pages


def attn_prefill_paged(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,                       # (1, c, D) — one prompt chunk
    k_pages: jnp.ndarray,                 # (num_pages, page_size, kv, dh)
    v_pages: jnp.ndarray,
    page_row: jnp.ndarray,                # (max_pages,) int32 — this request's pages
    pos0: int,                            # static absolute position of x[0]
    cfg: ArchConfig,
    *,
    backend: str,
    window=None,
    k_scales: Optional[jnp.ndarray] = None,  # (num_pages, page_size, kv) f32
    v_scales: Optional[jnp.ndarray] = None,
):
    """One chunked-prefill step: attend the chunk to the request's already-
    paged context plus itself (causal), then append the chunk's K/V to the
    pages.  ``pos0`` must be a multiple of ``page_size`` (chunk sizes are),
    so the context occupies exactly the first ``pos0 // page_size`` pages.
    The chunk may be right-padded to a page multiple: causal attention keeps
    pad rows invisible to real rows, and pad K/V lands in positions the
    decode path masks (by length) until it overwrites them.  With a
    quantized pool the gathered context dequantizes through its scale rows
    and the append quantizes the chunk.  Returns (y, k_pages, v_pages) —
    plus the scale pools when quantized.
    """
    c = x.shape[1]
    page_size = k_pages.shape[1]
    if pos0 % page_size:
        raise ValueError(f"chunk start {pos0} not page-aligned ({page_size})")
    positions = pos0 + jnp.arange(c)
    q, k, v = _project_qkv(p, x, cfg, positions, backend)
    n_ctx = pos0 // page_size
    if n_ctx:
        kctx = k_pages[page_row[:n_ctx]].reshape(1, pos0, *k_pages.shape[2:])
        vctx = v_pages[page_row[:n_ctx]].reshape(1, pos0, *v_pages.shape[2:])
        if k_scales is not None:
            ksc = k_scales[page_row[:n_ctx]].reshape(1, pos0, k_scales.shape[-1])
            vsc = v_scales[page_row[:n_ctx]].reshape(1, pos0, v_scales.shape[-1])
            kctx = kctx.astype(jnp.float32) * ksc[..., None]
            vctx = vctx.astype(jnp.float32) * vsc[..., None]
        k_all = jnp.concatenate([kctx.astype(k.dtype), k], axis=1)
        v_all = jnp.concatenate([vctx.astype(v.dtype), v], axis=1)
    else:
        k_all, v_all = k, v
    out = ops.attention(
        q, k_all, v_all,
        causal=True,
        window=window,
        softcap=cfg.attn_softcap,
        q_offset=pos0,
        backend=backend,
    )
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if opt_enabled("rs_block_outputs"):
        y = shard_act(y, ("batch", "seq", "act_embed"))
    tok_pos = pos0 + jnp.arange(c)
    page_ids = page_row[tok_pos // page_size]
    offsets = tok_pos % page_size
    if k_scales is not None:
        kq, ks = kvquant.quantize(k[0], k_pages.dtype)
        vq, vs = kvquant.quantize(v[0], v_pages.dtype)
        k_pages = k_pages.at[page_ids, offsets].set(kq)
        v_pages = v_pages.at[page_ids, offsets].set(vq)
        k_scales = k_scales.at[page_ids, offsets].set(ks)
        v_scales = v_scales.at[page_ids, offsets].set(vs)
        return y, k_pages, v_pages, k_scales, v_scales
    k_pages = k_pages.at[page_ids, offsets].set(k[0].astype(k_pages.dtype))
    v_pages = v_pages.at[page_ids, offsets].set(v[0].astype(v_pages.dtype))
    return y, k_pages, v_pages


def attn_prefill_packed(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,                       # (1, T, D) — token-packed chunks
    k_pages: jnp.ndarray,                 # (num_pages, page_size, kv, dh)
    v_pages: jnp.ndarray,
    meta: Dict[str, jnp.ndarray],         # packing metadata (see below)
    cfg: ArchConfig,
    *,
    backend: str,
    window=None,
    pages_bound: Optional[int] = None,
    k_scales: Optional[jnp.ndarray] = None,  # (num_pages, page_size, kv) f32
    v_scales: Optional[jnp.ndarray] = None,
):
    """One packed varlen-prefill step: chunks from many requests share the
    packed buffer; each attends its request's committed pages plus the
    causal prefix of its own tokens, and the packed K/V are scattered
    straight into the paged pool (the per-row append path, fused over every
    chunk at once).  ``meta`` carries the packing layout:

    * ``tok_pos``     (T,)   absolute position per packed token
    * ``dst_page``/``dst_off`` (T,) physical K/V destination per token
      (buffer-tail pads point at the scratch page)
    * ``cu_seqlens``  (C+1,) packed chunk boundaries (page-aligned spans)
    * ``chunk_lens``  (C,)   real tokens per chunk
    * ``chunk_pos0``  (C,)   absolute chunk starts (page-aligned)
    * ``page_tables`` (C, max_pages) the owning requests' pages

    Returns (y, k_pages, v_pages) — plus the scale pools when quantized.
    """
    positions = meta["tok_pos"][None, :]
    q, k, v = _project_qkv(p, x, cfg, positions, backend)
    out = ops.varlen_prefill(
        q[0], k[0], v[0], k_pages, v_pages,
        meta["cu_seqlens"], meta["chunk_lens"], meta["chunk_pos0"],
        meta["page_tables"],
        softcap=cfg.attn_softcap,
        window=window,
        backend=backend,
        pages_bound=pages_bound,
        k_scales=k_scales,
        v_scales=v_scales,
    )
    y = jnp.einsum("bshk,hkd->bsd", out[None], p["wo"])
    if opt_enabled("rs_block_outputs"):
        y = shard_act(y, ("batch", "seq", "act_embed"))
    if k_scales is not None:
        kq, ks = kvquant.quantize(k[0], k_pages.dtype)
        vq, vs = kvquant.quantize(v[0], v_pages.dtype)
        k_pages = k_pages.at[meta["dst_page"], meta["dst_off"]].set(kq)
        v_pages = v_pages.at[meta["dst_page"], meta["dst_off"]].set(vq)
        k_scales = k_scales.at[meta["dst_page"], meta["dst_off"]].set(ks)
        v_scales = v_scales.at[meta["dst_page"], meta["dst_off"]].set(vs)
        return y, k_pages, v_pages, k_scales, v_scales
    k_pages = k_pages.at[meta["dst_page"], meta["dst_off"]].set(
        k[0].astype(k_pages.dtype)
    )
    v_pages = v_pages.at[meta["dst_page"], meta["dst_off"]].set(
        v[0].astype(v_pages.dtype)
    )
    return y, k_pages, v_pages


def cross_attn_decode(
    p: Dict[str, jnp.ndarray],
    x1: jnp.ndarray,                      # (b, 1, D)
    k_cross: jnp.ndarray,                 # (b, Se, kv, dh) — precomputed at prefill
    v_cross: jnp.ndarray,
    cfg: ArchConfig,
    *,
    backend: str,
):
    q = jnp.einsum("bsd,dhk->bshk", x1, p["wq"])
    Se = k_cross.shape[1]
    lengths = jnp.full((x1.shape[0],), Se, jnp.int32)
    out = ops.decode_attention(q, k_cross, v_cross, lengths, backend=backend)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------
def moe_defs(cfg: ArchConfig, Lp: Tuple[int, ...]) -> Dict[str, P]:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    std_in = 0.02
    std_out = 0.02 / math.sqrt(2 * max(cfg.num_layers, 1))
    la = _ax(Lp)
    # experts shard the model axis (EP); the per-expert FFN dim stays local
    # ("expert_ffn" -> None) so specs never map one mesh axis twice.
    return {
        "router": P(Lp + (D, E), std=std_in, axes=la + ("embed", None)),
        "w_gate": P(Lp + (E, D, F), std=std_in, axes=la + ("experts", "embed", "expert_ffn")),
        "w_up": P(Lp + (E, D, F), std=std_in, axes=la + ("experts", "embed", "expert_ffn")),
        "w_down": P(Lp + (E, F, D), std=std_out, axes=la + ("experts", "expert_ffn", "embed")),
    }


def _positions_in_expert(eid: jnp.ndarray, num_experts: int) -> jnp.ndarray:
    """Per-token rank within its expert's queue. eid: (n,) -> (n,)."""
    n = eid.shape[0]
    order = jnp.argsort(eid, stable=True)
    ranks = jnp.zeros((n,), jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))
    sorted_eid = eid[order]
    seg_starts = jnp.searchsorted(sorted_eid, jnp.arange(num_experts, dtype=eid.dtype))
    return ranks - seg_starts[eid].astype(jnp.int32)


def moe_apply(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,                       # (b, s, D)
    cfg: ArchConfig,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Capacity-based top-k routing with scatter dispatch (no (T,E,C) one-hot).

    Groups = batch rows; per-group capacity C = cf * s * k / E. Tokens over
    capacity are dropped (standard Switch behaviour). Returns (out, aux_loss).
    """
    b, s, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    C = max(int(cfg.capacity_factor * s * K / E), 1)
    C = min(C, s * K)

    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, K)                     # (b, s, K)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * sum_e f_e * P_e
    me = probs.mean(axis=(0, 1))                                # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (b * s * K)
    aux = E * jnp.sum(me * ce)

    if s == 1 and opt_enabled("moe_decode_gather"):
        # decode fast path: compute ONLY the selected experts by gathering
        # their weights (K·D·F reads per token instead of running every
        # expert over mostly-empty capacity slots)
        sel = idx[:, 0]                                          # (b, K)
        xt = x[:, 0]                                             # (b, D)
        wg = p["w_gate"][sel]                                    # (b, K, D, F)
        wu = p["w_up"][sel]
        wd = p["w_down"][sel]                                    # (b, K, F, D)
        hg = jax.nn.silu(jnp.einsum("bd,bkdf->bkf", xt, wg))
        hu = jnp.einsum("bd,bkdf->bkf", xt, wu)
        expert_out = jnp.einsum("bkf,bkfd->bkd", hg * hu, wd)    # (b, K, D)
        comb = jnp.einsum("bkd,bk->bd", expert_out, weights[:, 0].astype(expert_out.dtype))
        return comb[:, None, :].astype(x.dtype), aux

    eid = idx.reshape(b, s * K).astype(jnp.int32)               # (b, n) slots
    pos = jax.vmap(lambda e: _positions_in_expert(e, E))(eid)   # (b, n)
    x_slots = jnp.broadcast_to(x[:, :, None, :], (b, s, K, D)).reshape(b, s * K, D)

    # dispatch: (b, E, C, D); slots with pos >= C are dropped.
    # The scatter runs with E *unsharded* (batch-sharded buffer) — a scatter
    # into an expert-sharded buffer would make GSPMD gather it. The reshard
    # to expert-sharded happens right before the expert matmul: that pair of
    # constraints IS the MoE all-to-all.
    buf = jnp.zeros((b, E, C, D), x.dtype)
    buf = shard_act(buf, ("batch", None, None, None))
    # vmapped scatter: the batch dim becomes an explicit scatter batch dim,
    # which GSPMD partitions instead of replicating the buffer
    buf = jax.vmap(
        lambda bb, e, p2, xs: bb.at[e, p2].set(xs, mode="drop")
    )(buf, eid, pos, x_slots)
    buf = shard_act(buf, ("batch", "act_experts", None, None))

    # expert computation (experts sharded over the model axis)
    hg = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["w_gate"]))
    hu = jnp.einsum("becd,edf->becf", buf, p["w_up"])
    out_buf = jnp.einsum("becf,efd->becd", hg * hu, p["w_down"])
    out_buf = shard_act(out_buf, ("batch", None, None, None))

    # combine: gather back (vmapped, batch-partitioned), zero dropped slots
    gathered = jax.vmap(lambda ob, e, p2: ob[e, p2])(
        out_buf, eid, jnp.minimum(pos, C - 1)
    )                                                           # (b, n, D)
    valid = (pos < C)[..., None]
    gathered = jnp.where(valid, gathered, 0.0)
    gathered = gathered.reshape(b, s, K, D)
    out = jnp.einsum("bskd,bsk->bsd", gathered, weights.astype(gathered.dtype))
    return out.astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Mamba-2 (SSD) block
# ---------------------------------------------------------------------------
def mamba_defs(cfg: ArchConfig, Lp: Tuple[int, ...]) -> Dict[str, P]:
    D = cfg.d_model
    din, n, h, K = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, cfg.conv_kernel
    conv_dim = din + 2 * n
    std_out = 0.02 / math.sqrt(2 * max(cfg.num_layers, 1))
    la = _ax(Lp)
    return {
        "in_proj": P(Lp + (D, 2 * din + 2 * n + h), std=0.02, axes=la + ("embed", "inner_all")),
        "conv_w": P(Lp + (K, conv_dim), std=0.2, axes=la + (None, "conv_dim")),
        "conv_b": P(Lp + (conv_dim,), "zeros", axes=la + ("conv_dim",)),
        "A_log": P(Lp + (h,), "ssm_a", dtype="float32", axes=la + ("ssm_heads",)),
        "D": P(Lp + (h,), "ones", dtype="float32", axes=la + ("ssm_heads",)),
        "dt_bias": P(Lp + (h,), "dt_bias", dtype="float32", axes=la + ("ssm_heads",)),
        "norm": P(Lp + (din,), "zeros", axes=la + ("inner",)),
        "out_proj": P(Lp + (din, D), std=std_out, axes=la + ("inner", "embed")),
    }


def _mamba_split(cfg: ArchConfig, zxbcdt: jnp.ndarray):
    din, n, h = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = din + 2 * n
    z = zxbcdt[..., :din]
    xBC = zxbcdt[..., din : din + conv_dim]
    dt_raw = zxbcdt[..., din + conv_dim :]
    return z, xBC, dt_raw


def causal_conv1d(
    x: jnp.ndarray,                        # (b, s, C)
    w: jnp.ndarray,                        # (K, C) depthwise taps
    bias: jnp.ndarray,                     # (C,)
    init: Optional[jnp.ndarray] = None,    # (b, K-1, C) carried state
) -> jnp.ndarray:
    K = w.shape[0]
    b, s, C = x.shape
    if init is None:
        init = jnp.zeros((b, K - 1, C), x.dtype)
    xp = jnp.concatenate([init.astype(x.dtype), x], axis=1)     # (b, s+K-1, C)
    y = sum(xp[:, i : i + s] * w[i] for i in range(K))
    return jax.nn.silu(y + bias)


def mamba_forward(
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,                        # (b, s, D)
    cfg: ArchConfig,
    *,
    backend: str,
    ssm_state: Optional[jnp.ndarray] = None,
    conv_state: Optional[jnp.ndarray] = None,
    return_state: bool = False,
):
    b, s, D = x.shape
    din, n, h = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    ph = cfg.ssm_head_dim
    zxbcdt = x @ p["in_proj"]
    z, xBC_raw, dt_raw = _mamba_split(cfg, zxbcdt)
    xBC = causal_conv1d(xBC_raw, p["conv_w"], p["conv_b"], init=conv_state)
    x_in = xBC[..., :din]
    B = xBC[..., din : din + n]
    C = xBC[..., din + n :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = x_in.reshape(b, s, h, ph)
    if opt_enabled("ssd_shard_p"):
        # SSD math is pointwise in the head_dim p: shard p over "model" so
        # the scan computes 1/16th per chip instead of replicating (used when
        # the head count — e.g. mamba2's 24 — cannot split the model axis)
        xh = shard_act(xh, ("batch", None, None, "ssm_p"))
    result = ops.ssd(
        xh, dt, A, B, C,
        chunk=cfg.ssm_chunk,
        initial_state=ssm_state,
        return_state=return_state,
        backend=backend,
    )
    if return_state:
        y, final_state = result
    else:
        y = result
    if opt_enabled("ssd_shard_p"):
        y = shard_act(y, ("batch", None, None, "ssm_p"))
    y = y + p["D"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(b, s, din)
    y = ops.rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps, backend=backend)
    out = y @ p["out_proj"]
    if return_state:
        km1 = cfg.conv_kernel - 1
        conv_dim = xBC_raw.shape[-1]
        prev = (
            conv_state.astype(xBC_raw.dtype)
            if conv_state is not None
            else jnp.zeros((b, km1, conv_dim), xBC_raw.dtype)
        )
        hist = jnp.concatenate([prev, xBC_raw], axis=1)
        new_conv = hist[:, hist.shape[1] - km1 :] if km1 else hist[:, :0]
        return out, final_state, new_conv
    return out


def mamba_step(
    p: Dict[str, jnp.ndarray],
    x1: jnp.ndarray,                       # (b, D) — one token
    ssm_state: jnp.ndarray,                # (b, h, ph, n)
    conv_state: jnp.ndarray,               # (b, K-1, conv_dim)
    cfg: ArchConfig,
    *,
    backend: str,
):
    din, n, h = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    ph = cfg.ssm_head_dim
    zxbcdt = x1 @ p["in_proj"]
    z, xBC_raw, dt_raw = _mamba_split(cfg, zxbcdt)
    window = jnp.concatenate([conv_state.astype(xBC_raw.dtype), xBC_raw[:, None]], axis=1)
    y_conv = sum(window[:, i] * p["conv_w"][i] for i in range(cfg.conv_kernel))
    xBC = jax.nn.silu(y_conv + p["conv_b"])
    new_conv_state = window[:, 1:]
    x_in = xBC[..., :din]
    B = xBC[..., din : din + n]
    C = xBC[..., din + n :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = x_in.reshape(-1, h, ph)
    y, new_ssm = ops.ssd_step(xh, dt, A, B, C, ssm_state, backend=backend)
    y = y + p["D"][None, :, None].astype(y.dtype) * xh
    y = y.reshape(-1, din)
    y = ops.rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps, backend=backend)
    return y @ p["out_proj"], new_ssm, new_conv_state.astype(conv_state.dtype)
