"""Language models: decoder-only (dense / MoE / SSM / hybrid) and enc-dec.

All models share one API so the platform's predictor, the launcher, and the
dry-run treat every architecture uniformly:

* ``param_defs()`` / ``init(rng, dtype)`` / ``param_specs(dtype)``
* ``forward(params, batch) -> (logits, aux)`` — full-sequence (training)
* ``init_cache(batch, max_seq, dtype)`` / ``cache_specs(...)``
* ``prefill(params, batch, cache) -> (last_logits, cache)``
* ``decode(params, tokens, cache) -> (logits, cache)`` — one token step

Layers are stacked and scanned (``lax.scan``) so compile time and HLO size
are depth-independent — required for 95-layer × 512-device dry-runs.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import kvquant, ops
from ..sharding.specs import opt_enabled, param_pspecs, shard_act
from .config import ArchConfig
from .modules import (
    attn_decode,
    attn_decode_paged,
    attn_decode_spec,
    attn_defs,
    attn_full,
    attn_prefill_packed,
    attn_prefill_paged,
    causal_conv1d,
    cross_attn_decode,
    mamba_defs,
    mamba_forward,
    mamba_step,
    mlp_apply,
    mlp_defs,
    moe_apply,
    moe_defs,
    norm_defs,
    sinusoidal,
)
from .params import P, init_params, param_specs

_BIG_WINDOW = jnp.int32(1 << 30)
# serve caches longer than this switch to a ring buffer of
# ``cfg.long_context_window`` slots (hybrid archs only; attn-free SSM has no cache)
_RING_THRESHOLD = 65_536


class BaseModel:
    def __init__(
        self,
        cfg: ArchConfig,
        backend: str = ops.DEFAULT_BACKEND,
        compute_dtype=None,
    ) -> None:
        cfg.validate()
        self.cfg = cfg
        self.backend = backend
        # mixed precision: weights cast per-layer inside the scan body so only
        # one layer's low-precision copy is live at a time
        self.compute_dtype = jnp.dtype(compute_dtype) if compute_dtype else None

    def _cast(self, tree):
        if self.compute_dtype is None:
            return tree
        cd = self.compute_dtype

        def cast(t):
            return t.astype(cd) if t.dtype in (jnp.float32, jnp.float64) else t

        return jax.tree.map(cast, tree)

    def _cast_mamba(self, blk):
        """Cast a mamba block, keeping the fp32 SSD scalars (A/D/dt) exact."""
        if self.compute_dtype is None:
            return blk
        keep = {"A_log", "D", "dt_bias"}
        out = dict(blk)
        out["mamba"] = {
            k: (v if k in keep else self._cast(v)) for k, v in blk["mamba"].items()
        }
        out["ln"] = self._cast(blk["ln"])
        return out

    # -- params ---------------------------------------------------------------
    def param_defs(self):
        raise NotImplementedError

    def init(self, rng: jax.Array, dtype=jnp.float32):
        return init_params(rng, self.param_defs(), dtype)

    def param_specs(self, dtype=jnp.float32):
        return param_specs(self.param_defs(), dtype)

    # -- helpers ----------------------------------------------------------------
    def _norm(self, x, w):
        return ops.rmsnorm(x, w, self.cfg.norm_eps, backend=self.backend)

    def _embed_tokens(self, params, tokens):
        x = jnp.take(params["embed"], tokens, axis=0)
        if self.compute_dtype is not None:
            x = x.astype(self.compute_dtype)
        if self.cfg.scale_embed:
            x = x * math.sqrt(self.cfg.d_model)
        return x

    def _logits(self, params, x):
        cfg = self.cfg
        x = self._norm(x, self._cast(params["final_norm"]))
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        head = self._cast(head)
        # MXU matmul in compute dtype, fp32 accumulation/output
        logits = jnp.einsum(
            "bsd,dv->bsv", x.astype(head.dtype), head,
            preferred_element_type=jnp.float32,
        )
        if cfg.logit_softcap > 0:
            logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
        return shard_act(logits, ("batch", "seq", "act_vocab"))

    def _prefill_logits(self, params, batch, x, new_cache, b, s):
        """Last-token logits + per-row positions.  With ``batch["lengths"]``
        prompts are RIGHT-padded to a common (bucketed) length: causal
        attention never reads the trailing pads, so logits gathered at
        ``lengths - 1`` are exactly the unpadded values — prefill shapes can
        be bucketed without changing numerics.  SSM/hybrid state scans the
        whole row (pads included), so only attention families may be ragged.
        """
        lengths = batch.get("lengths")
        if lengths is None:
            new_cache["pos"] = jnp.full((b,), s, jnp.int32)
            x_last = x[:, -1:, :]
        else:
            if self.cfg.family not in ("dense", "moe", "encdec"):
                raise NotImplementedError(
                    "ragged (right-padded) prefill requires a pure-attention "
                    "cache; ssm/hybrid state would absorb the pad tokens"
                )
            lengths = jnp.asarray(lengths, jnp.int32)
            new_cache["pos"] = lengths
            x_last = x[jnp.arange(b), lengths - 1][:, None, :]
        logits = self._logits(params, x_last)[:, 0]
        return logits, new_cache



def _scan_cached(body, x0, per_layer_xs, stacks, length):
    """Scan over layers with cache STACKS carried (not xs/ys).

    ``body(x, xs_l, caches_l, li) -> (x, new_caches_l)``. Each step
    dynamic-slices layer ``li`` from every stack and writes the update back
    with a dynamic-update-slice on the carry — the in-place while-loop
    pattern XLA aliases to a single buffer (a cache passed as scan xs/ys
    would be double-buffered, and hoisted dtype-converts could materialize
    whole-stack copies)."""

    def wrapped(carry, xs):
        x, stacks_c = carry
        xs_l, li = xs
        caches_l = {
            k: jax.lax.dynamic_index_in_dim(v, li, 0, keepdims=False)
            for k, v in stacks_c.items()
        }
        x, new_l = body(x, xs_l, caches_l, li)
        stacks_n = {
            k: jax.lax.dynamic_update_index_in_dim(
                stacks_c[k], new_l[k].astype(stacks_c[k].dtype), li, 0
            )
            if k in new_l
            else stacks_c[k]
            for k in stacks_c
        }
        return (x, stacks_n), None

    (x, stacks), _ = jax.lax.scan(
        wrapped, (x0, dict(stacks)), (per_layer_xs, jnp.arange(length))
    )
    return x, stacks


# =============================================================================
# Decoder-only LM (dense / moe / ssm / hybrid)
# =============================================================================
class DecoderLM(BaseModel):
    # -- parameter definitions -------------------------------------------------
    def param_defs(self):
        cfg = self.cfg
        V, D, L = cfg.vocab_size, cfg.d_model, cfg.num_layers
        defs: Dict[str, Any] = {
            "embed": P((V, D), std=0.02, axes=("vocab", "embed")),
            "blocks": self._block_defs((L,)),
            "final_norm": norm_defs(cfg, ()),
        }
        if cfg.family == "hybrid":
            defs["shared"] = {
                "ln1": norm_defs(cfg, ()),
                "attn": attn_defs(cfg, ()),
                "ln2": norm_defs(cfg, ()),
                "mlp": mlp_defs(cfg, ()),
            }
        if not cfg.tie_embeddings:
            defs["lm_head"] = P((D, V), std=0.02, axes=("embed", "vocab"))
        return defs

    def _block_defs(self, Lp: Tuple[int, ...]):
        cfg = self.cfg
        if cfg.family in ("ssm", "hybrid"):
            return {"ln": norm_defs(cfg, Lp), "mamba": mamba_defs(cfg, Lp)}
        if cfg.family == "moe" and cfg.moe_every == 2:
            # llama4-style interleave: scan over (dense, moe) super-layers
            L2 = (Lp[0] // 2,)
            return {
                "a": self._attn_block_defs(L2, kind="dense"),
                "b": self._attn_block_defs(L2, kind="moe"),
            }
        kind = "moe" if cfg.family == "moe" else "dense"
        return self._attn_block_defs(Lp, kind=kind)

    def _attn_block_defs(self, Lp: Tuple[int, ...], kind: str):
        cfg = self.cfg
        blk: Dict[str, Any] = {
            "ln1": norm_defs(cfg, Lp),
            "attn": attn_defs(cfg, Lp),
            "ln2": norm_defs(cfg, Lp),
        }
        if kind == "moe":
            blk["mlp"] = moe_defs(cfg, Lp)
        else:
            d_ff = cfg.dense_d_ff if (cfg.family == "moe" and cfg.moe_every == 2) else cfg.d_ff
            blk["mlp"] = mlp_defs(cfg, Lp, d_ff=d_ff)
        if cfg.post_norms:
            blk["post_attn_norm"] = norm_defs(cfg, Lp)
            blk["post_mlp_norm"] = norm_defs(cfg, Lp)
        return blk

    @property
    def _interleaved(self) -> bool:
        return self.cfg.family == "moe" and self.cfg.moe_every == 2

    # -- per-layer static metadata ----------------------------------------------
    def _layer_windows(self, sk_hint: int) -> Optional[jnp.ndarray]:
        """Per-layer window values for alternating local/global attention."""
        cfg = self.cfg
        if cfg.global_every <= 0 or cfg.sliding_window <= 0:
            return None
        L = cfg.num_layers
        is_global = (jnp.arange(L) % cfg.global_every) == (cfg.global_every - 1)
        return jnp.where(is_global, _BIG_WINDOW, jnp.int32(cfg.sliding_window))

    # -- attention/mlp block bodies ----------------------------------------------
    def _attn_block_full(self, blk, x, window, q_offset=0, return_kv=False):
        cfg = self.cfg
        blk = self._cast(blk)
        h = self._norm(x, blk["ln1"])
        res = attn_full(
            blk["attn"], h, cfg, backend=self.backend,
            window=window, q_offset=q_offset, return_kv=return_kv,
        )
        a, kv = res if return_kv else (res, None)
        if opt_enabled("rs_block_outputs"):
            # constrain the TP partial-sum output to the seq-sharded layout
            # BEFORE the residual add: GSPMD emits reduce-scatter (half the
            # bytes of the all-reduce it would otherwise place after the add)
            a = shard_act(a, ("batch", "seq", "act_embed"))
        if cfg.post_norms:
            a = self._norm(a, blk["post_attn_norm"])
        x = x + a
        h2 = self._norm(x, blk["ln2"])
        if "router" in blk["mlp"]:
            m, aux = moe_apply(blk["mlp"], h2, cfg)
        else:
            m, aux = mlp_apply(blk["mlp"], h2), jnp.float32(0.0)
        if opt_enabled("rs_block_outputs"):
            m = shard_act(m, ("batch", "seq", "act_embed"))
        if cfg.post_norms:
            m = self._norm(m, blk["post_mlp_norm"])
        x = shard_act(x + m, ("batch", "seq", "act_embed"))
        return (x, aux, kv) if return_kv else (x, aux)

    def _block_ffn(self, blk, x):
        """ln2 + (MoE|MLP) + optional post-norm, residual-added.  ``blk`` is
        already cast to the compute dtype."""
        cfg = self.cfg
        h2 = self._norm(x, blk["ln2"])
        if "router" in blk["mlp"]:
            m, _ = moe_apply(blk["mlp"], h2, cfg)
        else:
            m = mlp_apply(blk["mlp"], h2)
        if cfg.post_norms:
            m = self._norm(m, blk["post_mlp_norm"])
        return x + m

    def _attn_block_decode(self, blk, x1, kc, vc, pos, window, ring=False,
                           uniform_pos=True, kv_bound=None):
        cfg = self.cfg
        blk = self._cast(blk)
        h = self._norm(x1, blk["ln1"])
        a, kc, vc = attn_decode(
            blk["attn"], h, kc, vc, pos, cfg, backend=self.backend,
            window=window, ring=ring, uniform_pos=uniform_pos, kv_bound=kv_bound,
        )
        if cfg.post_norms:
            a = self._norm(a, blk["post_attn_norm"])
        x1 = x1 + a
        return self._block_ffn(blk, x1), kc, vc

    def _mamba_block_full(self, blk, x, state=None, conv=None, return_state=False):
        blk = self._cast_mamba(blk)
        h = self._norm(x, blk["ln"])
        out = mamba_forward(
            blk["mamba"], h, self.cfg, backend=self.backend,
            ssm_state=state, conv_state=conv, return_state=return_state,
        )
        if return_state:
            y, new_state, new_conv = out
            return shard_act(x + y, ("batch", "seq", "act_embed")), new_state, new_conv
        return shard_act(x + out, ("batch", "seq", "act_embed"))

    def _mamba_block_step(self, blk, x1, state, conv):
        blk = self._cast_mamba(blk)
        h = self._norm(x1, blk["ln"])
        y, state, conv = mamba_step(
            blk["mamba"], h, state, conv, self.cfg, backend=self.backend
        )
        return x1 + y, state, conv

    def _shared_block_full(self, shared, x, window=None, kv_cache=None):
        """Zamba2 shared attention+MLP block (full sequence)."""
        shared = self._cast(shared)
        h = self._norm(x, shared["ln1"])
        if kv_cache is not None:
            a, (k, v) = attn_full(
                shared["attn"], h, self.cfg, backend=self.backend,
                window=window, return_kv=True,
            )
        else:
            a = attn_full(shared["attn"], h, self.cfg, backend=self.backend, window=window)
            k = v = None
        x = x + a
        x = x + mlp_apply(shared["mlp"], self._norm(x, shared["ln2"]))
        x = shard_act(x, ("batch", "seq", "act_embed"))
        return (x, (k, v)) if kv_cache is not None else x

    # -- forward (training) -------------------------------------------------------
    def forward(self, params, batch, remat: bool = False):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = self._embed_tokens(params, tokens)
        x = shard_act(x, ("batch", "seq", "act_embed"))
        if cfg.family in ("dense", "moe"):
            if self._interleaved:

                def body(carry, blk):
                    x, aux = carry
                    x, a1 = self._attn_block_full(blk["a"], x, None)
                    x, a2 = self._attn_block_full(blk["b"], x, None)
                    return (x, aux + a1 + a2), None

                if remat:
                    body = jax.checkpoint(body)
                (x, aux), _ = jax.lax.scan(
                    body, (x, jnp.float32(0.0)), params["blocks"]
                )
                return self._logits(params, x), aux
            windows = self._layer_windows(tokens.shape[1])

            def body(carry, xs):
                x, aux = carry
                blk = xs[0]
                window = xs[1] if windows is not None else None
                x, a = self._attn_block_full(blk, x, window)
                return (x, aux + a), None

            if remat:
                body = jax.checkpoint(body)
            xs = (params["blocks"],) + ((windows,) if windows is not None else ())
            (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), xs)
        elif cfg.family == "ssm":

            def body(x, blk):
                return self._mamba_block_full(blk, x), None

            if remat:
                body = jax.checkpoint(body)
            x, _ = jax.lax.scan(body, x, params["blocks"])
            aux = jnp.float32(0.0)
        elif cfg.family == "hybrid":
            x = self._hybrid_forward(params, x, remat)
            aux = jnp.float32(0.0)
        else:
            raise ValueError(cfg.family)
        return self._logits(params, x), aux

    def _hybrid_forward(self, params, x, remat: bool = False):
        cfg = self.cfg
        G = cfg.num_layers // cfg.hybrid_attn_every
        grouped = jax.tree.map(
            lambda t: t.reshape((G, cfg.hybrid_attn_every) + t.shape[1:]),
            params["blocks"],
        )
        shared = params["shared"]

        def group_body(x, mamba_g):
            def inner(x, blk):
                return self._mamba_block_full(blk, x), None

            x, _ = jax.lax.scan(inner, x, mamba_g)
            x = self._shared_block_full(shared, x)
            return x, None

        if remat:
            group_body = jax.checkpoint(group_body)
        x, _ = jax.lax.scan(group_body, x, grouped)
        return x

    # -- serving caches --------------------------------------------------------------
    def _cache_len(self, max_seq: int) -> Tuple[int, bool]:
        cfg = self.cfg
        if cfg.family == "hybrid" and max_seq > _RING_THRESHOLD:
            return cfg.long_context_window, True
        return max_seq, False

    def cache_defs(self, batch: int, max_seq: int, dtype="bfloat16") -> Dict[str, P]:
        """Cache described as a P-tree (reuses init/specs/pspec machinery)."""
        cfg = self.cfg
        kv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
        defs: Dict[str, Any] = {"pos": P((batch,), "zeros", dtype="int32", axes=("batch",))}
        # shard the kv-cache sequence dim over "model" when heads can't split
        kv_axes = ("layer", "batch", "kv_seq", "act_kv", "head_dim")
        if cfg.family in ("dense", "moe"):
            S, _ = self._cache_len(max_seq)
            L = cfg.num_layers
            if self._interleaved:
                pair_axes = ("layer", None) + kv_axes[1:]
                defs["k"] = P((L // 2, 2, batch, S, kv, dh), "zeros", dtype=dtype, axes=pair_axes)
                defs["v"] = P((L // 2, 2, batch, S, kv, dh), "zeros", dtype=dtype, axes=pair_axes)
            else:
                defs["k"] = P((L, batch, S, kv, dh), "zeros", dtype=dtype, axes=kv_axes)
                defs["v"] = P((L, batch, S, kv, dh), "zeros", dtype=dtype, axes=kv_axes)
        elif cfg.family == "ssm":
            L = cfg.num_layers
            defs.update(self._ssm_cache_defs((L,), batch, dtype))
        elif cfg.family == "hybrid":
            L, E = cfg.num_layers, cfg.hybrid_attn_every
            G = L // E
            S, _ = self._cache_len(max_seq)
            defs.update(self._ssm_cache_defs((G, E), batch, dtype))
            ga = ("group", "batch", "kv_seq", "act_kv", "head_dim")
            defs["k"] = P((G, batch, S, kv, dh), "zeros", dtype=dtype, axes=ga)
            defs["v"] = P((G, batch, S, kv, dh), "zeros", dtype=dtype, axes=ga)
        return defs

    def _ssm_cache_defs(self, Lp: Tuple[int, ...], batch: int, dtype) -> Dict[str, P]:
        cfg = self.cfg
        h, ph, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        conv_dim = cfg.ssm_inner + 2 * n
        la = ("layer",) * len(Lp)
        return {
            "ssm": P(
                Lp + (batch, h, ph, n), "zeros", dtype="float32",
                axes=la + ("batch", "ssm_heads", None, None),
            ),
            "conv": P(
                Lp + (batch, cfg.conv_kernel - 1, conv_dim), "zeros", dtype=dtype,
                axes=la + ("batch", None, "conv_dim"),
            ),
        }

    def init_cache(self, batch: int, max_seq: int, dtype="bfloat16"):
        return init_params(jax.random.PRNGKey(0), self.cache_defs(batch, max_seq, dtype))

    def cache_specs(self, batch: int, max_seq: int, dtype="bfloat16"):
        return param_specs(self.cache_defs(batch, max_seq, dtype))

    def paged_cache_defs(self, num_pages: int, page_size: int,
                         dtype="bfloat16") -> Dict[str, P]:
        """Paged KV layout: one global pool of ``page_size``-token pages per
        layer, indexed through per-request page tables — HBM scales with the
        page pool (live tokens), not ``num_slots * max_seq``."""
        cfg = self.cfg
        if cfg.family not in ("dense", "moe") or self._interleaved:
            raise NotImplementedError(
                "paged KV cache supports dense/moe (non-interleaved) decoder "
                "caches only; ssm/hybrid state is not paged"
            )
        kv, dh, L = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.num_layers
        axes = ("layer", None, "kv_seq", "act_kv", "head_dim")
        if kvquant.is_quantized(dtype):
            # quantized pool: int8/fp8 pages + a parallel float32 scale pool
            # (one scale per page row per kv head); scales shard with heads
            store = kvquant.pool_dtype(dtype)
            sc_axes = ("layer", None, "kv_seq", "act_kv")
            return {
                "k_pages": P((L, num_pages, page_size, kv, dh), "zeros",
                             dtype=store, axes=axes),
                "v_pages": P((L, num_pages, page_size, kv, dh), "zeros",
                             dtype=store, axes=axes),
                "k_scales": P((L, num_pages, page_size, kv), "zeros",
                              dtype="float32", axes=sc_axes),
                "v_scales": P((L, num_pages, page_size, kv), "zeros",
                              dtype="float32", axes=sc_axes),
            }
        return {
            "k_pages": P((L, num_pages, page_size, kv, dh), "zeros",
                         dtype=dtype, axes=axes),
            "v_pages": P((L, num_pages, page_size, kv, dh), "zeros",
                         dtype=dtype, axes=axes),
        }

    def init_paged_cache(self, num_pages: int, page_size: int, dtype="bfloat16"):
        return init_params(
            jax.random.PRNGKey(0), self.paged_cache_defs(num_pages, page_size, dtype)
        )

    def paged_cache_pspecs(self, rules, num_pages: int, page_size: int,
                           dtype="bfloat16"):
        """PartitionSpec tree for the paged pool under ``rules``: the
        ``act_kv`` head dim shards over "model" (each shard holds kv/tp
        heads of EVERY page), everything else replicates — page accounting
        stays host-global.  Non-divisible kv head counts fall back to full
        replication via the rules themselves."""
        return param_pspecs(
            self.paged_cache_defs(num_pages, page_size, dtype), rules
        )

    # -- prefill -----------------------------------------------------------------------
    def prefill(self, params, batch, cache):
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = self._embed_tokens(params, tokens)
        x = shard_act(x, ("batch", "seq", "act_embed"))
        new_cache = dict(cache)
        if cfg.family in ("dense", "moe"):
            if self._interleaved:
                L2 = cfg.num_layers // 2

                def body(x, blk, caches, li):
                    kc, vc = caches["k"], caches["v"]     # (2, b, S, kv, dh)
                    x, _, (k1, v1) = self._attn_block_full(blk["a"], x, None, return_kv=True)
                    x, _, (k2, v2) = self._attn_block_full(blk["b"], x, None, return_kv=True)
                    write = lambda c, t: jax.lax.dynamic_update_slice(
                        c, t.astype(c.dtype), (0, 0, 0, 0)
                    )
                    return x, {
                        "k": jnp.stack([write(kc[0], k1), write(kc[1], k2)]),
                        "v": jnp.stack([write(vc[0], v1), write(vc[1], v2)]),
                    }

                x, stacks = _scan_cached(
                    body, x, params["blocks"],
                    {"k": cache["k"], "v": cache["v"]}, L2,
                )
            else:
                windows = self._layer_windows(s)
                xs = (
                    (params["blocks"], windows)
                    if windows is not None
                    else (params["blocks"],)
                )

                def body(x, xs_l, caches, li):
                    blk = xs_l[0]
                    window = xs_l[1] if len(xs_l) > 1 else None
                    x, _, (k, v) = self._attn_block_full(blk, x, window, return_kv=True)
                    kc = jax.lax.dynamic_update_slice(
                        caches["k"], k.astype(caches["k"].dtype), (0, 0, 0, 0)
                    )
                    vc = jax.lax.dynamic_update_slice(
                        caches["v"], v.astype(caches["v"].dtype), (0, 0, 0, 0)
                    )
                    return x, {"k": kc, "v": vc}

                x, stacks = _scan_cached(
                    body, x, xs, {"k": cache["k"], "v": cache["v"]}, cfg.num_layers
                )
            new_cache.update(stacks)
        elif cfg.family == "ssm":

            def body(x, blk, caches, li):
                x, st, cv = self._mamba_block_full(
                    blk, x, state=None, conv=None, return_state=True
                )
                return x, {"ssm": st, "conv": cv}

            x, stacks = _scan_cached(
                body, x, params["blocks"],
                {"ssm": cache["ssm"], "conv": cache["conv"]}, cfg.num_layers,
            )
            new_cache.update(stacks)
        elif cfg.family == "hybrid":
            x, new_cache = self._hybrid_prefill(params, x, cache)
        return self._prefill_logits(params, batch, x, new_cache, b, s)

    def _hybrid_prefill(self, params, x, cache):
        cfg = self.cfg
        G, E = cfg.num_layers // cfg.hybrid_attn_every, cfg.hybrid_attn_every
        grouped = jax.tree.map(
            lambda t: t.reshape((G, E) + t.shape[1:]), params["blocks"]
        )
        shared = params["shared"]
        S = cache["k"].shape[2]
        s = x.shape[1]

        def body(x, mamba_g, caches, gi):
            ssm_g, conv_g, kc, vc = (
                caches["ssm"], caches["conv"], caches["k"], caches["v"]
            )

            def inner(x, xs2):
                blk, st, cv = xs2
                x, st, cv = self._mamba_block_full(blk, x, return_state=True)
                return x, (st, cv)

            x, (ssm_g, conv_g) = jax.lax.scan(inner, x, (mamba_g, ssm_g, conv_g))
            x, (k, v) = self._shared_block_full(shared, x, kv_cache=True)
            if s <= S:
                kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, 0, 0, 0))
                vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, 0, 0, 0))
            else:
                # ring cache shorter than the prompt: keep the last S tokens,
                # placed at their pos-mod-S slots (ring invariant for decode)
                shift = (s - S) % S
                kc = jnp.roll(k[:, -S:], shift, axis=1).astype(kc.dtype)
                vc = jnp.roll(v[:, -S:], shift, axis=1).astype(vc.dtype)
            return x, {"ssm": ssm_g, "conv": conv_g, "k": kc, "v": vc}

        x, stacks = _scan_cached(
            body, x, grouped,
            {"ssm": cache["ssm"], "conv": cache["conv"], "k": cache["k"], "v": cache["v"]},
            G,
        )
        new_cache = dict(cache)
        new_cache.update(stacks)
        return x, new_cache

    # -- decode ------------------------------------------------------------------------
    def decode(self, params, tokens, cache, uniform_pos=True, kv_bound=None):
        """One token step. tokens: (b,) int32. Returns (logits, new cache).

        ``uniform_pos=False`` selects the masked per-row cache-update path so
        slots may sit at different sequence positions (continuous batching).
        ``kv_bound`` is a static host-known bound on the live cache lengths:
        attention streams only that prefix of the cache instead of all of
        padded ``max_seq`` (the serving engine buckets it to a power of two).
        """
        cfg = self.cfg
        pos = cache["pos"]
        x = self._embed_tokens(params, tokens)[:, None, :]       # (b, 1, D)
        new_cache = dict(cache)
        if cfg.family in ("dense", "moe"):
            if self._interleaved:
                L2 = cfg.num_layers // 2

                def body(x1, blk, caches, li):
                    kc, vc = caches["k"], caches["v"]     # (2, b, S, kv, dh)
                    x1, k0, v0 = self._attn_block_decode(
                        blk["a"], x1, kc[0], vc[0], pos, None,
                        uniform_pos=uniform_pos, kv_bound=kv_bound,
                    )
                    x1, k1, v1 = self._attn_block_decode(
                        blk["b"], x1, kc[1], vc[1], pos, None,
                        uniform_pos=uniform_pos, kv_bound=kv_bound,
                    )
                    return x1, {"k": jnp.stack([k0, k1]), "v": jnp.stack([v0, v1])}

                x, stacks = _scan_cached(
                    body, x, params["blocks"], {"k": cache["k"], "v": cache["v"]}, L2
                )
            else:
                windows = self._layer_windows(0)
                xs = (
                    (params["blocks"], windows)
                    if windows is not None
                    else (params["blocks"],)
                )

                def body(x1, xs_l, caches, li):
                    blk = xs_l[0]
                    window = xs_l[1] if len(xs_l) > 1 else None
                    x1, kc, vc = self._attn_block_decode(
                        blk, x1, caches["k"], caches["v"], pos, window,
                        uniform_pos=uniform_pos, kv_bound=kv_bound,
                    )
                    return x1, {"k": kc, "v": vc}

                x, stacks = _scan_cached(
                    body, x, xs, {"k": cache["k"], "v": cache["v"]}, cfg.num_layers
                )
            new_cache.update(stacks)
        elif cfg.family == "ssm":

            def body(x1, blk, caches, li):
                y, st, cv = self._mamba_block_step(
                    blk, x1[:, 0], caches["ssm"], caches["conv"]
                )
                return y[:, None], {"ssm": st, "conv": cv}

            x, stacks = _scan_cached(
                body, x, params["blocks"],
                {"ssm": cache["ssm"], "conv": cache["conv"]}, cfg.num_layers,
            )
            new_cache.update(stacks)
        elif cfg.family == "hybrid":
            x, new_cache = self._hybrid_decode(params, x, cache, uniform_pos=uniform_pos)
        new_cache["pos"] = pos + 1
        logits = self._logits(params, x)[:, 0]
        return logits, new_cache

    # -- paged serving (global page pool + per-request page tables) --------------------
    @staticmethod
    def _paged_stacks(cache):
        """Cache stacks the paged serving bodies carry through the layer
        scan — the float32 scale pools ride along when the pool is
        quantized."""
        return {
            k: cache[k]
            for k in ("k_pages", "v_pages", "k_scales", "v_scales")
            if k in cache
        }

    def decode_paged(self, params, tokens, cache, page_table, lengths,
                     pages_bound=None):
        """One paged decode step for a pool of slots.

        ``tokens``: (b,) next-token ids; ``page_table``: (b, max_pages)
        int32 physical page ids; ``lengths``: (b,) int32 tokens already held
        per slot — the new token is appended at logical position ``lengths``
        and attention covers ``lengths + 1`` tokens.  ``pages_bound``
        statically bounds live pages per request (host-known, bucketed) so
        the paged kernel's grid tracks actual context lengths.
        Returns (logits, new cache)."""
        cfg = self.cfg
        if cfg.family not in ("dense", "moe") or self._interleaved:
            raise NotImplementedError(
                "paged decode supports dense/moe (non-interleaved) only"
            )
        pos = jnp.asarray(lengths, jnp.int32)
        x = self._embed_tokens(params, tokens)[:, None, :]       # (b, 1, D)
        x = shard_act(x, ("batch", None, "act_embed"))
        windows = self._layer_windows(0)
        xs = (
            (params["blocks"], windows)
            if windows is not None
            else (params["blocks"],)
        )

        def body(x1, xs_l, caches, li):
            blk = self._cast(xs_l[0])
            window = xs_l[1] if len(xs_l) > 1 else None
            h = self._norm(x1, blk["ln1"])
            if "k_scales" in caches:
                a, kp, vp, ksc, vsc = attn_decode_paged(
                    blk["attn"], h, caches["k_pages"], caches["v_pages"],
                    page_table, pos, cfg, backend=self.backend,
                    window=window, pages_bound=pages_bound,
                    k_scales=caches["k_scales"], v_scales=caches["v_scales"],
                )
                new_l = {"k_pages": kp, "v_pages": vp,
                         "k_scales": ksc, "v_scales": vsc}
            else:
                a, kp, vp = attn_decode_paged(
                    blk["attn"], h, caches["k_pages"], caches["v_pages"],
                    page_table, pos, cfg, backend=self.backend,
                    window=window, pages_bound=pages_bound,
                )
                new_l = {"k_pages": kp, "v_pages": vp}
            if cfg.post_norms:
                a = self._norm(a, blk["post_attn_norm"])
            x1 = x1 + a
            return self._block_ffn(blk, x1), new_l

        x, stacks = _scan_cached(
            body, x, xs, self._paged_stacks(cache), cfg.num_layers,
        )
        new_cache = dict(cache)
        new_cache.update(stacks)
        logits = self._logits(params, x)[:, 0]
        return logits, new_cache

    def decode_spec(self, params, tokens, cache, page_table, lengths,
                    window_lens, pages_bound=None):
        """Speculative-decoding verification step for a pool of slots.

        ``tokens``: (b, W) int32 in-flight windows — per slot the pending
        ``next_token`` followed by up to ``W - 1`` prompt-lookup draft
        tokens, right-padded; ``window_lens``: (b,) real tokens per window
        (0 for idle slots).  ``lengths``: (b,) tokens already committed —
        the window occupies logical positions ``[lengths, lengths +
        window_lens)``.  Every layer scatters the window's K/V into the
        request's pages, then attends the committed context plus the
        window's own causal prefix (one varlen-style launch per layer
        instead of ``W`` sequential decode steps — the KV pool streams
        once).  ``pages_bound`` statically bounds live+in-flight pages.

        Returns (logits (b, W, V), new cache): row ``w`` holds the
        next-token distribution after consuming ``tokens[:, :w + 1]``, so
        greedy acceptance compares ``argmax(logits[:, w - 1])`` against
        ``tokens[:, w]`` — accepted tokens are bit-identical to running the
        one-token decode path sequentially."""
        cfg = self.cfg
        if cfg.family not in ("dense", "moe") or self._interleaved:
            raise NotImplementedError(
                "speculative paged decode supports dense/moe "
                "(non-interleaved) only"
            )
        pos = jnp.asarray(lengths, jnp.int32)
        wlens = jnp.asarray(window_lens, jnp.int32)
        x = self._embed_tokens(params, tokens)                   # (b, W, D)
        x = shard_act(x, ("batch", None, "act_embed"))
        windows = self._layer_windows(0)
        xs = (
            (params["blocks"], windows)
            if windows is not None
            else (params["blocks"],)
        )

        def body(x1, xs_l, caches, li):
            blk = self._cast(xs_l[0])
            window = xs_l[1] if len(xs_l) > 1 else None
            h = self._norm(x1, blk["ln1"])
            if "k_scales" in caches:
                a, kp, vp, ksc, vsc = attn_decode_spec(
                    blk["attn"], h, caches["k_pages"], caches["v_pages"],
                    page_table, pos, wlens, cfg, backend=self.backend,
                    window=window, pages_bound=pages_bound,
                    k_scales=caches["k_scales"], v_scales=caches["v_scales"],
                )
                new_l = {"k_pages": kp, "v_pages": vp,
                         "k_scales": ksc, "v_scales": vsc}
            else:
                a, kp, vp = attn_decode_spec(
                    blk["attn"], h, caches["k_pages"], caches["v_pages"],
                    page_table, pos, wlens, cfg, backend=self.backend,
                    window=window, pages_bound=pages_bound,
                )
                new_l = {"k_pages": kp, "v_pages": vp}
            if cfg.post_norms:
                a = self._norm(a, blk["post_attn_norm"])
            x1 = x1 + a
            return self._block_ffn(blk, x1), new_l

        x, stacks = _scan_cached(
            body, x, xs, self._paged_stacks(cache), cfg.num_layers,
        )
        new_cache = dict(cache)
        new_cache.update(stacks)
        logits = self._logits(params, x)                         # (b, W, V)
        return logits, new_cache

    def prefill_paged_chunk(self, params, tokens, cache, page_row,
                            last_index, pos0: int):
        """One chunked-prefill step: process a (1, c) prompt chunk starting
        at static page-aligned absolute position ``pos0``, attending to the
        request's already-paged context and appending the chunk's K/V to its
        pages (``page_row``: (max_pages,) int32).  The chunk may be right-
        padded to a page multiple so chunk shapes stay bucketed;
        ``last_index`` (dynamic scalar) is the final *real* token's offset
        within the chunk.  Returns (logits (1, V) at ``last_index``, new
        cache) — the logits only matter for the final chunk, whose argmax is
        the request's first generated token."""
        cfg = self.cfg
        if cfg.family not in ("dense", "moe") or self._interleaved:
            raise NotImplementedError(
                "chunked paged prefill supports dense/moe (non-interleaved) only"
            )
        b, c = tokens.shape
        x = self._embed_tokens(params, tokens)
        x = shard_act(x, ("batch", "seq", "act_embed"))
        windows = self._layer_windows(c)
        xs = (
            (params["blocks"], windows)
            if windows is not None
            else (params["blocks"],)
        )

        def body(x, xs_l, caches, li):
            blk = self._cast(xs_l[0])
            window = xs_l[1] if len(xs_l) > 1 else None
            h = self._norm(x, blk["ln1"])
            if "k_scales" in caches:
                a, kp, vp, ksc, vsc = attn_prefill_paged(
                    blk["attn"], h, caches["k_pages"], caches["v_pages"],
                    page_row, pos0, cfg, backend=self.backend, window=window,
                    k_scales=caches["k_scales"], v_scales=caches["v_scales"],
                )
                new_l = {"k_pages": kp, "v_pages": vp,
                         "k_scales": ksc, "v_scales": vsc}
            else:
                a, kp, vp = attn_prefill_paged(
                    blk["attn"], h, caches["k_pages"], caches["v_pages"],
                    page_row, pos0, cfg, backend=self.backend, window=window,
                )
                new_l = {"k_pages": kp, "v_pages": vp}
            if cfg.post_norms:
                a = self._norm(a, blk["post_attn_norm"])
            x = x + a
            return self._block_ffn(blk, x), new_l

        x, stacks = _scan_cached(
            body, x, xs, self._paged_stacks(cache), cfg.num_layers,
        )
        new_cache = dict(cache)
        new_cache.update(stacks)
        last = jnp.asarray(last_index, jnp.int32)
        logits = self._logits(params, x[:, last][:, None, :])[:, 0]
        return logits, new_cache

    def prefill_packed(self, params, batch, cache, pages_bound=None):
        """One packed varlen-prefill launch: process prompt chunks from MANY
        requests in a single token-packed ``(1, T)`` buffer, each chunk
        attending its request's already-committed pages (via the per-chunk
        page-table rows) plus the causal prefix of its own tokens, with the
        packed K/V scattered straight into the paged pool.

        ``batch`` holds the packed tokens plus the packing metadata of
        :func:`repro.models.modules.attn_prefill_packed`, and ``last_idx``
        (C,) — the packed row of each chunk's last real token.  Returns
        (logits (C, V) gathered at ``last_idx``, new cache); only rows of
        chunks that complete their prompt this launch are meaningful (their
        argmax is the request's first generated token).
        """
        cfg = self.cfg
        if cfg.family not in ("dense", "moe") or self._interleaved:
            raise NotImplementedError(
                "packed paged prefill supports dense/moe (non-interleaved) only"
            )
        tokens = batch["tokens"]
        b, T = tokens.shape
        meta = {
            k: batch[k]
            for k in ("tok_pos", "dst_page", "dst_off", "cu_seqlens",
                      "chunk_lens", "chunk_pos0", "page_tables")
        }
        x = self._embed_tokens(params, tokens)
        x = shard_act(x, ("batch", "seq", "act_embed"))
        windows = self._layer_windows(T)
        xs = (
            (params["blocks"], windows)
            if windows is not None
            else (params["blocks"],)
        )

        def body(x, xs_l, caches, li):
            blk = self._cast(xs_l[0])
            window = xs_l[1] if len(xs_l) > 1 else None
            h = self._norm(x, blk["ln1"])
            if "k_scales" in caches:
                a, kp, vp, ksc, vsc = attn_prefill_packed(
                    blk["attn"], h, caches["k_pages"], caches["v_pages"],
                    meta, cfg, backend=self.backend, window=window,
                    pages_bound=pages_bound,
                    k_scales=caches["k_scales"], v_scales=caches["v_scales"],
                )
                new_l = {"k_pages": kp, "v_pages": vp,
                         "k_scales": ksc, "v_scales": vsc}
            else:
                a, kp, vp = attn_prefill_packed(
                    blk["attn"], h, caches["k_pages"], caches["v_pages"],
                    meta, cfg, backend=self.backend, window=window,
                    pages_bound=pages_bound,
                )
                new_l = {"k_pages": kp, "v_pages": vp}
            if cfg.post_norms:
                a = self._norm(a, blk["post_attn_norm"])
            x = x + a
            return self._block_ffn(blk, x), new_l

        x, stacks = _scan_cached(
            body, x, xs, self._paged_stacks(cache), cfg.num_layers,
        )
        new_cache = dict(cache)
        new_cache.update(stacks)
        last = jnp.asarray(batch["last_idx"], jnp.int32)
        logits = self._logits(params, x[0, last][:, None, :])[:, 0]
        return logits, new_cache

    def _hybrid_decode(self, params, x, cache, uniform_pos=True):
        cfg = self.cfg
        G, E = cfg.num_layers // cfg.hybrid_attn_every, cfg.hybrid_attn_every
        grouped = jax.tree.map(
            lambda t: t.reshape((G, E) + t.shape[1:]), params["blocks"]
        )
        shared = self._cast(params["shared"])
        pos = cache["pos"]
        # ring semantics are a no-op while pos < cache length, so always on
        ring = True

        def body(x1, mamba_g, caches, gi):
            ssm_g, conv_g, kc, vc = (
                caches["ssm"], caches["conv"], caches["k"], caches["v"]
            )

            def inner(x1s, xs2):
                blk, st, cv = xs2
                y, st, cv = self._mamba_block_step(blk, x1s, st, cv)
                return y, (st, cv)

            y, (ssm_g, conv_g) = jax.lax.scan(inner, x1[:, 0], (mamba_g, ssm_g, conv_g))
            x1 = y[:, None]
            h = self._norm(x1, shared["ln1"])
            a, kc, vc = attn_decode(
                shared["attn"], h, kc, vc, pos, cfg, backend=self.backend,
                ring=ring, uniform_pos=uniform_pos,
            )
            x1 = x1 + a
            x1 = x1 + mlp_apply(shared["mlp"], self._norm(x1, shared["ln2"]))
            return x1, {"ssm": ssm_g, "conv": conv_g, "k": kc, "v": vc}

        x, stacks = _scan_cached(
            body, x, grouped,
            {"ssm": cache["ssm"], "conv": cache["conv"], "k": cache["k"], "v": cache["v"]},
            G,
        )
        new_cache = dict(cache)
        new_cache.update(stacks)
        return x, new_cache


# =============================================================================
# Encoder–decoder (whisper-style; conv/audio frontend is a stub)
# =============================================================================
class EncDecLM(BaseModel):
    def param_defs(self):
        cfg = self.cfg
        V, D = cfg.vocab_size, cfg.d_model
        Le, Ld = (cfg.encoder_layers,), (cfg.num_layers,)
        enc_blk = {
            "ln1": norm_defs(cfg, Le),
            "attn": attn_defs(cfg, Le),
            "ln2": norm_defs(cfg, Le),
            "mlp": mlp_defs(cfg, Le, gated=False),
        }
        dec_blk = {
            "ln1": norm_defs(cfg, Ld),
            "self_attn": attn_defs(cfg, Ld),
            "ln2": norm_defs(cfg, Ld),
            "cross_attn": attn_defs(cfg, Ld, cross=True),
            "ln3": norm_defs(cfg, Ld),
            "mlp": mlp_defs(cfg, Ld, gated=False),
        }
        return {
            "embed": P((V, D), std=0.02, axes=("vocab", "embed")),
            "enc_blocks": enc_blk,
            "enc_norm": norm_defs(cfg, ()),
            "dec_blocks": dec_blk,
            "final_norm": norm_defs(cfg, ()),
            "lm_head": P((D, V), std=0.02, axes=("embed", "vocab")),
        }

    # -- encoder -------------------------------------------------------------
    def encode(self, params, frames, remat: bool = False):
        """frames: (b, Se, D) — precomputed frame embeddings (frontend stub)."""
        cfg = self.cfg
        Se = frames.shape[1]
        x = frames + sinusoidal(jnp.arange(Se), cfg.d_model).astype(frames.dtype)
        x = shard_act(x, ("batch", "seq", "act_embed"))

        def body(x, blk):
            blk = self._cast(blk)
            h = self._norm(x, blk["ln1"])
            x = x + attn_full(
                blk["attn"], h, cfg, backend=self.backend, causal=False, use_rope=False
            )
            x = x + mlp_apply(blk["mlp"], self._norm(x, blk["ln2"]))
            return shard_act(x, ("batch", "seq", "act_embed")), None

        if remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
        return self._norm(x, params["enc_norm"])

    def _embed_dec(self, params, tokens, pos0=0):
        b, s = tokens.shape
        x = self._embed_tokens(params, tokens)
        x = x + sinusoidal(pos0 + jnp.arange(s), self.cfg.d_model).astype(x.dtype)
        return shard_act(x, ("batch", "seq", "act_embed"))

    # -- training forward -------------------------------------------------------
    def forward(self, params, batch, remat: bool = False):
        cfg = self.cfg
        enc = self.encode(params, batch["frames"], remat=remat)
        x = self._embed_dec(params, batch["tokens"])

        def body(x, blk):
            blk = self._cast(blk)
            h = self._norm(x, blk["ln1"])
            x = x + attn_full(
                blk["self_attn"], h, cfg, backend=self.backend, use_rope=False
            )
            h2 = self._norm(x, blk["ln2"])
            x = x + attn_full(
                blk["cross_attn"], h2, cfg, backend=self.backend,
                use_rope=False, kv_from=enc,
            )
            x = x + mlp_apply(blk["mlp"], self._norm(x, blk["ln3"]))
            return shard_act(x, ("batch", "seq", "act_embed")), None

        if remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["dec_blocks"])
        return self._logits(params, x), jnp.float32(0.0)

    # -- serving -------------------------------------------------------------------
    def cache_defs(self, batch: int, max_seq: int, dtype="bfloat16") -> Dict[str, P]:
        cfg = self.cfg
        kv, dh, L = cfg.num_kv_heads, cfg.resolved_head_dim, cfg.num_layers
        Se = cfg.encoder_seq
        kv_axes = ("layer", "batch", "kv_seq", "act_kv", "head_dim")
        return {
            "pos": P((batch,), "zeros", dtype="int32", axes=("batch",)),
            "k": P((L, batch, max_seq, kv, dh), "zeros", dtype=dtype, axes=kv_axes),
            "v": P((L, batch, max_seq, kv, dh), "zeros", dtype=dtype, axes=kv_axes),
            "k_cross": P((L, batch, Se, kv, dh), "zeros", dtype=dtype, axes=kv_axes),
            "v_cross": P((L, batch, Se, kv, dh), "zeros", dtype=dtype, axes=kv_axes),
        }

    def init_cache(self, batch: int, max_seq: int, dtype="bfloat16"):
        return init_params(jax.random.PRNGKey(0), self.cache_defs(batch, max_seq, dtype))

    def cache_specs(self, batch: int, max_seq: int, dtype="bfloat16"):
        return param_specs(self.cache_defs(batch, max_seq, dtype))

    def prefill(self, params, batch, cache):
        """batch: {frames, tokens}; encodes, caches cross-KV, fills self-KV."""
        cfg = self.cfg
        enc = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = self._embed_dec(params, tokens)

        def body(x, blk, caches, li):
            blk = self._cast(blk)
            h = self._norm(x, blk["ln1"])
            a, (k, v) = attn_full(
                blk["self_attn"], h, cfg, backend=self.backend,
                use_rope=False, return_kv=True,
            )
            x = x + a
            kc = jax.lax.dynamic_update_slice(
                caches["k"], k.astype(caches["k"].dtype), (0, 0, 0, 0)
            )
            vc = jax.lax.dynamic_update_slice(
                caches["v"], v.astype(caches["v"].dtype), (0, 0, 0, 0)
            )
            h2 = self._norm(x, blk["ln2"])
            # cross attention; cache enc K/V for decode
            kx_new = jnp.einsum("bsd,dhk->bshk", enc, blk["cross_attn"]["wk"])
            vx_new = jnp.einsum("bsd,dhk->bshk", enc, blk["cross_attn"]["wv"])
            q = jnp.einsum("bsd,dhk->bshk", h2, blk["cross_attn"]["wq"])
            o = ops.attention(q, kx_new, vx_new, causal=False, backend=self.backend)
            x = x + jnp.einsum("bshk,hkd->bsd", o, blk["cross_attn"]["wo"])
            x = x + mlp_apply(blk["mlp"], self._norm(x, blk["ln3"]))
            return x, {"k": kc, "v": vc, "k_cross": kx_new, "v_cross": vx_new}

        x, stacks = _scan_cached(
            body, x, params["dec_blocks"],
            {"k": cache["k"], "v": cache["v"],
             "k_cross": cache["k_cross"], "v_cross": cache["v_cross"]},
            cfg.num_layers,
        )
        new_cache = dict(cache)
        new_cache.update(stacks)
        return self._prefill_logits(params, batch, x, new_cache, b, s)

    def decode(self, params, tokens, cache, uniform_pos=True, kv_bound=None):
        cfg = self.cfg
        pos = cache["pos"]
        x = self._embed_tokens(params, tokens)[:, None, :]
        x = x + sinusoidal(pos[:, None], cfg.d_model).astype(x.dtype)[:, :, :]

        def body(x1, blk, caches, li):
            blk = self._cast(blk)
            h = self._norm(x1, blk["ln1"])
            a, kc, vc = attn_decode(
                blk["self_attn"], h, caches["k"], caches["v"], pos, cfg,
                backend=self.backend, use_rope=False, uniform_pos=uniform_pos,
                kv_bound=kv_bound,
            )
            x1 = x1 + a
            h2 = self._norm(x1, blk["ln2"])
            x1 = x1 + cross_attn_decode(
                blk["cross_attn"], h2, caches["k_cross"], caches["v_cross"],
                cfg, backend=self.backend,
            )
            x1 = x1 + mlp_apply(blk["mlp"], self._norm(x1, blk["ln3"]))
            return x1, {"k": kc, "v": vc}

        x, stacks = _scan_cached(
            body, x, params["dec_blocks"],
            {"k": cache["k"], "v": cache["v"],
             "k_cross": cache["k_cross"], "v_cross": cache["v_cross"]},
            cfg.num_layers,
        )
        new_cache = dict(cache)
        new_cache.update(stacks)
        new_cache["pos"] = pos + 1
        logits = self._logits(params, x)[:, 0]
        return logits, new_cache


def _layer_slice(tree, l: int):
    return jax.tree.map(lambda t: t[l], tree)


def _forward_instrumented_decoder(self, params, batch, hook):
    """Layer-by-layer forward with a ``hook(name, thunk)`` around each layer.

    This is the FRAMEWORK-level tracing path (paper §4.4.4): like TF's
    RunOptions tracer, it trades throughput for per-layer visibility —
    each layer runs (and synchronizes) separately.
    """
    cfg = self.cfg
    x = hook("embed", lambda: self._embed_tokens(params, batch["tokens"]))
    if cfg.family in ("dense", "moe"):
        if self._interleaved:
            L2 = cfg.num_layers // 2
            for l in range(L2):
                blk = _layer_slice(params["blocks"], l)
                x = hook(
                    f"layer_{2*l:03d}_dense",
                    lambda blk=blk, x=x: self._attn_block_full(blk["a"], x, None)[0],
                )
                x = hook(
                    f"layer_{2*l+1:03d}_moe",
                    lambda blk=blk, x=x: self._attn_block_full(blk["b"], x, None)[0],
                )
        else:
            windows = self._layer_windows(batch["tokens"].shape[1])
            import numpy as _np

            wvals = None if windows is None else _np.asarray(windows)
            for l in range(cfg.num_layers):
                blk = _layer_slice(params["blocks"], l)
                w = None if wvals is None else int(wvals[l])
                name = f"layer_{l:03d}_attn" + ("" if w is None else f"_w{w}")
                x = hook(
                    name, lambda blk=blk, x=x, w=w: self._attn_block_full(blk, x, w)[0]
                )
    elif cfg.family == "ssm":
        for l in range(cfg.num_layers):
            blk = _layer_slice(params["blocks"], l)
            x = hook(
                f"layer_{l:03d}_mamba",
                lambda blk=blk, x=x: self._mamba_block_full(blk, x),
            )
    elif cfg.family == "hybrid":
        G, E = cfg.num_layers // cfg.hybrid_attn_every, cfg.hybrid_attn_every
        for g in range(G):
            for e in range(E):
                l = g * E + e
                blk = _layer_slice(params["blocks"], l)
                x = hook(
                    f"layer_{l:03d}_mamba",
                    lambda blk=blk, x=x: self._mamba_block_full(blk, x),
                )
            x = hook(
                f"layer_{g:03d}_shared_attn",
                lambda x=x: self._shared_block_full(params["shared"], x),
            )
    return hook("logits", lambda: self._logits(params, x))


def _forward_instrumented_encdec(self, params, batch, hook):
    cfg = self.cfg
    frames = batch["frames"]
    Se = frames.shape[1]
    x = hook(
        "enc_embed",
        lambda: frames
        + sinusoidal(jnp.arange(Se), cfg.d_model).astype(frames.dtype),
    )
    for l in range(cfg.encoder_layers):
        blk = self._cast(_layer_slice(params["enc_blocks"], l))

        def enc_layer(blk=blk, x=x):
            h = self._norm(x, blk["ln1"])
            y = x + attn_full(
                blk["attn"], h, cfg, backend=self.backend, causal=False, use_rope=False
            )
            return y + mlp_apply(blk["mlp"], self._norm(y, blk["ln2"]))

        x = hook(f"enc_layer_{l:03d}", enc_layer)
    enc = hook("enc_norm", lambda x=x: self._norm(x, params["enc_norm"]))
    x = hook("dec_embed", lambda: self._embed_dec(params, batch["tokens"]))
    for l in range(cfg.num_layers):
        blk = self._cast(_layer_slice(params["dec_blocks"], l))

        def dec_layer(blk=blk, x=x):
            h = self._norm(x, blk["ln1"])
            y = x + attn_full(
                blk["self_attn"], h, cfg, backend=self.backend, use_rope=False
            )
            h2 = self._norm(y, blk["ln2"])
            y = y + attn_full(
                blk["cross_attn"], h2, cfg, backend=self.backend,
                use_rope=False, kv_from=enc,
            )
            return y + mlp_apply(blk["mlp"], self._norm(y, blk["ln3"]))

        x = hook(f"dec_layer_{l:03d}", dec_layer)
    return hook("logits", lambda: self._logits(params, x))


DecoderLM.forward_instrumented = _forward_instrumented_decoder
EncDecLM.forward_instrumented = _forward_instrumented_encdec


def build_model(
    cfg: ArchConfig, backend: str = ops.DEFAULT_BACKEND, compute_dtype=None
) -> BaseModel:
    if cfg.family == "encdec":
        return EncDecLM(cfg, backend, compute_dtype)
    return DecoderLM(cfg, backend, compute_dtype)
