"""The JAX model-zoo predictor: the platform's "framework predictor".

Implements the 3-function predictor interface (core.predictor) over the
architecture zoo. The backend string selects the kernel implementation
(``ref`` | ``pallas``) — the TPU analogue of the paper's framework axis.

Trace levels:

* MODEL     — model_load / inference spans only (jit'd whole-graph path)
* FRAMEWORK — adds per-layer spans via the instrumented (eager per-layer)
              forward, like TF's RunOptions tracer: more visibility, more
              overhead (documented, mirrors the paper's behaviour)
* SYSTEM    — adds compiled-artifact counters (FLOPs/bytes from XLA
              cost_analysis) as trace events — the CUPTI stand-in on TPU
"""
from __future__ import annotations

import time
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..core.predictor import OpenRequest, Predictor, PredictorHandle, _handles
from ..core.tracing import Tracer, TraceLevel
from .lm import build_model
from .resnet import ResNet, ResNetConfig


class JaxModelPredictor(Predictor):
    name = "jax-zoo"
    version = "1.0.0"

    def __init__(self, kernel_backend: str = "ref") -> None:
        self.kernel_backend = kernel_backend
        self.name = kernel_backend

    # -- ModelLoad ---------------------------------------------------------------
    def open(self, req: OpenRequest, tracer: Tracer) -> PredictorHandle:
        manifest = req.manifest
        arch = manifest.arch or manifest.name
        with tracer.span("model_load", TraceLevel.MODEL, arch=arch, backend=self.name):
            if arch.startswith("resnet"):
                state = self._open_resnet(req, tracer)
            else:
                state = self._open_lm(req, tracer, arch)
        return PredictorHandle(
            handle_id=next(_handles),
            backend=self.name,
            model_key=manifest.key,
            state=state,
        )

    def _open_lm(self, req: OpenRequest, tracer: Tracer, arch: str) -> Dict[str, Any]:
        # map the platform backend onto kernel backends: the "ref" platform
        # backend uses the chunked pure-JAX kernels; "pallas" the TPU kernels
        # in interpret mode on CPU.
        kernel = {"ref": "flash", "pallas": "pallas"}.get(
            self.kernel_backend, self.kernel_backend
        )
        cfg = get_config(arch, reduced=req.manifest.reduced)
        model = build_model(cfg, backend=kernel)
        seed = int(req.manifest.model_assets.get("seed", 0))
        with tracer.span("weight_init", TraceLevel.MODEL):
            params = model.init(jax.random.PRNGKey(seed))
            params = jax.block_until_ready(params)
        fwd = jax.jit(lambda p, b: model.forward(p, b)[0])
        state = {
            "kind": "lm",
            "cfg": cfg,
            "model": model,
            "params": params,
            "forward": fwd,
            "seq_len": req.seq_len,
            "compiled": {},
        }
        return state

    def _open_resnet(self, req: OpenRequest, tracer: Tracer) -> Dict[str, Any]:
        rcfg = ResNetConfig()
        if req.manifest.reduced:
            rcfg = rcfg.reduced()
        model = ResNet(rcfg)
        seed = int(req.manifest.model_assets.get("seed", 0))
        with tracer.span("weight_init", TraceLevel.MODEL):
            params = jax.block_until_ready(model.init(jax.random.PRNGKey(seed)))
        fwd = jax.jit(model.forward)
        return {
            "kind": "resnet",
            "cfg": rcfg,
            "model": model,
            "params": params,
            "forward": fwd,
            "compiled": {},
        }

    # -- Predict ------------------------------------------------------------------
    def predict(self, handle: PredictorHandle, batch: Any, tracer: Tracer) -> Any:
        state = handle.state
        model, params = state["model"], state["params"]
        if state["kind"] == "resnet":
            images = jnp.asarray(np.asarray(batch, dtype=np.float32))
            if images.ndim == 3:
                images = images[None]
            with tracer.span("inference", TraceLevel.MODEL, batch=int(images.shape[0])):
                out = jax.block_until_ready(state["forward"](params, images))
            self._system_events(state, tracer, {"images": images})
            return np.asarray(out)

        tokens = jnp.asarray(np.asarray(batch, dtype=np.int32))
        if tokens.ndim == 1:
            tokens = tokens[None]
        model_batch = {"tokens": tokens}
        if state["cfg"].family == "encdec":
            model_batch["frames"] = jnp.zeros(
                (tokens.shape[0], state["cfg"].encoder_seq, state["cfg"].d_model),
                jnp.float32,
            )
        if tracer.enabled(TraceLevel.FRAMEWORK):
            out = self._predict_instrumented(state, model_batch, tracer)
        else:
            with tracer.span("inference", TraceLevel.MODEL, batch=int(tokens.shape[0])):
                out = jax.block_until_ready(state["forward"](params, model_batch))
        self._system_events(state, tracer, model_batch)
        return np.asarray(out)

    def _predict_instrumented(self, state, model_batch, tracer: Tracer):
        model, params = state["model"], state["params"]
        clock = tracer.clock

        def hook(name: str, thunk):
            with tracer.span(name, TraceLevel.FRAMEWORK):
                return jax.block_until_ready(thunk())

        with tracer.span("inference", TraceLevel.MODEL, instrumented=True):
            return model.forward_instrumented(params, model_batch, hook)

    def _system_events(self, state, tracer: Tracer, model_batch) -> None:
        if not tracer.enabled(TraceLevel.SYSTEM):
            return
        key = tuple(
            (k, tuple(v.shape)) for k, v in sorted(model_batch.items())
        )
        cost = state["compiled"].get(key)
        if cost is None:
            try:
                lowered = jax.jit(
                    lambda p, b: state["model"].forward(p, b)[0]
                    if state["kind"] == "lm"
                    else state["model"].forward(p, b)
                ).lower(state["params"], model_batch)
                cost = lowered.compile().cost_analysis()
            except Exception:  # pragma: no cover - cost analysis best effort
                cost = {}
            state["compiled"][key] = cost
        if cost:
            tracer.event(
                "system:xla_cost",
                0.0,
                0.0,
                TraceLevel.SYSTEM,
                flops=float(cost.get("flops", 0.0)),
                bytes_accessed=float(cost.get("bytes accessed", 0.0)),
            )

    # -- ModelUnload ----------------------------------------------------------------
    def close(self, handle: PredictorHandle) -> None:
        handle.state = None
