"""Architecture configuration.

One :class:`ArchConfig` instance fully determines a model: family, block
structure, attention variant, MoE/SSM parameters. The assigned-architecture
configs live in :mod:`repro.configs`; each also provides a ``reduced()``
variant for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | encdec
    num_layers: int
    d_model: int
    vocab_size: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0            # 0 -> d_model // num_heads
    d_ff: int = 0
    # attention variants
    sliding_window: int = 0      # >0: local attention window (where used)
    global_every: int = 0        # >0: layer l is GLOBAL iff l % global_every == global_every-1
    attn_softcap: float = 0.0
    logit_softcap: float = 0.0
    qk_norm: bool = False
    post_norms: bool = False     # gemma2-style post-attn/post-mlp norms
    tie_embeddings: bool = False
    scale_embed: bool = False    # gemma-style sqrt(d_model) embedding scale
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_every: int = 1           # 2 = alternate dense/MoE layers (llama4-style)
    dense_d_ff: int = 0          # FFN width of interleaved dense layers
    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    conv_kernel: int = 4
    hybrid_attn_every: int = 0   # zamba2: shared attention after every k SSM layers
    # enc-dec
    encoder_layers: int = 0
    encoder_seq: int = 0         # fixed encoder context (whisper: 1500 frames)
    # misc
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    # serving: attention window used by hybrid archs at very long context
    long_context_window: int = 4096

    # ---- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run 500k-token decode? (assignment: ssm/hybrid only)"""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (whisper is enc-dec)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def validate(self) -> None:
        assert self.family in ("dense", "moe", "hybrid", "ssm", "encdec"), self.family
        if self.family in ("dense", "moe", "encdec"):
            assert self.num_heads > 0 and self.num_kv_heads > 0
            assert self.num_heads % self.num_kv_heads == 0, "GQA grouping"
        if self.family == "moe":
            assert self.num_experts > 0 and self.experts_per_token > 0
            assert self.moe_every in (1, 2)
            if self.moe_every == 2:
                assert self.num_layers % 2 == 0 and self.dense_d_ff > 0
        if self.family in ("ssm", "hybrid"):
            assert self.ssm_state > 0
            assert self.ssm_inner % self.ssm_head_dim == 0
        if self.family == "hybrid":
            assert self.hybrid_attn_every > 0
            assert self.num_layers % self.hybrid_attn_every == 0
        if self.family == "encdec":
            assert self.encoder_layers > 0 and self.encoder_seq > 0

    # approximate parameter counts (used for MODEL_FLOPS = 6·N·D)
    def param_count(self, active_only: bool = False) -> int:
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        H, KV, dh = self.num_heads, self.num_kv_heads, self.resolved_head_dim
        emb = V * D * (1 if self.tie_embeddings else 2)
        total = emb
        attn = D * H * dh + 2 * D * KV * dh + H * dh * D
        if self.family in ("dense", "encdec"):
            per_layer = attn + 3 * D * F
            total += L * per_layer
            if self.family == "encdec":
                # encoder self-attn + mlp, decoder already counted; add cross-attn
                total += self.encoder_layers * (attn + 3 * D * F)
                total += L * attn  # cross-attention in decoder
        elif self.family == "moe":
            experts = self.experts_per_token if active_only else self.num_experts
            moe_layers = L // self.moe_every
            dense_layers = L - moe_layers
            total += moe_layers * (attn + D * self.num_experts + experts * 3 * D * F)
            total += dense_layers * (attn + 3 * D * self.dense_d_ff)
        elif self.family in ("ssm", "hybrid"):
            din, n, hh = self.ssm_inner, self.ssm_state, self.ssm_heads
            in_proj = D * (2 * din + 2 * n + hh)
            per_layer = in_proj + self.conv_kernel * (din + 2 * n) + din * D
            total += L * per_layer
            if self.family == "hybrid":
                total += attn + 3 * D * F  # one shared attention+mlp block
        return total
