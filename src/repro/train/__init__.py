from .checkpoint import CheckpointManager
from .data import RecordIOReader, RecordIOWriter, SyntheticTokenDataset, make_loader
from .optimizer import OptimizerConfig, adamw_update, init_opt_state, lr_at, opt_state_defs
from .step import make_loss_fn, make_train_step

__all__ = [
    "CheckpointManager",
    "OptimizerConfig",
    "RecordIOReader",
    "RecordIOWriter",
    "SyntheticTokenDataset",
    "adamw_update",
    "init_opt_state",
    "lr_at",
    "make_loader",
    "make_loss_fn",
    "make_train_step",
    "opt_state_defs",
]
