"""Data pipeline: RecordIO-style binary token store + prefetching loader.

The paper (F6, §4.4.1) stores datasets in TFRecord/RecordIO formats —
contiguous binary layouts optimized for sequential reads. We implement the
same idea for token data: fixed-width records in one contiguous file with a
small JSON index header, memory-mapped reads, and a background-thread
prefetch loader (producer/consumer, mirroring the pipeline executor).

Fault tolerance: the loader exposes a ``cursor`` (records consumed) saved
in checkpoints; ``make_loader(..., skip=cursor)`` resumes exactly.
"""
from __future__ import annotations

import json
import os
import queue
import struct
import threading
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

_MAGIC = b"RIO1"


class RecordIOWriter:
    """Fixed-width int32 token records: [magic][json header][payload]."""

    def __init__(self, path: str, seq_len: int) -> None:
        self.path = path
        self.seq_len = seq_len
        self.count = 0
        self._tmp = path + ".tmp"
        self._f = open(self._tmp, "wb")
        self._f.write(_MAGIC)
        self._f.write(struct.pack("<I", 0))  # header length placeholder
        self._header_pos = self._f.tell()

    def append(self, tokens: np.ndarray) -> None:
        tokens = np.asarray(tokens, dtype=np.int32)
        if tokens.shape != (self.seq_len,):
            raise ValueError(f"record must be ({self.seq_len},), got {tokens.shape}")
        self._f.write(tokens.tobytes())
        self.count += 1

    def close(self) -> None:
        self._f.close()
        header = json.dumps(
            {"seq_len": self.seq_len, "count": self.count, "dtype": "int32"}
        ).encode()
        # rewrite with header (header follows magic+len, then payload)
        with open(self._tmp, "rb") as f:
            f.seek(self._header_pos)
            payload = f.read()
        with open(self._tmp, "wb") as f:
            f.write(_MAGIC)
            f.write(struct.pack("<I", len(header)))
            f.write(header)
            f.write(payload)
        os.replace(self._tmp, self.path)


class RecordIOReader:
    """Memory-mapped sequential/random reads over a RecordIO token file."""

    def __init__(self, path: str) -> None:
        with open(path, "rb") as f:
            magic = f.read(4)
            if magic != _MAGIC:
                raise ValueError(f"{path}: bad magic {magic!r}")
            (hlen,) = struct.unpack("<I", f.read(4))
            header = json.loads(f.read(hlen).decode())
            self.offset = f.tell()
        self.seq_len = int(header["seq_len"])
        self.count = int(header["count"])
        self._mm = np.memmap(path, dtype=np.int32, mode="r", offset=self.offset)

    def __len__(self) -> int:
        return self.count

    def record(self, i: int) -> np.ndarray:
        if not 0 <= i < self.count:
            raise IndexError(i)
        s = self.seq_len
        return np.asarray(self._mm[i * s : (i + 1) * s])

    def batch(self, start: int, batch_size: int) -> np.ndarray:
        """Contiguous batch with wraparound (epoch crossing)."""
        idx = (start + np.arange(batch_size)) % self.count
        if np.all(np.diff(idx) == 1):  # fast contiguous path
            s = self.seq_len
            i0 = int(idx[0])
            return np.asarray(self._mm[i0 * s : (i0 + batch_size) * s]).reshape(
                batch_size, s
            )
        return np.stack([self.record(int(i)) for i in idx])


class SyntheticTokenDataset:
    """Deterministic synthetic LM data (Zipf-ish marginals), offline stand-in."""

    def __init__(self, vocab_size: int, seq_len: int, seed: int = 0) -> None:
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.seed = seed

    def batch(self, start: int, batch_size: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed + start)
        ranks = rng.zipf(1.3, size=(batch_size, self.seq_len)).astype(np.int64)
        return (ranks % self.vocab_size).astype(np.int32)

    def write_recordio(self, path: str, num_records: int) -> None:
        w = RecordIOWriter(path, self.seq_len)
        for i in range(num_records):
            w.append(self.batch(i, 1)[0])
        w.close()


def make_loader(
    source,
    batch_size: int,
    skip: int = 0,
    prefetch: int = 2,
) -> Iterator[Tuple[int, Dict[str, np.ndarray]]]:
    """Background-prefetching loader yielding (cursor, batch) pairs.

    ``cursor`` is the number of records consumed INCLUDING this batch — save
    it in the checkpoint; pass it back as ``skip`` to resume.
    """
    q: "queue.Queue" = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def produce() -> None:
        cursor = skip
        while not stop.is_set():
            batch = source.batch(cursor, batch_size)
            cursor += batch_size
            q.put((cursor, {"tokens": batch}))

    t = threading.Thread(target=produce, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()
        try:  # unblock the producer if it's waiting on a full queue
            q.get_nowait()
        except queue.Empty:
            pass
