"""Training step: loss, microbatch gradient accumulation, remat.

``make_train_step`` builds the function the dry-run lowers and the training
driver jits: (params, opt_state, batch) -> (params, opt_state, metrics).
Microbatching scans over batch slices accumulating gradients (activations
for only one microbatch are ever live), remat checkpoints each layer-scan
body (saves block inputs only).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.lm import BaseModel
from .optimizer import OptimizerConfig, adamw_update

AUX_LOSS_WEIGHT = 0.01


def make_loss_fn(model: BaseModel, remat: bool = False):
    """Next-token cross entropy (+ MoE aux loss); labels = shifted tokens."""

    def loss_fn(params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        logits, aux = model.forward(params, batch, remat=remat)
        tokens = batch["tokens"]
        tgt = tokens[:, 1:]
        lg = logits[:, :-1].astype(jnp.float32)
        logz = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, tgt[..., None], axis=-1)[..., 0]
        ce = jnp.mean(logz - gold)
        loss = ce + AUX_LOSS_WEIGHT * aux
        return loss, {"loss": loss, "ce": ce, "aux": aux}

    return loss_fn


def make_train_step(
    model: BaseModel,
    opt_cfg: OptimizerConfig,
    microbatches: int = 1,
    remat: bool = True,
    accum_dtype=jnp.float32,
    grad_shardings=None,
    cast_params_once: bool = False,
):
    """``grad_shardings`` (a NamedSharding tree matching params) pins the
    microbatch gradients and the accumulation buffer to the parameters'
    (FSDP) sharding, so GSPMD reduce-scatters each microbatch's gradients
    instead of all-reducing them (~2x less gradient traffic, and the accum
    buffer is shard-sized). ``cast_params_once`` casts the fp32 master
    weights to the compute dtype once per step BEFORE the microbatch loop,
    so FSDP weight all-gathers move bf16, not fp32 (~2x less weight
    traffic); gradients still flow to the fp32 master through the cast."""
    if cast_params_once and model.compute_dtype is not None:
        inner_loss = make_loss_fn(model, remat=remat)

        def loss_fn(params, batch):
            return inner_loss(model._cast(params), batch)

    else:
        loss_fn = make_loss_fn(model, remat=remat)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    accum_dtype = jnp.dtype(accum_dtype)

    def pin(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(
            lambda t, s: jax.lax.with_sharding_constraint(t, s), tree, grad_shardings
        )

    def split_micro(batch):
        def r(t):
            b = t.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return t.reshape((microbatches, b // microbatches) + t.shape[1:])

        return jax.tree.map(r, batch)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (_, metrics), grads = grad_fn(params, batch)
        else:
            micro = split_micro(batch)
            zeros = pin(
                jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
            )

            def acc_body(carry, mb):
                acc, met_sum = carry
                (_, metrics), grads = grad_fn(params, mb)
                grads = pin(grads)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(accum_dtype), acc, grads
                )
                met_sum = jax.tree.map(jnp.add, met_sum, metrics)
                return (acc, met_sum), None

            met0 = {"loss": jnp.float32(0), "ce": jnp.float32(0), "aux": jnp.float32(0)}
            (grads, met_sum), _ = jax.lax.scan(acc_body, (zeros, met0), micro)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m / microbatches, met_sum)
        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg
        )
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return new_params, new_opt, metrics

    return train_step
