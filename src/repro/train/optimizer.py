"""AdamW with warmup+cosine schedule, clipping, and sharded states.

Optimizer states are described as a P-tree mirroring the parameter tree so
the dry-run can lower them as ShapeDtypeStructs and ZeRO-1-shard them (the
states inherit each parameter's sharding, *plus* FSDP-style data-axis
sharding when the policy enables it — see sharding rules).

``m_dtype``/``v_dtype`` allow reduced-precision moments for the largest
configs (llama4 training keeps m in bf16), and ``compress_grads`` applies
int8 quantize/dequantize to the gradient before the update — modelling the
numerics of compressed cross-pod gradient exchange (the bandwidth win
itself needs a shard_map reduction; documented in DESIGN.md).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.params import P, init_params, param_specs, tree_map_defs


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    clip_norm: float = 1.0
    m_dtype: str = "float32"
    v_dtype: str = "float32"
    compress_grads: bool = False


def lr_at(step: jnp.ndarray, cfg: OptimizerConfig) -> jnp.ndarray:
    """Linear warmup then cosine decay to ``min_lr_frac * lr``."""
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.lr * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_frac * cfg.lr + (1 - cfg.min_lr_frac) * cfg.lr * 0.5 * (
        1 + jnp.cos(jnp.pi * prog)
    )
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def opt_state_defs(model_defs, cfg: OptimizerConfig):
    """P-tree for (m, v) mirroring the parameter def tree."""

    def mk(dtype):
        def make(path: str, p: P) -> P:
            return P(p.shape, "zeros", dtype=dtype, axes=p.axes)

        return make

    return {
        "step": P((), "zeros", dtype="int32"),
        "m": tree_map_defs(mk(cfg.m_dtype), model_defs),
        "v": tree_map_defs(mk(cfg.v_dtype), model_defs),
    }


def init_opt_state(model_defs, cfg: OptimizerConfig):
    return init_params(jax.random.PRNGKey(0), opt_state_defs(model_defs, cfg))


def opt_state_specs(model_defs, cfg: OptimizerConfig):
    return param_specs(opt_state_defs(model_defs, cfg))


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), norm


def quantize_int8(g: jnp.ndarray, key: jax.Array) -> jnp.ndarray:
    """Stochastic-rounding int8 quantize/dequantize (per-tensor scale)."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    scaled = gf / scale
    noise = jax.random.uniform(key, g.shape, jnp.float32) - 0.5
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return (q.astype(jnp.float32) * scale).astype(g.dtype)


def compress_gradients(grads, seed: jnp.ndarray):
    """Apply int8 compression numerics leaf-wise (deterministic per leaf)."""
    leaves, treedef = jax.tree.flatten(grads)
    key = jax.random.PRNGKey(seed.astype(jnp.uint32))
    out = [
        quantize_int8(g, jax.random.fold_in(key, i)) for i, g in enumerate(leaves)
    ]
    return jax.tree.unflatten(treedef, out)


def adamw_update(params, grads, state, cfg: OptimizerConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    if cfg.compress_grads:
        grads = compress_gradients(grads, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    lr = lr_at(step, cfg)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        pn, mn, vn = upd(p, g, m, v)
        new_p.append(pn)
        new_m.append(mn)
        new_v.append(vn)
    new_params = jax.tree.unflatten(treedef, new_p)
    new_state = {
        "step": step,
        "m": jax.tree.unflatten(treedef, new_m),
        "v": jax.tree.unflatten(treedef, new_v),
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
