"""Fault-tolerant checkpointing: sharded npz, atomic, checksummed.

Properties required for 1000-node operation:

* **atomicity** — a checkpoint directory is written under a temp name and
  ``os.replace``'d into place; a crash mid-write never corrupts the latest
  good checkpoint;
* **integrity** — every shard file carries a sha256 in the manifest and is
  verified on restore (the platform's data-manager checksum discipline);
* **mesh-shape agnosticism** — leaves are saved as full (unsharded) numpy
  arrays keyed by tree path, so a restart may use a different mesh/device
  count (elastic re-layout happens at load via the current shardings);
* **retention** — keep the last N checkpoints, prune older ones;
* **resume metadata** — step + data-cursor so the loader skips consumed
  batches on restart.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, Any]:
    flat = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            flat.update(_flatten(tree[k], f"{prefix}/{k}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            flat.update(_flatten(v, f"{prefix}/{i}"))
    else:
        flat[prefix] = tree
    return flat


def _unflatten_into(template, flat, prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_into(template[k], flat, f"{prefix}/{k}") for k in template}
    if isinstance(template, (list, tuple)):
        seq = [
            _unflatten_into(v, flat, f"{prefix}/{i}") for i, v in enumerate(template)
        ]
        return type(template)(seq)
    return flat[prefix]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3) -> None:
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- save -------------------------------------------------------------------
    def save(
        self,
        step: int,
        params,
        opt_state=None,
        extra: Optional[Dict[str, Any]] = None,
    ) -> str:
        tree = {"params": params}
        if opt_state is not None:
            tree["opt_state"] = opt_state
        flat = _flatten(tree)
        tmp = tempfile.mkdtemp(dir=self.directory, prefix=".tmp-")
        manifest: Dict[str, Any] = {"step": step, "extra": extra or {}, "shards": {}}
        try:
            for i, (path, leaf) in enumerate(sorted(flat.items())):
                arr = np.asarray(jax.device_get(leaf))
                fn = f"shard-{i:05d}.npz"
                fpath = os.path.join(tmp, fn)
                np.savez(fpath, data=arr)
                with open(fpath, "rb") as f:
                    digest = hashlib.sha256(f.read()).hexdigest()
                manifest["shards"][path] = {
                    "file": fn,
                    "sha256": digest,
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            final = os.path.join(self.directory, f"ckpt-{step:09d}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._prune()
        return final

    def _prune(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.directory, f"ckpt-{s:09d}"), ignore_errors=True)

    # -- load ---------------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("ckpt-"):
                try:
                    out.append(int(name.split("-")[1]))
                except (IndexError, ValueError):
                    continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        step: Optional[int] = None,
        params_template=None,
        opt_template=None,
        shardings=None,
        verify: bool = True,
    ) -> Tuple[Any, Any, Dict[str, Any]]:
        """Restore (params, opt_state, manifest-extra).

        ``shardings`` (optional pytree of NamedSharding matching params)
        re-lays leaves onto the *current* mesh — elastic restart.
        """
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        cdir = os.path.join(self.directory, f"ckpt-{step:09d}")
        with open(os.path.join(cdir, "manifest.json")) as f:
            manifest = json.load(f)
        flat: Dict[str, Any] = {}
        for path, info in manifest["shards"].items():
            fpath = os.path.join(cdir, info["file"])
            if verify:
                with open(fpath, "rb") as f:
                    digest = hashlib.sha256(f.read()).hexdigest()
                if digest != info["sha256"]:
                    raise ValueError(f"checksum mismatch in {fpath}")
            flat[path] = np.load(fpath)["data"]
        tree = {"params": params_template}
        if opt_template is not None:
            tree["opt_state"] = opt_template
        if params_template is None:
            # reconstruct a nested dict purely from paths
            restored = _paths_to_tree(flat)
        else:
            restored = _unflatten_into(tree, flat)
        params = restored["params"]
        opt_state = restored.get("opt_state")
        if shardings is not None:
            params = jax.tree.map(
                lambda a, s: jax.device_put(a, s), params, shardings
            )
        return params, opt_state, {"step": manifest["step"], **manifest.get("extra", {})}


def _paths_to_tree(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for path, leaf in flat.items():
        parts = [p for p in path.split("/") if p]
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return root
