"""Distributed registry (paper F4/F5, §4.5.1).

A key-value store holding (a) registered model manifests and (b) running
agents with their HW/SW stack info. The paper uses an etcd-like distributed
KV store with dynamic registration; we implement the same semantics —
prefix scans, TTL leases with heartbeats, runtime add/delete — over an
in-process store that can optionally persist to a shared JSON file so that
subprocess agents on one host observe a single registry (the single-host
stand-in for the distributed deployment).
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from .manifest import BackendManifest, ModelManifest, SystemRequirements, VersionConstraint


@dataclass
class Entry:
    value: Dict[str, Any]
    expires_at: Optional[float] = None  # None = no lease (static entry)


class KVStore:
    """TTL'd key-value store with prefix scan (the etcd stand-in)."""

    def __init__(self, clock: Callable[[], float] = time.monotonic) -> None:
        self._lock = threading.Lock()
        self._data: Dict[str, Entry] = {}
        self._clock = clock

    def put(self, key: str, value: Dict[str, Any], ttl: Optional[float] = None) -> None:
        expires = self._clock() + ttl if ttl is not None else None
        with self._lock:
            self._data[key] = Entry(value=value, expires_at=expires)

    def update_value(self, key: str, value: Dict[str, Any]) -> bool:
        """Replace a live entry's value, preserving its lease."""
        with self._lock:
            e = self._data.get(key)
            if e is None or self._expired(e):
                self._data.pop(key, None)
                return False
            e.value = value
            return True

    def mutate(self, key: str, fn: Callable[[Dict[str, Any]], Dict[str, Any]]
               ) -> bool:
        """Atomic read-modify-write on a live entry: ``fn`` receives a copy
        of the current value and returns the replacement, all under the
        store lock — the race-free path for counters like agent load (a
        get/modify/update_value sequence can lose concurrent updates)."""
        with self._lock:
            e = self._data.get(key)
            if e is None or self._expired(e):
                self._data.pop(key, None)
                return False
            e.value = fn(dict(e.value))
            return True

    def renew(self, key: str, ttl: float) -> bool:
        """Heartbeat: extend a lease. Returns False if the key expired."""
        with self._lock:
            e = self._data.get(key)
            if e is None or self._expired(e):
                self._data.pop(key, None)
                return False
            e.expires_at = self._clock() + ttl
            return True

    def get(self, key: str) -> Optional[Dict[str, Any]]:
        with self._lock:
            e = self._data.get(key)
            if e is None:
                return None
            if self._expired(e):
                del self._data[key]
                return None
            # a copy: callers must not mutate store state outside the lock
            # (use mutate() for read-modify-write)
            return dict(e.value)

    def delete(self, key: str) -> bool:
        with self._lock:
            return self._data.pop(key, None) is not None

    def scan(self, prefix: str) -> List[Tuple[str, Dict[str, Any]]]:
        with self._lock:
            # expiry cutoff taken INSIDE the lock: a renew that wins the
            # lock first extends the lease and the scan sees it live; one
            # that loses sees the entry purged and returns False — no
            # window where an expired agent is both renewable and listed
            now = self._clock()
            dead = [k for k, e in self._data.items() if self._expired(e, now)]
            for k in dead:
                del self._data[k]
            return sorted(
                (k, dict(e.value))
                for k, e in self._data.items() if k.startswith(prefix)
            )

    def _expired(self, e: Entry, now: Optional[float] = None) -> bool:
        if e.expires_at is None:
            return False
        return (now if now is not None else self._clock()) > e.expires_at

    # -- optional shared-file persistence (single-host "distributed") ------
    def dump(self, path: str) -> None:
        with self._lock:
            # serialize INSIDE the lock: values are live dicts, and a
            # concurrent mutate() mid-json.dump would tear the snapshot
            # (the file write itself stays outside — atomic via rename)
            payload_text = json.dumps({
                k: {"value": e.value, "expires_at": e.expires_at}
                for k, e in self._data.items()
            })
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".")
        with os.fdopen(fd, "w") as f:
            f.write(payload_text)
        os.replace(tmp, path)

    def load(self, path: str) -> None:
        with open(path) as f:
            payload = json.load(f)
        with self._lock:
            for k, d in payload.items():
                self._data[k] = Entry(value=d["value"], expires_at=d.get("expires_at"))


@dataclass
class AgentRecord:
    """A registered agent: its HW/SW stack + models it can serve (§4.4 init)."""

    agent_id: str
    backend: str                 # backend name, e.g. "ref" | "pallas"
    backend_version: str
    system: Dict[str, Any]       # platform, num_devices, memory_bytes, mesh, host
    models: List[str] = field(default_factory=list)  # model manifest keys
    address: str = ""            # in-proc handle name or host:port
    load: int = 0                # outstanding evaluations (for balancing)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "agent_id": self.agent_id,
            "backend": self.backend,
            "backend_version": self.backend_version,
            "system": self.system,
            "models": self.models,
            "address": self.address,
            "load": self.load,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "AgentRecord":
        return cls(**d)


class Registry:
    """The MLModelScope distributed registry facade.

    Namespaces::

        manifests/<name>:<version>   -> model manifest dict
        backends/<name>:<version>    -> backend manifest dict
        agents/<agent_id>            -> AgentRecord dict   (TTL lease)
    """

    AGENT_TTL = 10.0  # seconds; agents heartbeat at TTL/3

    def __init__(self, store: Optional[KVStore] = None) -> None:
        self.store = store or KVStore()

    # -- manifests ---------------------------------------------------------
    def register_manifest(self, manifest: ModelManifest) -> str:
        self.store.put(f"manifests/{manifest.key}", manifest.to_dict())
        return manifest.key

    def register_backend(self, manifest: BackendManifest) -> str:
        self.store.put(f"backends/{manifest.key}", manifest.to_dict())
        return manifest.key

    def unregister_manifest(self, key: str) -> bool:
        return self.store.delete(f"manifests/{key}")

    def manifests(self, name: str = "") -> List[ModelManifest]:
        return [
            ModelManifest.from_dict(v)
            for _, v in self.store.scan(f"manifests/{name}")
        ]

    def find_manifest(
        self, name: str, constraint: str = ""
    ) -> Optional[ModelManifest]:
        """Highest version satisfying the constraint (F5 resolution)."""
        cons = VersionConstraint(constraint)
        best: Optional[ModelManifest] = None
        for m in self.manifests(name):
            if m.name != name or not cons.satisfied_by(m.version):
                continue
            if best is None or _ver(m.version) > _ver(best.version):
                best = m
        return best

    # -- agents --------------------------------------------------------------
    def register_agent(self, record: AgentRecord, ttl: Optional[float] = None) -> None:
        self.store.put(
            f"agents/{record.agent_id}", record.to_dict(), ttl=ttl or self.AGENT_TTL
        )

    def heartbeat(self, agent_id: str, ttl: Optional[float] = None) -> bool:
        return self.store.renew(f"agents/{agent_id}", ttl if ttl is not None else self.AGENT_TTL)

    def deregister_agent(self, agent_id: str) -> bool:
        return self.store.delete(f"agents/{agent_id}")

    def agents(self) -> List[AgentRecord]:
        return [AgentRecord.from_dict(v) for _, v in self.store.scan("agents/")]

    def update_load(self, agent_id: str, delta: int) -> None:
        def bump(rec: Dict[str, Any]) -> Dict[str, Any]:
            rec["load"] = max(0, int(rec.get("load", 0)) + delta)
            return rec

        # atomic RMW under the store lock: two concurrent dispatches must
        # not lose a load increment (get -> modify -> update_value races)
        self.store.mutate(f"agents/{agent_id}", bump)

    # -- resolution (server-side, §4.3 step 3) -------------------------------
    def resolve(
        self,
        model_key: str,
        backend_name: str = "",
        backend_constraint: str = "",
        requirements: Optional[SystemRequirements] = None,
    ) -> List[AgentRecord]:
        """Agents able to run ``model_key`` under the given constraints,
        least-loaded first (the registry load-balances requests, §4.5.1)."""
        cons = VersionConstraint(backend_constraint)
        reqs = requirements or SystemRequirements()
        out = []
        for rec in self.agents():
            if model_key not in rec.models:
                continue
            if backend_name and rec.backend != backend_name:
                continue
            if backend_constraint and not cons.satisfied_by(rec.backend_version):
                continue
            if not reqs.satisfied_by(rec.system):
                continue
            out.append(rec)
        out.sort(key=lambda r: (r.load, r.agent_id))
        return out


def _ver(v: str) -> Tuple[int, ...]:
    from .manifest import parse_version

    return parse_version(v)
