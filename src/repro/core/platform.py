"""Platform bootstrap: wire registry + server + agents + model zoo.

``LocalPlatform`` is the single-host instantiation of the paper's
deployment: one server, N agents (one per backend/"stack"), shared
middleware (registry, tracing server, evaluation DB). The built-in model
manifests (the paper ships >300; we ship the 10 assigned architectures, in
full and reduced versions, plus ResNet-50) are registered at agent
initialization, mirroring workflow step 0.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional

from .agent import Agent, EvaluationRequest
from .evaldb import EvalDB
from .manifest import IOSpec, ModelManifest, ProcessingStep
from .registry import Registry
from .server import Server
from .tracing import TracingServer


def builtin_manifests(reduced: bool = True) -> List[ModelManifest]:
    """Model manifests for the architecture zoo (+ the paper's ResNet-50)."""
    from ..configs import list_archs, get_config

    manifests = []
    for arch in list_archs():
        cfg = get_config(arch, reduced=reduced)
        manifests.append(
            ModelManifest(
                name=arch,
                version="1.0.0",
                description=f"{cfg.family} LM ({arch})",
                arch=arch,
                reduced=reduced,
                inputs=[IOSpec(type="tokens", element_type="int32")],
                outputs=[IOSpec(type="logits", element_type="float32")],
                model_assets={"seed": 0},
                attributes={
                    "family": cfg.family,
                    "vocab_size": cfg.vocab_size,
                    "params": cfg.param_count(),
                    "params_active": cfg.param_count(active_only=True),
                },
            )
        )
    manifests.append(
        ModelManifest(
            name="resnet50",
            version="1.5.0",
            description="ResNet-50 v1.5 (MLPerf reference; the paper's workload)",
            arch="resnet50",
            reduced=reduced,
            inputs=[
                IOSpec(
                    type="image",
                    element_type="float32",
                    steps=[
                        ProcessingStep("decode", {"element_type": "float32"}),
                        ProcessingStep(
                            "resize", {"dimensions": [3, 32 if reduced else 224, 32 if reduced else 224]}
                        ),
                        ProcessingStep(
                            "normalize",
                            {"mean": [123.68, 116.78, 103.94], "rescale": 255.0},
                        ),
                    ],
                )
            ],
            outputs=[
                IOSpec(
                    type="probability",
                    element_type="float32",
                    steps=[ProcessingStep("argsort", {"k": 5})],
                )
            ],
            model_assets={"seed": 0},
            attributes={"family": "vision"},
        )
    )
    return manifests


class LocalPlatform:
    """A fully-wired single-host MLModelScope instance."""

    def __init__(
        self,
        backends: Iterable[str] = ("ref",),
        evaldb_path: str = ":memory:",
        reduced_models: bool = True,
    ) -> None:
        self.registry = Registry()
        self.tracing_server = TracingServer()
        self.evaldb = EvalDB(evaldb_path)
        self.server = Server(self.registry, self.tracing_server, self.evaldb)
        self.agents: Dict[str, Agent] = {}
        manifests = builtin_manifests(reduced=reduced_models)
        for backend in backends:
            agent = Agent(
                backend=backend,
                registry=self.registry,
                tracing_server=self.tracing_server,
                evaldb=self.evaldb,
                lease_ttl=3600.0,   # in-process: alive as long as the process
            )
            agent.register_models(manifests)
            self.server.attach_agent(agent)
            self.agents[agent.agent_id] = agent

    def evaluate(self, req: EvaluationRequest, **kw):
        return self.server.evaluate(req, **kw)

    def analyze(self, **kw):
        return self.server.analyze(**kw)

    def report(self, **kw) -> str:
        return self.server.report(**kw)

    def shutdown(self) -> None:
        for agent in self.agents.values():
            agent.shutdown()
        self.server.shutdown()
        self.evaldb.close()
