"""Streaming evaluation pipeline (paper F6, §4.4.2).

The agent's model-evaluation pipeline is a chain of *pipeline operators*
mapped onto light-weight threads, each pair connected by a bounded queue so
operators form producer/consumer relationships and I/O overlaps compute.
Pre-processing, model inference, and post-processing are all operators.

Built-in operators mirror the manifest's built-in processing steps
(§4.1.1): decode / resize / normalize / tokenize for pre-processing,
argsort / top-k / detokenize for post-processing. Arbitrary-callable
operators are supported (the paper's custom Python functions).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterable, Iterator, List, Optional, Sequence

import numpy as np

from .manifest import ProcessingStep
from .tracing import Tracer, TraceLevel

_END = object()  # stream terminator sentinel


@dataclass
class Item:
    """One element flowing through the pipeline."""

    index: int
    data: Any
    meta: Dict[str, Any]


OpFn = Callable[[Any, Dict[str, Any]], Any]


class Pipeline:
    """A chain of operators executed on threads with bounded channels."""

    def __init__(
        self,
        operators: Sequence[tuple],
        tracer: Optional[Tracer] = None,
        channel_capacity: int = 8,
    ) -> None:
        """``operators`` is a sequence of (name, fn) pairs; fn(data, meta)."""
        if not operators:
            raise ValueError("pipeline requires at least one operator")
        self.operators = list(operators)
        self.tracer = tracer
        self.capacity = channel_capacity

    def run(self, inputs: Iterable[Any]) -> List[Any]:
        """Stream ``inputs`` through all operators; return ordered outputs."""
        return list(self.stream(inputs))

    def stream(self, inputs: Iterable[Any]) -> Iterator[Any]:
        n_ops = len(self.operators)
        channels: List["queue.Queue"] = [
            queue.Queue(maxsize=self.capacity) for _ in range(n_ops + 1)
        ]
        errors: List[BaseException] = []

        def feed() -> None:
            try:
                for i, x in enumerate(inputs):
                    channels[0].put(Item(index=i, data=x, meta={}))
            except BaseException as e:  # noqa: BLE001 - propagated below
                errors.append(e)
            finally:
                channels[0].put(_END)

        def stage(op_idx: int) -> None:
            name, fn = self.operators[op_idx]
            src, dst = channels[op_idx], channels[op_idx + 1]
            try:
                while True:
                    item = src.get()
                    if item is _END:
                        break
                    if self.tracer is not None:
                        with self.tracer.span(
                            f"op:{name}", TraceLevel.MODEL, index=item.index
                        ):
                            item.data = fn(item.data, item.meta)
                    else:
                        item.data = fn(item.data, item.meta)
                    dst.put(item)
            except BaseException as e:  # noqa: BLE001
                errors.append(e)
            finally:
                dst.put(_END)

        threads = [threading.Thread(target=feed, daemon=True)]
        threads += [
            threading.Thread(target=stage, args=(i,), daemon=True)
            for i in range(n_ops)
        ]
        for t in threads:
            t.start()
        out = channels[-1]
        while True:
            item = out.get()
            if item is _END:
                break
            yield item.data
        for t in threads:
            t.join()
        if errors:
            raise errors[0]


# --------------------------------------------------------------------------
# Built-in operators (manifest `steps` -> callables)
# --------------------------------------------------------------------------
def _op_decode(params: Dict[str, Any]) -> OpFn:
    """Decode raw bytes/lists to an ndarray with the given layout."""
    dtype = np.dtype(params.get("element_type", "float32"))

    def fn(data: Any, meta: Dict[str, Any]) -> np.ndarray:
        arr = np.asarray(data, dtype=dtype)
        meta["decoded_shape"] = arr.shape
        return arr

    return fn


def _op_resize(params: Dict[str, Any]) -> OpFn:
    """Nearest-neighbour resize of an HWC image to `dimensions` [C,H,W]."""
    dims = params.get("dimensions")
    if not dims or len(dims) != 3:
        raise ValueError("resize requires dimensions: [C, H, W]")
    c, h, w = dims

    def fn(data: Any, meta: Dict[str, Any]) -> np.ndarray:
        img = np.asarray(data)
        if img.ndim == 2:
            img = img[..., None].repeat(c, axis=-1)
        ih, iw = img.shape[:2]
        ys = np.clip((np.arange(h) * ih / h).astype(int), 0, ih - 1)
        xs = np.clip((np.arange(w) * iw / w).astype(int), 0, iw - 1)
        return img[np.ix_(ys, xs)]

    return fn


def _op_normalize(params: Dict[str, Any]) -> OpFn:
    mean = np.asarray(params.get("mean", 0.0), dtype=np.float32)
    rescale = float(params.get("rescale", 1.0))
    std = np.asarray(params.get("std", 1.0), dtype=np.float32)

    def fn(data: Any, meta: Dict[str, Any]) -> np.ndarray:
        return (np.asarray(data, dtype=np.float32) - mean) / std / rescale

    return fn


def _op_tokenize(params: Dict[str, Any]) -> OpFn:
    """Toy byte-level tokenizer for LM workloads (vocab-mod folding)."""
    vocab = int(params.get("vocab_size", 256))
    max_len = int(params.get("max_len", 128))
    pad_id = int(params.get("pad_id", 0))

    def fn(data: Any, meta: Dict[str, Any]) -> np.ndarray:
        if isinstance(data, str):
            ids = np.frombuffer(data.encode("utf-8"), dtype=np.uint8).astype(np.int32)
            ids = ids % vocab
        else:
            ids = np.asarray(data, dtype=np.int32) % vocab
        out = np.full((max_len,), pad_id, dtype=np.int32)
        n = min(len(ids), max_len)
        out[:n] = ids[:n]
        meta["num_tokens"] = int(n)
        return out

    return fn


def _op_argsort(params: Dict[str, Any]) -> OpFn:
    """Post-process logits/probabilities to top-K (label, score) pairs."""
    k = int(params.get("k", 5))
    labels = params.get("labels")

    def fn(data: Any, meta: Dict[str, Any]) -> List[tuple]:
        probs = np.asarray(data)
        flat = probs.reshape(-1)
        idx = np.argsort(-flat)[:k]
        return [
            (labels[i] if labels and i < len(labels) else int(i), float(flat[i]))
            for i in idx
        ]

    return fn


def _op_topk_tokens(params: Dict[str, Any]) -> OpFn:
    k = int(params.get("k", 1))

    def fn(data: Any, meta: Dict[str, Any]) -> np.ndarray:
        logits = np.asarray(data)
        return np.argsort(-logits, axis=-1)[..., :k]

    return fn


def _op_identity(params: Dict[str, Any]) -> OpFn:
    return lambda data, meta: data


_BUILTIN_OPS: Dict[str, Callable[[Dict[str, Any]], OpFn]] = {
    "decode": _op_decode,
    "resize": _op_resize,
    "normalize": _op_normalize,
    "tokenize": _op_tokenize,
    "argsort": _op_argsort,
    "topk_tokens": _op_topk_tokens,
    "identity": _op_identity,
}


def register_op(name: str, factory: Callable[[Dict[str, Any]], OpFn]) -> None:
    """Extensibility hook (§4.6): add custom pipeline operators."""
    _BUILTIN_OPS[name] = factory


def build_steps(steps: Sequence[ProcessingStep]) -> List[tuple]:
    """Compile manifest processing steps into (name, fn) operator pairs,
    executed in the order they appear in the manifest (§4.1.1)."""
    ops = []
    for s in steps:
        try:
            factory = _BUILTIN_OPS[s.op]
        except KeyError:
            raise KeyError(f"unknown processing op {s.op!r}; have {sorted(_BUILTIN_OPS)}")
        ops.append((s.op, factory(s.params)))
    return ops
