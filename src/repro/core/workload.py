"""Workload generators (paper F7, §4.1.3).

The server generates an inference request load from the benchmarking
scenario: batched inference, or online inference with a configurable
arrival-time distribution (e.g. Poisson). Generators are pluggable.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, List, Optional

import numpy as np


@dataclass
class Request:
    """One inference request in a generated load."""

    request_id: int
    arrival_s: float       # offset from scenario start
    batch_size: int = 1
    tags: Dict[str, object] = field(default_factory=dict)


class WorkloadGenerator:
    name = "base"

    def requests(self) -> Iterator[Request]:  # pragma: no cover - interface
        raise NotImplementedError


class BatchedLoad(WorkloadGenerator):
    """Offline/batched scenario: all requests available at t=0."""

    name = "batched"

    def __init__(self, num_requests: int, batch_size: int) -> None:
        self.num_requests = num_requests
        self.batch_size = batch_size

    def requests(self) -> Iterator[Request]:
        for i in range(self.num_requests):
            yield Request(request_id=i, arrival_s=0.0, batch_size=self.batch_size)


class PoissonLoad(WorkloadGenerator):
    """Online scenario: exponential inter-arrivals at ``rate_hz`` (batch 1)."""

    name = "poisson"

    def __init__(self, num_requests: int, rate_hz: float, seed: int = 0) -> None:
        if rate_hz <= 0:
            raise ValueError("rate_hz must be positive")
        self.num_requests = num_requests
        self.rate_hz = rate_hz
        self.seed = seed

    def requests(self) -> Iterator[Request]:
        rng = np.random.default_rng(self.seed)
        t = 0.0
        for i in range(self.num_requests):
            t += float(rng.exponential(1.0 / self.rate_hz))
            yield Request(request_id=i, arrival_s=t, batch_size=1)


class UniformLoad(WorkloadGenerator):
    """Interactive scenario: fixed-interval arrivals."""

    name = "uniform"

    def __init__(self, num_requests: int, interval_s: float, batch_size: int = 1) -> None:
        self.num_requests = num_requests
        self.interval_s = interval_s
        self.batch_size = batch_size

    def requests(self) -> Iterator[Request]:
        for i in range(self.num_requests):
            yield Request(
                request_id=i, arrival_s=i * self.interval_s, batch_size=self.batch_size
            )


class BurstyLoad(WorkloadGenerator):
    """On/off-modulated Poisson arrivals (interrupted Poisson process).

    Time alternates between an ``on_s``-long burst phase at
    ``rate_hz * burst_factor`` and an ``off_s``-long quiet phase at
    ``rate_hz``.  With ``burst_factor=1`` this degenerates to plain
    Poisson.  The overload story's arrival process: short bursts that
    exceed sustainable capacity while the long-run average does not."""

    name = "bursty"

    def __init__(self, num_requests: int, rate_hz: float,
                 burst_factor: float = 3.0, on_s: float = 1.0,
                 off_s: float = 4.0, seed: int = 0) -> None:
        if rate_hz <= 0:
            raise ValueError("rate_hz must be positive")
        if burst_factor < 1.0:
            raise ValueError("burst_factor must be >= 1")
        if on_s <= 0 or off_s < 0:
            raise ValueError("need on_s > 0 and off_s >= 0")
        self.num_requests = num_requests
        self.rate_hz = rate_hz
        self.burst_factor = burst_factor
        self.on_s = on_s
        self.off_s = off_s
        self.seed = seed

    def requests(self) -> Iterator[Request]:
        rng = np.random.default_rng(self.seed)
        period = self.on_s + self.off_s
        t = 0.0
        for i in range(self.num_requests):
            while True:
                phase = t % period
                in_burst = phase < self.on_s
                rate = self.rate_hz * (self.burst_factor if in_burst else 1.0)
                dt = float(rng.exponential(1.0 / rate))
                # an arrival drawn past the current phase boundary is
                # discarded and the clock restarts at the boundary with the
                # next phase's rate (standard piecewise-constant thinning)
                boundary = self.on_s if in_burst else period
                if phase + dt <= boundary:
                    t += dt
                    break
                t += boundary - phase
            yield Request(
                request_id=i, arrival_s=t, batch_size=1,
                tags={"burst": bool((t % period) < self.on_s)},
            )


class DiurnalLoad(WorkloadGenerator):
    """Sinusoidally rate-modulated Poisson arrivals (diurnal cycle).

    Instantaneous rate ``rate_hz * (1 + amplitude * sin(2*pi*t/period_s))``
    sampled by Lewis-Shedler thinning against the peak rate, so the
    arrival process is an exact non-homogeneous Poisson process."""

    name = "diurnal"

    def __init__(self, num_requests: int, rate_hz: float,
                 period_s: float = 60.0, amplitude: float = 0.8,
                 seed: int = 0) -> None:
        if rate_hz <= 0:
            raise ValueError("rate_hz must be positive")
        if not 0.0 <= amplitude < 1.0:
            raise ValueError("amplitude must be in [0, 1)")
        if period_s <= 0:
            raise ValueError("period_s must be positive")
        self.num_requests = num_requests
        self.rate_hz = rate_hz
        self.period_s = period_s
        self.amplitude = amplitude
        self.seed = seed

    def _rate(self, t: float) -> float:
        return self.rate_hz * (
            1.0 + self.amplitude * math.sin(2.0 * math.pi * t / self.period_s)
        )

    def requests(self) -> Iterator[Request]:
        rng = np.random.default_rng(self.seed)
        peak = self.rate_hz * (1.0 + self.amplitude)
        t = 0.0
        for i in range(self.num_requests):
            while True:
                t += float(rng.exponential(1.0 / peak))
                if rng.random() < self._rate(t) / peak:
                    break
            yield Request(request_id=i, arrival_s=t, batch_size=1)


class MultiTenantLoad(WorkloadGenerator):
    """Superposition of independent per-tenant Poisson streams.

    Each tenant is a dict with at least ``name`` and ``rate_hz``; optional
    keys ``num_requests`` (default ``num_requests`` split evenly),
    ``priority``, ``slo_ms``, ``prompt_len``, ``gen_tokens`` ride along in
    each request's tags so scheduler-level scenarios can submit with the
    tenant's identity and shape (prefill-heavy vs decode-heavy mixes are
    just different prompt_len/gen_tokens per tenant).  Streams are merged
    by arrival time and re-numbered globally."""

    name = "multi_tenant"

    def __init__(self, num_requests: int,
                 tenants: List[Dict[str, object]], seed: int = 0) -> None:
        if not tenants:
            raise ValueError("need at least one tenant")
        for t in tenants:
            if "name" not in t or float(t.get("rate_hz", 0.0)) <= 0:
                raise ValueError(
                    "each tenant needs a name and a positive rate_hz"
                )
        self.num_requests = num_requests
        self.tenants = [dict(t) for t in tenants]
        self.seed = seed

    def requests(self) -> Iterator[Request]:
        per_default = max(1, self.num_requests // len(self.tenants))
        merged: List[Request] = []
        for k, spec in enumerate(self.tenants):
            rng = np.random.default_rng((self.seed, k))
            n = int(spec.get("num_requests", per_default))
            rate = float(spec["rate_hz"])
            tags = {
                "tenant": str(spec["name"]),
                "priority": int(spec.get("priority", 1)),
                "slo_ms": float(spec.get("slo_ms", 0.0)),
                "prompt_len": int(spec.get("prompt_len", 0)),
                "gen_tokens": int(spec.get("gen_tokens", 0)),
            }
            t = 0.0
            for _ in range(n):
                t += float(rng.exponential(1.0 / rate))
                merged.append(Request(
                    request_id=0, arrival_s=t, batch_size=1,
                    tags=dict(tags),
                ))
        merged.sort(key=lambda r: r.arrival_s)
        for i, req in enumerate(merged):
            req.request_id = i
            yield req


class SingleStreamLoad(BatchedLoad):
    """MLPerf single-stream: back-to-back batch-1 requests (latency-bound)."""

    name = "single_stream"

    def __init__(self, num_requests: int) -> None:
        super().__init__(num_requests, 1)


class TraceReplayLoad(WorkloadGenerator):
    """Custom/emerging workloads: replay recorded (arrival, batch) pairs.

    ``tags`` optionally carries per-request metadata recorded with the
    trace (e.g. the shared-prefix composition of replayed prompts), passed
    through on each :class:`Request` so scheduler-level scenarios can
    reconstruct the prompt mix."""

    name = "trace"

    def __init__(self, arrivals: List[float], batch_sizes: Optional[List[int]] = None,
                 tags: Optional[List[Dict[str, object]]] = None) -> None:
        self.arrivals = list(arrivals)
        self.batch_sizes = list(batch_sizes) if batch_sizes else [1] * len(self.arrivals)
        if len(self.batch_sizes) != len(self.arrivals):
            raise ValueError("arrivals and batch_sizes length mismatch")
        self.tags = list(tags) if tags else None
        if self.tags is not None and len(self.tags) != len(self.arrivals):
            raise ValueError("arrivals and tags length mismatch")

    def requests(self) -> Iterator[Request]:
        for i, (t, b) in enumerate(zip(self.arrivals, self.batch_sizes)):
            yield Request(
                request_id=i, arrival_s=float(t), batch_size=int(b),
                tags=dict(self.tags[i]) if self.tags else {},
            )


class SharedPrefixLoad(WorkloadGenerator):
    """Shared-prefix serving mix: the workload the prefix cache eats.

    A configurable fraction (``share_ratio``) of requests reuse one of
    ``num_groups`` common prompt prefixes of ``prefix_len`` tokens (system
    prompts / few-shot templates), each followed by a ``suffix_len``-token
    unique tail; the rest are fully unique prompts of the same total
    length.  Arrivals are Poisson at ``rate_hz`` (all at t=0 when 0).  The
    generator emits *composition tags*, not tokens — ``prefix_group`` (-1
    for unique requests), ``prefix_len`` and ``prompt_len`` — so scheduler-
    level scenarios measure the mix without a tokenizer, and
    :func:`shared_prefix_prompts` materializes token arrays for the engine
    (same-group requests share their first ``prefix_len`` tokens
    bit-for-bit)."""

    name = "shared_prefix"

    def __init__(self, num_requests: int, rate_hz: float = 0.0,
                 prefix_len: int = 64, suffix_len: int = 16,
                 share_ratio: float = 0.75, num_groups: int = 1,
                 seed: int = 0) -> None:
        if prefix_len < 0 or suffix_len < 0:
            raise ValueError("prefix_len and suffix_len must be >= 0")
        if not 0.0 <= share_ratio <= 1.0:
            raise ValueError("share_ratio must be in [0, 1]")
        if num_groups < 1:
            raise ValueError("num_groups must be >= 1")
        self.num_requests = num_requests
        self.rate_hz = rate_hz
        self.prefix_len = prefix_len
        self.suffix_len = suffix_len
        self.share_ratio = share_ratio
        self.num_groups = num_groups
        self.seed = seed

    def requests(self) -> Iterator[Request]:
        rng = np.random.default_rng(self.seed)
        t = 0.0
        total = self.prefix_len + self.suffix_len
        for i in range(self.num_requests):
            if self.rate_hz > 0:
                t += float(rng.exponential(1.0 / self.rate_hz))
            shared = bool(rng.random() < self.share_ratio)
            group = int(rng.integers(0, self.num_groups)) if shared else -1
            yield Request(
                request_id=i,
                arrival_s=t,
                batch_size=1,
                tags={
                    "prefix_group": group,
                    "prefix_len": self.prefix_len if shared else 0,
                    "prompt_len": total,
                },
            )


def shared_prefix_prompts(
    requests: List[Request], vocab_size: int, seed: int = 0
) -> List["np.ndarray"]:
    """Materialize token arrays for a shared-prefix load: requests tagged
    with the same ``prefix_group`` (>= 0) share their first ``prefix_len``
    tokens bit-for-bit (generated once per group from ``seed``); the
    remainder of every prompt is unique.  The engine-side counterpart of
    :class:`SharedPrefixLoad` — prompts feed ``serve_paged`` directly."""
    rng = np.random.default_rng(seed)
    prefixes: Dict[int, np.ndarray] = {}
    prompts: List[np.ndarray] = []
    for req in requests:
        total = int(req.tags.get("prompt_len", 0))
        plen = int(req.tags.get("prefix_len", 0))
        group = int(req.tags.get("prefix_group", -1))
        if group >= 0 and plen > 0:
            if group not in prefixes:
                grng = np.random.default_rng((seed, group))
                prefixes[group] = grng.integers(
                    0, vocab_size, (plen,)
                ).astype(np.int32)
            tail = rng.integers(0, vocab_size, (total - plen,)).astype(np.int32)
            prompts.append(np.concatenate([prefixes[group], tail]))
        else:
            prompts.append(rng.integers(0, vocab_size, (total,)).astype(np.int32))
    return prompts


_GENERATORS: Dict[str, Callable[..., WorkloadGenerator]] = {
    "batched": BatchedLoad,
    "poisson": PoissonLoad,
    "uniform": UniformLoad,
    "trace": TraceReplayLoad,
    "single_stream": SingleStreamLoad,
    # the server scenario's open-loop arrival process is Poisson
    "server": PoissonLoad,
    # shared-prefix request mixes (system prompts / few-shot templates)
    "shared_prefix": SharedPrefixLoad,
    # overload / multi-tenant arrival processes
    "bursty": BurstyLoad,
    "diurnal": DiurnalLoad,
    "multi_tenant": MultiTenantLoad,
}


def register_generator(name: str, factory: Callable[..., WorkloadGenerator]) -> None:
    """Pluggable workload generators (§1)."""
    _GENERATORS[name] = factory


def make_generator(name: str, **kwargs) -> WorkloadGenerator:
    try:
        return _GENERATORS[name](**kwargs)
    except KeyError:
        raise KeyError(f"unknown workload generator {name!r}; have {sorted(_GENERATORS)}")
