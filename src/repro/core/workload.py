"""Workload generators (paper F7, §4.1.3).

The server generates an inference request load from the benchmarking
scenario: batched inference, or online inference with a configurable
arrival-time distribution (e.g. Poisson). Generators are pluggable.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, Iterator, List, Optional

import numpy as np


@dataclass
class Request:
    """One inference request in a generated load."""

    request_id: int
    arrival_s: float       # offset from scenario start
    batch_size: int = 1
    tags: Dict[str, object] = field(default_factory=dict)


class WorkloadGenerator:
    name = "base"

    def requests(self) -> Iterator[Request]:  # pragma: no cover - interface
        raise NotImplementedError


class BatchedLoad(WorkloadGenerator):
    """Offline/batched scenario: all requests available at t=0."""

    name = "batched"

    def __init__(self, num_requests: int, batch_size: int) -> None:
        self.num_requests = num_requests
        self.batch_size = batch_size

    def requests(self) -> Iterator[Request]:
        for i in range(self.num_requests):
            yield Request(request_id=i, arrival_s=0.0, batch_size=self.batch_size)


class PoissonLoad(WorkloadGenerator):
    """Online scenario: exponential inter-arrivals at ``rate_hz`` (batch 1)."""

    name = "poisson"

    def __init__(self, num_requests: int, rate_hz: float, seed: int = 0) -> None:
        if rate_hz <= 0:
            raise ValueError("rate_hz must be positive")
        self.num_requests = num_requests
        self.rate_hz = rate_hz
        self.seed = seed

    def requests(self) -> Iterator[Request]:
        rng = np.random.default_rng(self.seed)
        t = 0.0
        for i in range(self.num_requests):
            t += float(rng.exponential(1.0 / self.rate_hz))
            yield Request(request_id=i, arrival_s=t, batch_size=1)


class UniformLoad(WorkloadGenerator):
    """Interactive scenario: fixed-interval arrivals."""

    name = "uniform"

    def __init__(self, num_requests: int, interval_s: float, batch_size: int = 1) -> None:
        self.num_requests = num_requests
        self.interval_s = interval_s
        self.batch_size = batch_size

    def requests(self) -> Iterator[Request]:
        for i in range(self.num_requests):
            yield Request(
                request_id=i, arrival_s=i * self.interval_s, batch_size=self.batch_size
            )


class SingleStreamLoad(BatchedLoad):
    """MLPerf single-stream: back-to-back batch-1 requests (latency-bound)."""

    name = "single_stream"

    def __init__(self, num_requests: int) -> None:
        super().__init__(num_requests, 1)


class TraceReplayLoad(WorkloadGenerator):
    """Custom/emerging workloads: replay recorded (arrival, batch) pairs."""

    name = "trace"

    def __init__(self, arrivals: List[float], batch_sizes: Optional[List[int]] = None) -> None:
        self.arrivals = list(arrivals)
        self.batch_sizes = list(batch_sizes) if batch_sizes else [1] * len(self.arrivals)
        if len(self.batch_sizes) != len(self.arrivals):
            raise ValueError("arrivals and batch_sizes length mismatch")

    def requests(self) -> Iterator[Request]:
        for i, (t, b) in enumerate(zip(self.arrivals, self.batch_sizes)):
            yield Request(request_id=i, arrival_s=float(t), batch_size=int(b))


_GENERATORS: Dict[str, Callable[..., WorkloadGenerator]] = {
    "batched": BatchedLoad,
    "poisson": PoissonLoad,
    "uniform": UniformLoad,
    "trace": TraceReplayLoad,
    "single_stream": SingleStreamLoad,
    # the server scenario's open-loop arrival process is Poisson
    "server": PoissonLoad,
}


def register_generator(name: str, factory: Callable[..., WorkloadGenerator]) -> None:
    """Pluggable workload generators (§1)."""
    _GENERATORS[name] = factory


def make_generator(name: str, **kwargs) -> WorkloadGenerator:
    try:
        return _GENERATORS[name](**kwargs)
    except KeyError:
        raise KeyError(f"unknown workload generator {name!r}; have {sorted(_GENERATORS)}")
