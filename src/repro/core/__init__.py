"""MLModelScope-JAX core: the paper's primary contribution.

Subsystems (paper objective in brackets):

* :mod:`.manifest`   — benchmarking specification, versioning [F1, F2, F5]
* :mod:`.registry`   — distributed registry, agent resolution [F4, F5]
* :mod:`.predictor`  — 3-function predictor interface [F2, F3]
* :mod:`.pipeline`   — streaming evaluation pipeline [F6]
* :mod:`.scenarios`  — benchmarking scenarios [F7]
* :mod:`.workload`   — pluggable request-load generators [F7]
* :mod:`.analysis`   — automated analysis & reporting [F8]
* :mod:`.tracing`    — across-stack tracing [F9]
* :mod:`.evaldb`     — evaluation database [F4, F8]
* :mod:`.agent`      — evaluation agents [F2, F4]
* :mod:`.server`     — dispatch, failover, straggler mitigation [F4]
"""
from ..serve.scheduler import RequestScheduler, SchedulerConfig, SchedulerQueueFull
from .agent import Agent, DataManager, EvaluationRequest
from .analysis import (
    latency_summary,
    percentile,
    scheduler_summary,
    slo_attainment,
    throughput_scalability,
    top_layers,
    trimmed_mean,
)
from .evaldb import EvalDB, EvaluationRecord
from .manifest import (
    BackendManifest,
    ModelManifest,
    SystemRequirements,
    VersionConstraint,
)
from .pipeline import Pipeline, build_steps, register_op
from .predictor import (
    CallablePredictor,
    OpenRequest,
    Predictor,
    PredictorHandle,
    available_backends,
    make_predictor,
    register_predictor,
)
from .registry import AgentRecord, KVStore, Registry
from .scenarios import (
    Scenario,
    ScenarioSpec,
    make_scenario,
    register_scenario,
    run_scenario,
    scenario_kinds,
)
from .server import DispatchError, DispatchPolicy, Server
from .tracing import NullTracer, Span, Tracer, TraceLevel, TracingServer
from .workload import (
    BatchedLoad,
    PoissonLoad,
    Request,
    TraceReplayLoad,
    UniformLoad,
    make_generator,
    register_generator,
)

__all__ = [
    "Agent",
    "AgentRecord",
    "BackendManifest",
    "BatchedLoad",
    "CallablePredictor",
    "DataManager",
    "DispatchError",
    "DispatchPolicy",
    "EvalDB",
    "EvaluationRecord",
    "EvaluationRequest",
    "KVStore",
    "ModelManifest",
    "NullTracer",
    "OpenRequest",
    "Pipeline",
    "PoissonLoad",
    "Predictor",
    "PredictorHandle",
    "Registry",
    "Request",
    "RequestScheduler",
    "Scenario",
    "ScenarioSpec",
    "SchedulerConfig",
    "SchedulerQueueFull",
    "Server",
    "Span",
    "SystemRequirements",
    "TraceLevel",
    "TraceReplayLoad",
    "Tracer",
    "TracingServer",
    "UniformLoad",
    "VersionConstraint",
    "available_backends",
    "build_steps",
    "latency_summary",
    "make_generator",
    "make_predictor",
    "make_scenario",
    "percentile",
    "register_generator",
    "register_op",
    "register_predictor",
    "register_scenario",
    "run_scenario",
    "scenario_kinds",
    "scheduler_summary",
    "slo_attainment",
    "throughput_scalability",
    "top_layers",
    "trimmed_mean",
]
