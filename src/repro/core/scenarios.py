"""Benchmarking scenarios (paper F7, §4.1.3 / §5.1).

A scenario couples a workload generator with the measurement protocol.  Each
scenario is a :class:`Scenario` class that *submits* requests to the shared
:class:`~repro.serve.scheduler.RequestScheduler` (asynchronous completion
futures) instead of calling the predict function inline, so queueing,
micro-batching and admission effects are measured identically everywhere.

Six kinds (the first three predate the scheduler and keep their exact
metrics via the compatibility shim in :func:`run_scenario`; the last three
are the MLPerf-loadgen-style additions):

* ``online``        — batch-1 Poisson arrivals, closed loop; trimmed-mean +
                      90th-percentile latency.
* ``batched``       — fixed-batch back-to-back; throughput sweep over batch
                      sizes yields max throughput + optimal batch (Table 2).
* ``trace``         — replay of a recorded arrival process.
* ``single_stream`` — back-to-back batch-1, latency-bound; p99 latency and
                      streams/sec.
* ``server``        — Poisson arrivals, *open loop* through the scheduler's
                      micro-batching; p99 latency SLO accounting and
                      achieved-QPS.
* ``offline``       — submit-everything-at-once, max-throughput; the
                      scheduler coalesces up to ``max_batch`` per call.

Scenarios drive a *predict function* ``fn(batch_size) -> None`` supplied by
the agent; they own timing and metric computation so every model/backend is
measured identically (F2 consistent evaluation).  ``clock``/``sleep`` are
injectable, making every scenario a deterministic discrete-event simulation
under a fake clock (the paper allows simulated time in traces).
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, List, Optional, Sequence, Type, Union

from ..serve.scheduler import (
    PRIORITY_TIERS,
    RequestScheduler,
    ScheduledRequest,
    SchedulerConfig,
    TenantSpec,
)
from .analysis import jain_index, latency_summary, percentile, slo_attainment
from .tracing import Tracer, TraceLevel
from .workload import (
    BatchedLoad,
    MultiTenantLoad,
    PoissonLoad,
    Request,
    SharedPrefixLoad,
    TraceReplayLoad,
)


@dataclass
class ScenarioSpec:
    """User-selected benchmarking scenario (part of the user input)."""

    kind: str = "online"            # online | batched | trace | single_stream | server | offline
    num_requests: int = 32
    batch_size: int = 1
    rate_hz: float = 50.0           # online/server arrival rate
    warmup: int = 3
    batch_sizes: Optional[List[int]] = None   # batched sweep
    arrivals: Optional[List[float]] = None    # trace replay
    seed: int = 0
    slo_ms: float = 100.0           # server scenario p99 latency SLO
    # shared-prefix request mix (server/trace kinds): prefix_len > 0 swaps
    # the arrival process for a SharedPrefixLoad whose requests carry the
    # prompt-composition tags the paged engine's prefix cache feeds on
    prefix_len: int = 0             # shared-prefix tokens (0 = plain load)
    prefix_share: float = 0.75      # fraction of requests reusing a prefix
    prefix_groups: int = 1          # distinct shared prefixes
    suffix_len: int = 16            # unique tail tokens per request
    # multi-tenant SLO serving (server kind): tenant dicts become a
    # MultiTenantLoad arrival mix plus per-tenant TenantSpec entries
    # (priority tier, weight, token-bucket rate/burst) in the scheduler;
    # priority_mix assigns tiers to a single-tenant load by fraction
    # (e.g. {"best_effort": 0.25, "standard": 0.5, "premium": 0.25});
    # fairness=False degrades dequeue to pure FIFO (the baseline)
    tenants: Optional[List[Dict[str, Any]]] = None
    priority_mix: Optional[Dict[str, float]] = None
    fairness: bool = True

    def to_dict(self) -> Dict[str, Any]:
        return {
            "kind": self.kind,
            "num_requests": self.num_requests,
            "batch_size": self.batch_size,
            "rate_hz": self.rate_hz,
            "warmup": self.warmup,
            "batch_sizes": self.batch_sizes,
            "arrivals": self.arrivals,
            "seed": self.seed,
            "slo_ms": self.slo_ms,
            "prefix_len": self.prefix_len,
            "prefix_share": self.prefix_share,
            "prefix_groups": self.prefix_groups,
            "suffix_len": self.suffix_len,
            "tenants": self.tenants,
            "priority_mix": self.priority_mix,
            "fairness": self.fairness,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ScenarioSpec":
        return cls(**{k: v for k, v in d.items() if k in cls.__dataclass_fields__})


PredictFn = Callable[[int], Any]


class _SchedulerTrace:
    """Adapter publishing scheduler batch events at MODEL level so the
    default trace level records the queue-depth / occupancy series."""

    def __init__(self, tracer: Tracer) -> None:
        self._tracer = tracer

    def event(self, name: str, begin: float, end: float, **tags: Any) -> None:
        self._tracer.event(name, begin, end, TraceLevel.MODEL, **tags)


class Scenario:
    """Base scenario: workload generation + submission + metric computation.

    Subclasses override :meth:`run`.  All requests flow through a
    :class:`RequestScheduler` built over the predict function — closed-loop
    kinds use a degenerate batch-1 scheduler, open-loop kinds exercise
    micro-batch coalescing and the bounded queue.
    """

    kind = "base"
    #: scheduler used when the caller does not thread a SchedulerConfig
    default_scheduler = SchedulerConfig(max_batch=1, batch_timeout_ms=0.0)

    def __init__(self, spec: ScenarioSpec) -> None:
        self.spec = spec

    # -- plumbing ------------------------------------------------------------
    def make_scheduler(
        self,
        predict: PredictFn,
        tracer: Tracer,
        clock: Callable[[], float],
        sleep: Callable[[float], None],
        config: Optional[SchedulerConfig],
    ) -> RequestScheduler:
        cfg = config or self.default_scheduler
        if not self.spec.fairness and cfg.fairness:
            cfg = replace(cfg, fairness=False)
        tenant_specs = [
            TenantSpec.from_dict(t) for t in (self.spec.tenants or [])
        ]

        def execute(batch: List[ScheduledRequest]) -> None:
            total = sum(r.batch_size for r in batch)
            with tracer.span(
                "predict",
                TraceLevel.MODEL,
                batch=total,
                coalesced=len(batch),
                request_id=batch[0].request_id,
            ):
                predict(total)

        return RequestScheduler(
            execute, cfg, clock=clock, sleep=sleep,
            tracer=_SchedulerTrace(tracer), tenants=tenant_specs,
        )

    def warmup(self, predict: PredictFn, tracer: Tracer, batch: int) -> None:
        for _ in range(self.spec.warmup):
            with tracer.span("warmup", TraceLevel.MODEL, batch=batch):
                predict(batch)

    def closed_loop(
        self,
        requests: Sequence[Request],
        sched: RequestScheduler,
        clock: Callable[[], float],
        sleep: Callable[[float], None],
        t0: float,
        honor_arrivals: bool,
    ) -> List[Dict[str, float]]:
        """Submit each request and wait for its future (sequential issue),
        recording per-request service + queueing latency — the legacy
        ``_measure`` protocol, now on the scheduler code path."""
        rows = []
        for req in requests:
            if honor_arrivals:
                now = clock() - t0
                if req.arrival_s > now:
                    sleep(req.arrival_s - now)
            fut = sched.submit(
                batch_size=req.batch_size, arrival_s=t0 + req.arrival_s
            )
            fut.result()
            r = fut.request
            rows.append(
                {
                    "request_id": req.request_id,
                    "batch_size": req.batch_size,
                    "arrival_s": req.arrival_s,
                    "start_s": r.start_s - t0,
                    "latency_s": r.service_s,
                    # queueing delay: intended arrival -> service start
                    "queue_s": max(0.0, (r.start_s - t0) - req.arrival_s),
                }
            )
        return rows

    def scheduler_metrics(self, sched: RequestScheduler) -> Dict[str, float]:
        return {f"sched_{k}": v for k, v in sched.stats().items()}

    # -- interface -----------------------------------------------------------
    def run(
        self,
        predict: PredictFn,
        tracer: Tracer,
        clock: Callable[[], float],
        sleep: Callable[[float], None],
        scheduler: Optional[SchedulerConfig] = None,
    ) -> Dict[str, Any]:
        raise NotImplementedError


class OnlineScenario(Scenario):
    """Closed-loop batch-1 Poisson arrivals (the paper's online scenario)."""

    kind = "online"

    def run(self, predict, tracer, clock, sleep, scheduler=None):
        spec = self.spec
        self.warmup(predict, tracer, 1)
        sched = self.make_scheduler(predict, tracer, clock, sleep, scheduler)
        load = PoissonLoad(spec.num_requests, spec.rate_hz, seed=spec.seed)
        with tracer.span("scenario:online", TraceLevel.MODEL, rate_hz=spec.rate_hz):
            t0 = clock()
            rows = self.closed_loop(list(load.requests()), sched, clock, sleep, t0, True)
        lat = [r["latency_s"] for r in rows]
        metrics = latency_summary(lat)
        metrics.update(
            {
                "scenario": "online",
                "num_requests": len(rows),
                "mean_queue_s": sum(r["queue_s"] for r in rows) / max(len(rows), 1),
            }
        )
        return metrics


class BatchedScenario(Scenario):
    """Throughput at each batch size; max throughput + optimal batch size."""

    kind = "batched"

    def run(self, predict, tracer, clock, sleep, scheduler=None):
        spec = self.spec
        batch_sizes = spec.batch_sizes or [spec.batch_size]
        per_batch: Dict[int, Dict[str, float]] = {}
        for bs in batch_sizes:
            self.warmup(predict, tracer, bs)
            sched = self.make_scheduler(predict, tracer, clock, sleep, scheduler)
            load = BatchedLoad(spec.num_requests, bs)
            with tracer.span("scenario:batched", TraceLevel.MODEL, batch=bs):
                t0 = clock()
                rows = self.closed_loop(
                    list(load.requests()), sched, clock, sleep, t0, False
                )
                elapsed = clock() - t0
            inputs = sum(r["batch_size"] for r in rows)
            lat = [r["latency_s"] for r in rows]
            per_batch[bs] = {
                "throughput_ips": inputs / elapsed if elapsed > 0 else float("inf"),
                **latency_summary(lat),
            }
        best_bs = max(per_batch, key=lambda b: per_batch[b]["throughput_ips"])
        return {
            "scenario": "batched",
            "per_batch": {str(k): v for k, v in per_batch.items()},
            "max_throughput_ips": per_batch[best_bs]["throughput_ips"],
            "optimal_batch_size": best_bs,
        }


class TraceScenario(Scenario):
    """Replay of a recorded arrival process (closed loop)."""

    kind = "trace"

    def run(self, predict, tracer, clock, sleep, scheduler=None):
        spec = self.spec
        if not spec.arrivals:
            raise ValueError("trace scenario requires arrivals")
        self.warmup(predict, tracer, spec.batch_size)
        sched = self.make_scheduler(predict, tracer, clock, sleep, scheduler)
        tags = None
        if spec.prefix_len > 0:
            # replayed traces with shared prompt prefixes: stamp each
            # replayed request with the composition tags a SharedPrefixLoad
            # of the same seed would emit, so the trace exercises the
            # prefix cache exactly like the server mix does
            tags = [
                r.tags
                for r in SharedPrefixLoad(
                    len(spec.arrivals),
                    prefix_len=spec.prefix_len,
                    suffix_len=spec.suffix_len,
                    share_ratio=spec.prefix_share,
                    num_groups=spec.prefix_groups,
                    seed=spec.seed,
                ).requests()
            ]
        load = TraceReplayLoad(
            spec.arrivals, [spec.batch_size] * len(spec.arrivals), tags=tags
        )
        with tracer.span("scenario:trace", TraceLevel.MODEL):
            t0 = clock()
            rows = self.closed_loop(list(load.requests()), sched, clock, sleep, t0, True)
        lat = [r["latency_s"] for r in rows]
        metrics = latency_summary(lat)
        metrics.update({"scenario": "trace", "num_requests": len(rows)})
        if tags is not None:
            shared = sum(1 for t in tags if t.get("prefix_group", -1) >= 0)
            metrics.update(
                {
                    "prefix_len": spec.prefix_len,
                    "shared_prefix_requests": shared,
                    "shared_prefix_fraction": shared / max(len(tags), 1),
                }
            )
        return metrics


class SingleStreamScenario(Scenario):
    """MLPerf single-stream: back-to-back batch-1 requests, latency-bound."""

    kind = "single_stream"

    def run(self, predict, tracer, clock, sleep, scheduler=None):
        spec = self.spec
        self.warmup(predict, tracer, 1)
        sched = self.make_scheduler(predict, tracer, clock, sleep, scheduler)
        load = BatchedLoad(spec.num_requests, 1)
        with tracer.span("scenario:single_stream", TraceLevel.MODEL):
            t0 = clock()
            rows = self.closed_loop(list(load.requests()), sched, clock, sleep, t0, False)
            elapsed = clock() - t0
        lat = [r["latency_s"] for r in rows]
        metrics = latency_summary(lat)
        metrics.update(
            {
                "scenario": "single_stream",
                "num_requests": len(rows),
                "p99_ms": percentile(lat, 99.0) * 1e3,
                "streams_per_s": len(rows) / elapsed if elapsed > 0 else float("inf"),
            }
        )
        return metrics


class ServerScenario(Scenario):
    """MLPerf server: open-loop Poisson arrivals through the micro-batching
    scheduler, with p99-latency SLO accounting and achieved-QPS."""

    kind = "server"
    default_scheduler = SchedulerConfig(max_batch=4, batch_timeout_ms=2.0)

    def run(self, predict, tracer, clock, sleep, scheduler=None):
        spec = self.spec
        self.warmup(predict, tracer, 1)
        sched = self.make_scheduler(predict, tracer, clock, sleep, scheduler)
        multi = bool(spec.tenants or spec.priority_mix)
        if spec.tenants:
            # multi-tenant mix: superposed per-tenant Poisson streams whose
            # tags carry each tenant's identity, tier, SLO and token shape
            tdicts = [dict(t) for t in spec.tenants]
            for t in tdicts:
                t.setdefault("rate_hz", spec.rate_hz / len(tdicts))
                t.setdefault("slo_ms", spec.slo_ms)
            load = MultiTenantLoad(
                spec.num_requests, tdicts, seed=spec.seed
            )
        elif spec.prefix_len > 0:
            # shared-prefix server mix: Poisson arrivals whose requests
            # carry prompt-composition tags (prefix group / lengths) so the
            # scheduler path — and the paged engine behind it — sees the
            # request mix the prefix cache is built for
            load = SharedPrefixLoad(
                spec.num_requests,
                rate_hz=spec.rate_hz,
                prefix_len=spec.prefix_len,
                suffix_len=spec.suffix_len,
                share_ratio=spec.prefix_share,
                num_groups=spec.prefix_groups,
                seed=spec.seed,
            )
        else:
            load = PoissonLoad(spec.num_requests, spec.rate_hz, seed=spec.seed)
        mix_rng = random.Random(spec.seed) if spec.priority_mix else None
        tiers: List[int] = []
        weights: List[float] = []
        if spec.priority_mix:
            for name, frac in spec.priority_mix.items():
                tiers.append(
                    PRIORITY_TIERS.index(name)
                    if name in PRIORITY_TIERS else int(name)
                )
                weights.append(float(frac))

        def submit_kwargs(req: Request) -> Dict[str, Any]:
            if spec.tenants:
                tags = req.tags
                cost = float(
                    int(tags.get("prompt_len", 0))
                    + int(tags.get("gen_tokens", 0))
                )
                return {
                    "tenant": str(tags.get("tenant", "default")),
                    "priority": int(tags.get("priority", 1)),
                    "slo_ms": float(tags.get("slo_ms") or spec.slo_ms),
                    "cost_tokens": cost if cost > 0 else None,
                }
            if mix_rng is not None:
                return {
                    "priority": mix_rng.choices(tiers, weights)[0],
                    "slo_ms": spec.slo_ms,
                }
            return {}

        with tracer.span("scenario:server", TraceLevel.MODEL, rate_hz=spec.rate_hz):
            t0 = clock()
            futs = [
                sched.submit(
                    payload=req.tags or None,
                    batch_size=1,
                    arrival_s=t0 + req.arrival_s,
                    **submit_kwargs(req),
                )
                for req in load.requests()
            ]
            sched.run_until_idle()
        reqs = [f.request for f in futs]
        done = [r for r in reqs if r.status == "completed"] if multi else reqs
        # end-to-end latency including queueing: completion - arrival
        lat = [r.latency_s for r in done]
        makespan = max(r.end_s for r in reqs) - t0
        n = len(reqs)
        p99 = percentile(lat, 99.0) * 1e3 if lat else float("nan")
        metrics = latency_summary(lat)
        metrics.update(
            {
                "scenario": "server",
                "num_requests": n,
                "p99_ms": p99,
                "achieved_qps": (
                    len(done) / makespan if makespan > 0 else float("inf")
                ),
                "offered_qps": spec.rate_hz,
                "slo_ms": spec.slo_ms,
                "slo_met": bool(lat) and p99 <= spec.slo_ms,
                "mean_queue_s": (
                    sum(r.queue_s for r in done) / len(done) if done else 0.0
                ),
                **slo_attainment(lat, spec.slo_ms),
                **self.scheduler_metrics(sched),
            }
        )
        if multi:
            ledger = sched.ledger.stats()
            metrics.update(
                {
                    "fairness": spec.fairness,
                    "completed": len(done),
                    "rejected": sum(
                        1 for r in reqs if r.status == "rejected"
                    ),
                    "jain_index": jain_index(
                        [v["tokens_admitted"] for v in ledger.values()]
                    ),
                    "tenant_stats": ledger,
                }
            )
            for tname in sorted(ledger):
                tl = [r.latency_s for r in done if r.tenant == tname]
                if tl:
                    metrics[f"{tname}_p99_ms"] = percentile(tl, 99.0) * 1e3
        if spec.prefix_len > 0:
            shared = sum(
                1
                for r in reqs
                if isinstance(r.payload, dict)
                and r.payload.get("prefix_group", -1) >= 0
            )
            metrics.update(
                {
                    "prefix_len": spec.prefix_len,
                    "shared_prefix_requests": shared,
                    "shared_prefix_fraction": shared / n,
                }
            )
        return metrics


class OfflineScenario(Scenario):
    """MLPerf offline: submit everything at once; the scheduler coalesces
    micro-batches of up to ``max_batch`` requests — max throughput."""

    kind = "offline"
    default_scheduler = SchedulerConfig(max_batch=8, batch_timeout_ms=0.0)

    def run(self, predict, tracer, clock, sleep, scheduler=None):
        spec = self.spec
        cfg = scheduler or self.default_scheduler
        self.warmup(predict, tracer, spec.batch_size * cfg.max_batch)
        sched = self.make_scheduler(predict, tracer, clock, sleep, cfg)
        with tracer.span("scenario:offline", TraceLevel.MODEL):
            t0 = clock()
            futs = [
                sched.submit(batch_size=spec.batch_size, arrival_s=t0)
                for _ in range(spec.num_requests)
            ]
            sched.run_until_idle()
            elapsed = clock() - t0
        reqs = [f.request for f in futs]
        inputs = sum(r.batch_size for r in reqs)
        lat = [r.latency_s for r in reqs]
        metrics = latency_summary(lat)
        metrics.update(
            {
                "scenario": "offline",
                "num_requests": len(reqs),
                "throughput_ips": inputs / elapsed if elapsed > 0 else float("inf"),
                "elapsed_s": elapsed,
                **self.scheduler_metrics(sched),
            }
        )
        return metrics


_SCENARIOS: Dict[str, Type[Scenario]] = {
    cls.kind: cls
    for cls in (
        OnlineScenario,
        BatchedScenario,
        TraceScenario,
        SingleStreamScenario,
        ServerScenario,
        OfflineScenario,
    )
}


def register_scenario(kind: str, cls: Type[Scenario]) -> None:
    """Pluggable scenarios, mirroring the workload-generator registry."""
    _SCENARIOS[kind] = cls


def scenario_kinds() -> List[str]:
    return sorted(_SCENARIOS)


def make_scenario(spec: ScenarioSpec) -> Scenario:
    try:
        return _SCENARIOS[spec.kind](spec)
    except KeyError:
        raise ValueError(f"unknown scenario kind {spec.kind!r}; have {sorted(_SCENARIOS)}")


def run_scenario(
    spec: ScenarioSpec,
    predict: PredictFn,
    tracer: Tracer,
    clock: Callable[[], float] = time.perf_counter,
    sleep: Callable[[float], None] = time.sleep,
    scheduler: Optional[Union[SchedulerConfig, Dict[str, Any]]] = None,
) -> Dict[str, Any]:
    """Execute a scenario and return its metrics dict.

    Compatibility shim: callers keep passing a bare predict function; the
    scenario wraps it in a :class:`RequestScheduler` (closed-loop kinds use a
    degenerate batch-1 scheduler so their metrics are bit-identical to the
    pre-scheduler implementation).  ``scheduler`` selects the
    scheduler-backed executor configuration (threaded through
    ``EvaluationRequest.scheduler`` by the agent/server dispatch).
    ``clock``/``sleep`` are injectable for deterministic tests."""
    if isinstance(scheduler, dict):
        scheduler = SchedulerConfig.from_dict(scheduler)
    return make_scenario(spec).run(predict, tracer, clock, sleep, scheduler=scheduler)
