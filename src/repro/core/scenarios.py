"""Benchmarking scenarios (paper F7, §4.1.3 / §5.1).

A scenario couples a workload generator with the measurement protocol:

* ``online``   — batch-1 requests with Poisson arrivals; metrics are the
                 trimmed-mean latency and 90th-percentile latency.
* ``batched``  — fixed-batch back-to-back requests; metric is throughput
                 (inputs/sec); sweeping batch sizes yields max throughput
                 and the optimal batch size (Table 2).
* ``trace``    — replay of a recorded arrival process.

Scenarios drive a *predict function* ``fn(batch_size) -> None`` supplied by
the agent; they own timing and metric computation so every model/backend is
measured identically (F2 consistent evaluation).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence

from .analysis import latency_summary
from .tracing import Tracer, TraceLevel
from .workload import BatchedLoad, PoissonLoad, Request, TraceReplayLoad, make_generator


@dataclass
class ScenarioSpec:
    """User-selected benchmarking scenario (part of the user input)."""

    kind: str = "online"            # online | batched | trace
    num_requests: int = 32
    batch_size: int = 1
    rate_hz: float = 50.0           # online arrival rate
    warmup: int = 3
    batch_sizes: Optional[List[int]] = None   # batched sweep
    arrivals: Optional[List[float]] = None    # trace replay
    seed: int = 0

    def to_dict(self) -> Dict[str, Any]:
        return {
            "kind": self.kind,
            "num_requests": self.num_requests,
            "batch_size": self.batch_size,
            "rate_hz": self.rate_hz,
            "warmup": self.warmup,
            "batch_sizes": self.batch_sizes,
            "arrivals": self.arrivals,
            "seed": self.seed,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ScenarioSpec":
        return cls(**{k: v for k, v in d.items() if k in cls.__dataclass_fields__})


PredictFn = Callable[[int], Any]


def run_scenario(
    spec: ScenarioSpec,
    predict: PredictFn,
    tracer: Tracer,
    clock: Callable[[], float] = time.perf_counter,
    sleep: Callable[[float], None] = time.sleep,
) -> Dict[str, Any]:
    """Execute a scenario and return its metrics dict.

    ``clock``/``sleep`` are injectable for deterministic tests (the paper
    allows simulated time in traces)."""
    if spec.kind == "online":
        return _run_online(spec, predict, tracer, clock, sleep)
    if spec.kind == "batched":
        return _run_batched(spec, predict, tracer, clock)
    if spec.kind == "trace":
        return _run_trace(spec, predict, tracer, clock, sleep)
    raise ValueError(f"unknown scenario kind {spec.kind!r}")


def _measure(
    requests: Sequence[Request],
    predict: PredictFn,
    tracer: Tracer,
    clock: Callable[[], float],
    sleep: Callable[[float], None],
    honor_arrivals: bool,
) -> List[Dict[str, float]]:
    """Issue requests, recording per-request service + queueing latency."""
    results = []
    t0 = clock()
    for req in requests:
        if honor_arrivals:
            now = clock() - t0
            if req.arrival_s > now:
                sleep(req.arrival_s - now)
        start = clock()
        with tracer.span(
            "predict", TraceLevel.MODEL, request_id=req.request_id, batch=req.batch_size
        ):
            predict(req.batch_size)
        end = clock()
        results.append(
            {
                "request_id": req.request_id,
                "batch_size": req.batch_size,
                "arrival_s": req.arrival_s,
                "start_s": start - t0,
                "latency_s": end - start,
                # queueing delay: time between intended arrival and service start
                "queue_s": max(0.0, (start - t0) - req.arrival_s),
            }
        )
    return results


def _warmup(spec: ScenarioSpec, predict: PredictFn, tracer: Tracer, batch: int) -> None:
    for _ in range(spec.warmup):
        with tracer.span("warmup", TraceLevel.MODEL, batch=batch):
            predict(batch)


def _run_online(spec, predict, tracer, clock, sleep) -> Dict[str, Any]:
    _warmup(spec, predict, tracer, 1)
    load = PoissonLoad(spec.num_requests, spec.rate_hz, seed=spec.seed)
    with tracer.span("scenario:online", TraceLevel.MODEL, rate_hz=spec.rate_hz):
        rows = _measure(list(load.requests()), predict, tracer, clock, sleep, True)
    lat = [r["latency_s"] for r in rows]
    metrics = latency_summary(lat)
    metrics.update(
        {
            "scenario": "online",
            "num_requests": len(rows),
            "mean_queue_s": sum(r["queue_s"] for r in rows) / max(len(rows), 1),
        }
    )
    return metrics


def _run_batched(spec, predict, tracer, clock) -> Dict[str, Any]:
    """Throughput at each batch size; max throughput + optimal batch size."""
    batch_sizes = spec.batch_sizes or [spec.batch_size]
    per_batch: Dict[int, Dict[str, float]] = {}
    for bs in batch_sizes:
        _warmup(spec, predict, tracer, bs)
        load = BatchedLoad(spec.num_requests, bs)
        with tracer.span("scenario:batched", TraceLevel.MODEL, batch=bs):
            t0 = clock()
            rows = _measure(list(load.requests()), predict, tracer, clock, time.sleep, False)
            elapsed = clock() - t0
        inputs = sum(r["batch_size"] for r in rows)
        lat = [r["latency_s"] for r in rows]
        per_batch[bs] = {
            "throughput_ips": inputs / elapsed if elapsed > 0 else float("inf"),
            **latency_summary(lat),
        }
    best_bs = max(per_batch, key=lambda b: per_batch[b]["throughput_ips"])
    return {
        "scenario": "batched",
        "per_batch": {str(k): v for k, v in per_batch.items()},
        "max_throughput_ips": per_batch[best_bs]["throughput_ips"],
        "optimal_batch_size": best_bs,
    }


def _run_trace(spec, predict, tracer, clock, sleep) -> Dict[str, Any]:
    if not spec.arrivals:
        raise ValueError("trace scenario requires arrivals")
    _warmup(spec, predict, tracer, spec.batch_size)
    load = TraceReplayLoad(spec.arrivals, [spec.batch_size] * len(spec.arrivals))
    with tracer.span("scenario:trace", TraceLevel.MODEL):
        rows = _measure(list(load.requests()), predict, tracer, clock, sleep, True)
    lat = [r["latency_s"] for r in rows]
    metrics = latency_summary(lat)
    metrics.update({"scenario": "trace", "num_requests": len(rows)})
    return metrics
