"""Command-line client (paper F10, §4.2).

The CLI mirrors the paper's command-line interface: users specify the model,
backend ("framework"), benchmarking scenario, and trace level; results go to
the evaluation database and a human-readable report is printed. Usable in
shell scripts for combinational evaluations.

Examples::

    python -m repro.core.client evaluate --model glm4-9b --scenario online \
        --num-requests 16 --rate-hz 20 --trace-level MODEL
    python -m repro.core.client evaluate --model resnet50 --scenario batched \
        --batch-sizes 1,2,4,8
    python -m repro.core.client list-models
    python -m repro.core.client report --model glm4-9b
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from .agent import EvaluationRequest
from .platform import LocalPlatform
from .scenarios import ScenarioSpec


def _parse_int_list(text: str) -> List[int]:
    return [int(x) for x in text.split(",") if x]


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(prog="mlms", description="MLModelScope-JAX client")
    p.add_argument("--evaldb", default=":memory:", help="evaluation database path")
    p.add_argument(
        "--backends", default="ref", help="comma-separated agent backends to start"
    )
    sub = p.add_subparsers(dest="command", required=True)

    ev = sub.add_parser("evaluate", help="run a model evaluation")
    ev.add_argument("--model", required=True)
    ev.add_argument("--model-version", default="")
    ev.add_argument("--backend", default="ref")
    ev.add_argument(
        "--scenario",
        default="online",
        choices=["online", "batched", "trace", "single_stream", "server", "offline"],
    )
    ev.add_argument("--num-requests", type=int, default=8)
    ev.add_argument("--rate-hz", type=float, default=50.0)
    ev.add_argument("--batch-size", type=int, default=1)
    ev.add_argument("--batch-sizes", type=_parse_int_list, default=None)
    ev.add_argument("--seq-len", type=int, default=64)
    ev.add_argument("--warmup", type=int, default=2)
    ev.add_argument("--slo-ms", type=float, default=100.0, help="server scenario SLO")
    ev.add_argument(
        "--sched-max-batch", type=int, default=0,
        help="run through the scheduler-backed executor coalescing up to N requests",
    )
    ev.add_argument("--sched-timeout-ms", type=float, default=2.0)
    ev.add_argument("--sched-queue-depth", type=int, default=1024)
    ev.add_argument(
        "--trace-level", default="MODEL", choices=["NONE", "MODEL", "FRAMEWORK", "SYSTEM", "FULL"]
    )
    ev.add_argument("--all-agents", action="store_true", help="fan out to all capable agents")
    ev.add_argument("--json", action="store_true", help="print raw JSON metrics")

    sub.add_parser("list-models", help="list registered model manifests")
    sub.add_parser("list-agents", help="list running agents")

    rp = sub.add_parser("report", help="analysis report over past evaluations")
    rp.add_argument("--model", default="")
    rp.add_argument("--backend", default="")
    rp.add_argument("--scenario", default="")
    return p


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    platform = LocalPlatform(
        backends=args.backends.split(","), evaldb_path=args.evaldb
    )
    try:
        if args.command == "list-models":
            for m in platform.registry.manifests():
                print(f"{m.key:40s} {m.description}")
            return 0
        if args.command == "list-agents":
            for a in platform.registry.agents():
                print(f"{a.agent_id:24s} backend={a.backend:8s} models={len(a.models)}")
            return 0
        if args.command == "report":
            print(
                platform.report(
                    model=args.model, backend=args.backend, scenario=args.scenario
                )
            )
            return 0
        # evaluate
        spec = ScenarioSpec(
            kind=args.scenario,
            num_requests=args.num_requests,
            batch_size=args.batch_size,
            rate_hz=args.rate_hz,
            warmup=args.warmup,
            batch_sizes=args.batch_sizes,
            slo_ms=args.slo_ms,
        )
        scheduler = None
        if args.sched_max_batch > 0:
            from ..serve.scheduler import SchedulerConfig

            scheduler = SchedulerConfig(
                max_batch=args.sched_max_batch,
                batch_timeout_ms=args.sched_timeout_ms,
                queue_depth=args.sched_queue_depth,
            )
        req = EvaluationRequest(
            model=args.model,
            model_version=args.model_version,
            backend=args.backend,
            scenario=spec,
            trace_level=args.trace_level,
            batch_size=args.batch_size,
            seq_len=args.seq_len,
            scheduler=scheduler,
        )
        from .server import DispatchPolicy

        results = platform.evaluate(
            req, policy=DispatchPolicy(all_agents=args.all_agents)
        )
        for res in results:
            if args.json:
                print(json.dumps(res, indent=2, default=str))
            else:
                print(f"agent={res['agent_id']} model={res['model']}")
                for k, v in sorted(res["metrics"].items()):
                    if isinstance(v, float):
                        print(f"  {k:24s} {v:.4f}")
                    elif not isinstance(v, dict):
                        print(f"  {k:24s} {v}")
        print()
        print(platform.report(model=args.model))
        return 0
    finally:
        platform.shutdown()


if __name__ == "__main__":
    sys.exit(main())
