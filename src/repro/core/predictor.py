"""Predictor interface (paper F2/F3, §4.4.3, Listing 3).

The paper wraps each framework's C API behind three functions::

    ModelHandle   ModelLoad(OpenRequest)
    Error         ModelUnload(ModelHandle)
    PredictResponse Predict(ModelHandle, PredictRequest, PredictOptions)

Anything implementing the 3-function interface is a valid predictor — the
paper exposes FPGAs this way. Here the "frameworks" are JAX compute
backends (``ref`` pure-jnp vs ``pallas`` TPU kernels, and compiled AOT
executables per mesh); a predictor owns materialized weights + the compiled
step functions and hides everything else from the agent, keeping the agent
code backend-agnostic.
"""
from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

from .manifest import ModelManifest
from .tracing import NullTracer, Tracer, TraceLevel

_handles = itertools.count(1)


@dataclass
class OpenRequest:
    """Listing 4's OpenRequest: everything needed to load one predictor."""

    manifest: ModelManifest
    backend: str = "ref"
    batch_size: int = 1
    seq_len: int = 128
    mode: str = "serve"          # "serve" | "train"
    options: Dict[str, Any] = field(default_factory=dict)


@dataclass
class PredictorHandle:
    handle_id: int
    backend: str
    model_key: str
    state: Any = None            # backend-private (weights, compiled fns, caches)


class Predictor:
    """Abstract 3-function predictor. Subclass and register a factory."""

    name = "abstract"
    version = "1.0.0"

    def open(self, req: OpenRequest, tracer: Tracer) -> PredictorHandle:
        raise NotImplementedError

    def predict(
        self, handle: PredictorHandle, batch: Any, tracer: Tracer
    ) -> Any:
        raise NotImplementedError

    def close(self, handle: PredictorHandle) -> None:
        raise NotImplementedError


class CallablePredictor(Predictor):
    """Wrap plain callables as a predictor (the FPGA/ASIC story of §4.4.3:
    implementing the 3 functions is sufficient — no framework needed)."""

    def __init__(
        self,
        name: str,
        load_fn: Callable[[OpenRequest], Any],
        predict_fn: Callable[[Any, Any], Any],
        unload_fn: Optional[Callable[[Any], None]] = None,
        version: str = "1.0.0",
    ) -> None:
        self.name = name
        self.version = version
        self._load = load_fn
        self._predict = predict_fn
        self._unload = unload_fn

    def open(self, req: OpenRequest, tracer: Tracer) -> PredictorHandle:
        with tracer.span("model_load", TraceLevel.MODEL, backend=self.name):
            state = self._load(req)
        return PredictorHandle(
            handle_id=next(_handles),
            backend=self.name,
            model_key=req.manifest.key,
            state=state,
        )

    def predict(self, handle: PredictorHandle, batch: Any, tracer: Tracer) -> Any:
        with tracer.span("inference", TraceLevel.MODEL, backend=self.name):
            return self._predict(handle.state, batch)

    def close(self, handle: PredictorHandle) -> None:
        if self._unload is not None:
            self._unload(handle.state)
        handle.state = None


# --------------------------------------------------------------------------
# Predictor registry (the "adding frameworks" extension point, §4.6)
# --------------------------------------------------------------------------
_FACTORIES: Dict[str, Callable[[], Predictor]] = {}
_lock = threading.Lock()


def register_predictor(name: str, factory: Callable[[], Predictor]) -> None:
    with _lock:
        _FACTORIES[name] = factory


def make_predictor(name: str) -> Predictor:
    with _lock:
        try:
            factory = _FACTORIES[name]
        except KeyError:
            raise KeyError(
                f"no predictor backend {name!r}; registered: {sorted(_FACTORIES)}"
            )
    return factory()


def available_backends() -> list:
    with _lock:
        return sorted(_FACTORIES)


def _register_builtin() -> None:
    """Register the JAX model-zoo predictors lazily (import cycle guard)."""
    try:
        from ..models.predictor import JaxModelPredictor  # noqa: WPS433
    except Exception:  # pragma: no cover - models package optional at import
        return
    for backend in ("ref", "pallas"):
        if backend not in _FACTORIES:
            register_predictor(
                backend, lambda b=backend: JaxModelPredictor(kernel_backend=b)
            )


_register_builtin()
