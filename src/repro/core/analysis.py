"""Benchmarking analysis & reporting (paper F8, §4.3/§5.3).

Automated analysis over raw benchmarking output: the paper's metrics
(trimmed-mean latency, 90th-percentile latency, max throughput, throughput
scalability across batch sizes) plus the across-stack trace summaries
(top-K most time-consuming layers, per-level breakdowns — Table 3 / Fig 8),
and human-readable report generation.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from .tracing import Span, TraceLevel


# --------------------------------------------------------------------------
# Paper metrics
# --------------------------------------------------------------------------
def trimmed_mean(values: Sequence[float], trim: float = 0.2) -> float:
    """The paper's trimmed mean: drop the smallest/largest ``trim`` fraction.

    TrimmedMean(list) = Mean(Sort(list)[floor(trim*len) : -floor(trim*len)])
    """
    if not values:
        raise ValueError("trimmed_mean of empty sequence")
    if not 0.0 <= trim < 0.5:
        raise ValueError("trim must be in [0, 0.5)")
    s = sorted(values)
    k = math.floor(trim * len(s))
    core = s[k : len(s) - k] if k else s
    return sum(core) / len(core)


def percentile(values: Sequence[float], pct: float) -> float:
    """Nearest-rank percentile (pct in [0, 100])."""
    if not values:
        raise ValueError("percentile of empty sequence")
    if not 0.0 <= pct <= 100.0:
        raise ValueError("pct must be in [0, 100]")
    s = sorted(values)
    rank = max(1, math.ceil(pct / 100.0 * len(s)))
    return s[rank - 1]


def latency_summary(latencies_s: Sequence[float]) -> Dict[str, float]:
    """Standard latency metrics block used by every scenario."""
    if not latencies_s:
        return {"trimmed_mean_ms": float("nan"), "p90_ms": float("nan")}
    return {
        "trimmed_mean_ms": trimmed_mean(latencies_s) * 1e3,
        "p90_ms": percentile(latencies_s, 90.0) * 1e3,
        "min_ms": min(latencies_s) * 1e3,
        "max_ms": max(latencies_s) * 1e3,
    }


def slo_attainment(latencies_s: Sequence[float], slo_ms: float) -> Dict[str, float]:
    """Server-scenario SLO accounting: violation count + attained fraction."""
    if not latencies_s:
        return {"slo_violations": 0.0, "slo_attainment": 1.0}
    violations = sum(1 for l in latencies_s if l * 1e3 > slo_ms)
    return {
        "slo_violations": float(violations),
        "slo_attainment": 1.0 - violations / len(latencies_s),
    }


def scheduler_summary(spans: Iterable[Span]) -> Dict[str, float]:
    """Summarize the scheduler's queue-depth / batch-occupancy trace series.

    The request scheduler publishes one ``scheduler:batch`` event per
    executed micro-batch, tagged with ``queue_depth`` (arrived-but-unserved
    requests at batch formation), ``occupancy`` (coalesced requests) and
    ``inputs`` (total model batch).  This aggregates them into the queueing
    block of the analysis workflow."""
    depths: List[float] = []
    occs: List[float] = []
    inputs = 0.0
    for s in spans:
        if s.name != "scheduler:batch":
            continue
        depths.append(float(s.tags.get("queue_depth", 0)))
        occs.append(float(s.tags.get("occupancy", 0)))
        inputs += float(s.tags.get("inputs", 0))
    if not occs:
        return {}
    return {
        "batches": float(len(occs)),
        "total_inputs": inputs,
        "mean_batch_occupancy": sum(occs) / len(occs),
        "max_batch_occupancy": max(occs),
        "mean_queue_depth": sum(depths) / len(depths),
        "max_queue_depth": max(depths),
    }


def page_occupancy_summary(spans: Iterable[Span]) -> Dict[str, float]:
    """Summarize the paged engine's KV-page occupancy trace series.

    The paged slot pool publishes one ``pages:occupancy`` event per decode-
    step boundary, tagged with ``pages_in_use`` / ``pages_free`` /
    ``num_pages`` / ``active_slots``.  This aggregates them into the memory
    block of the analysis workflow: utilization tells whether the HBM page
    budget (not compute) caps concurrency."""
    used: List[float] = []
    active: List[float] = []
    total = 0.0
    for s in spans:
        if s.name != "pages:occupancy":
            continue
        used.append(float(s.tags.get("pages_in_use", 0)))
        active.append(float(s.tags.get("active_slots", 0)))
        total = max(total, float(s.tags.get("num_pages", 0)))
    if not used:
        return {}
    cap = max(total, 1.0)
    return {
        "samples": float(len(used)),
        "num_pages": total,
        "mean_pages_in_use": sum(used) / len(used),
        "peak_pages_in_use": max(used),
        "mean_page_utilization": sum(used) / len(used) / cap,
        "peak_page_utilization": max(used) / cap,
        "mean_active_slots": sum(active) / len(active),
        "peak_active_slots": max(active),
    }


def page_occupancy_section(spans: Iterable[Span]) -> str:
    """Render the page-occupancy block as a report section (markdown-safe
    text table); empty string when no paged run was traced."""
    summary = page_occupancy_summary(spans)
    if not summary:
        return ""
    rows = [{"metric": k, "value": v} for k, v in summary.items()]
    return comparison_table(rows, ("metric", "value"))


def prefill_saturation_summary(spans: Iterable[Span]) -> Dict[str, float]:
    """Summarize the packed-prefill pipeline's saturation trace series.

    The paged engine publishes one ``prefill:packed`` event per packed
    varlen launch, tagged with ``tokens`` (real prompt tokens), ``padding``
    (buffer slots spent on chunk/tail pad), ``chunks`` (coalesced spans),
    ``buffer`` (packed-buffer size) and ``budget`` (the per-boundary token
    knob).  This aggregates them into the prefill block of the analysis
    workflow: buffer utilization tells whether prompt traffic saturates the
    packed launches, chunks/launch how much cross-request coalescing the
    mix allows."""
    tokens: List[float] = []
    chunks: List[float] = []
    pad = 0.0
    buffer = 0.0
    total_s = 0.0
    for s in spans:
        if s.name != "prefill:packed":
            continue
        tokens.append(float(s.tags.get("tokens", 0)))
        chunks.append(float(s.tags.get("chunks", 0)))
        pad += float(s.tags.get("padding", 0))
        buffer = max(buffer, float(s.tags.get("buffer", 0)))
        total_s += s.duration
    if not tokens:
        return {}
    cap = max(buffer, 1.0) * len(tokens)
    total = sum(tokens)
    return {
        "launches": float(len(tokens)),
        "buffer_tokens": buffer,
        "prefill_tokens": total,
        "padded_tokens": pad,
        "mean_chunks_per_launch": sum(chunks) / len(chunks),
        "mean_buffer_utilization": total / cap,
        "peak_buffer_utilization": max(tokens) / max(buffer, 1.0),
        "pad_fraction": pad / max(total + pad, 1.0),
        "prefill_tokens_per_s": total / total_s if total_s > 0 else 0.0,
    }


def prefill_saturation_section(spans: Iterable[Span]) -> str:
    """Render the prefill-saturation block as a report section; empty string
    when no packed-prefill run was traced."""
    summary = prefill_saturation_summary(spans)
    if not summary:
        return ""
    rows = [{"metric": k, "value": v} for k, v in summary.items()]
    return comparison_table(rows, ("metric", "value"))


def spec_decode_summary(spans: Iterable[Span]) -> Dict[str, float]:
    """Summarize the speculative-decoding verify trace series.

    The paged engine publishes one ``spec:verify`` event per multi-token
    verification launch, tagged with ``window`` (the k+1 launch width),
    ``slots`` (decoding slots scored), ``proposed`` / ``accepted`` (draft
    tokens in/out of the greedy exact-match acceptance test) and ``emitted``
    (tokens committed by the launch: one per slot plus every accepted
    draft).  This aggregates them into the decode block of the analysis
    workflow: the acceptance rate is whether prompt-lookup drafting pays,
    and ``mean_tokens_per_launch`` vs 1.0 is the decode-step amplification
    the verification kernel bought."""
    proposed = 0.0
    accepted = 0.0
    emitted = 0.0
    slots = 0.0
    launches = 0
    window = 0.0
    total_s = 0.0
    for s in spans:
        if s.name != "spec:verify":
            continue
        launches += 1
        proposed += float(s.tags.get("proposed", 0))
        accepted += float(s.tags.get("accepted", 0))
        emitted += float(s.tags.get("emitted", 0))
        slots += float(s.tags.get("slots", 0))
        window = max(window, float(s.tags.get("window", 0)))
        total_s += s.duration
    if not launches:
        return {}
    return {
        "spec_launches": float(launches),
        "window": window,
        "draft_proposed": proposed,
        "draft_accepted": accepted,
        "acceptance_rate": accepted / proposed if proposed else 0.0,
        "emitted_tokens": emitted,
        "mean_tokens_per_launch": emitted / max(slots, 1.0),
        "emitted_tokens_per_s": emitted / total_s if total_s > 0 else 0.0,
    }


def spec_decode_section(spans: Iterable[Span]) -> str:
    """Render the speculative-decoding block as a report section; empty
    string when no speculative run was traced."""
    summary = spec_decode_summary(spans)
    if not summary:
        return ""
    rows = [{"metric": k, "value": v} for k, v in summary.items()]
    return comparison_table(rows, ("metric", "value"))


def tp_summary(spans: Iterable[Span]) -> Dict[str, float]:
    """Summarize tensor-parallel communication from ``tp:collective`` events.

    The paged engine publishes one event per sharded launch, tagged with
    ``phase`` (prefill / decode / verify), ``kind`` (psum vs
    reduce_scatter), ``tp``, ``count`` (collectives in the launch: two per
    transformer layer — the attention-output -> o-proj boundary and the MLP
    down-proj), ``payload_bytes`` (summed block-output bytes) and
    ``moved_bytes`` (ring-algorithm wire traffic per shard).  This
    aggregates them per boundary kind and phase so bottleneck attribution
    can rank communication against the compute stack levels."""
    tp = 0.0
    launches = 0
    count: Dict[str, float] = {}
    payload: Dict[str, float] = {}
    moved: Dict[str, float] = {}
    phase_moved: Dict[str, float] = {}
    for s in spans:
        if s.name != "tp:collective":
            continue
        launches += 1
        tp = max(tp, float(s.tags.get("tp", 0)))
        kind = str(s.tags.get("kind", "psum"))
        count[kind] = count.get(kind, 0.0) + float(s.tags.get("count", 0))
        payload[kind] = payload.get(kind, 0.0) + float(
            s.tags.get("payload_bytes", 0)
        )
        moved[kind] = moved.get(kind, 0.0) + float(s.tags.get("moved_bytes", 0))
        phase = str(s.tags.get("phase", ""))
        phase_moved[phase] = phase_moved.get(phase, 0.0) + float(
            s.tags.get("moved_bytes", 0)
        )
    if not launches:
        return {}
    out: Dict[str, float] = {"tp": tp, "sharded_launches": float(launches)}
    for kind in sorted(count):
        out[f"{kind}_count"] = count[kind]
        out[f"{kind}_payload_bytes"] = payload[kind]
        out[f"{kind}_moved_bytes"] = moved[kind]
    for phase in sorted(phase_moved):
        out[f"{phase}_moved_bytes"] = phase_moved[phase]
    out["total_moved_bytes"] = sum(moved.values())
    return out


def tp_section(spans: Iterable[Span]) -> str:
    """Render the tensor-parallel communication block as a report section;
    empty string when no sharded run was traced."""
    summary = tp_summary(spans)
    if not summary:
        return ""
    rows = [{"metric": k, "value": v} for k, v in summary.items()]
    return comparison_table(rows, ("metric", "value"))


def prefix_cache_summary(spans: Iterable[Span]) -> Dict[str, float]:
    """Summarize the automatic prefix cache's trace series.

    The paged engine publishes one ``prefix:lookup`` event per admitted
    request (tagged ``prompt_tokens`` / ``cached_tokens`` / ``hit_pages`` /
    ``full_hit``), a ``prefix:cow`` event per copy-on-write page split, and
    a ``prefix:evict`` event per reclamation of cached-unreferenced pages
    (tagged ``pages``).  This aggregates them into the serving block of the
    analysis workflow: the hit rate and saved-token fraction say how much
    prefill the workload's shared prefixes amortize, COW copies how often
    shared last pages had to split, and evictions whether the page budget
    is recycling the cache under pressure."""
    prompt = 0.0
    cached = 0.0
    hit_pages = 0.0
    lookups = 0
    hits = 0
    full_hits = 0
    cow = 0
    evicted = 0.0
    for s in spans:
        if s.name == "prefix:lookup":
            lookups += 1
            p = float(s.tags.get("prompt_tokens", 0))
            c = float(s.tags.get("cached_tokens", 0))
            prompt += p
            cached += c
            hit_pages += float(s.tags.get("hit_pages", 0))
            if c > 0:
                hits += 1
            if s.tags.get("full_hit"):
                full_hits += 1
        elif s.name == "prefix:cow":
            cow += 1
        elif s.name == "prefix:evict":
            evicted += float(s.tags.get("pages", 0))
    if not lookups and not cow and not evicted:
        return {}
    return {
        "lookups": float(lookups),
        "hits": float(hits),
        "full_hits": float(full_hits),
        "hit_rate": hits / lookups if lookups else 0.0,
        "hit_pages": hit_pages,
        "prompt_tokens": prompt,
        "saved_prefill_tokens": cached,
        "saved_fraction": cached / prompt if prompt else 0.0,
        "cow_copies": float(cow),
        "evicted_pages": evicted,
    }


def prefix_cache_section(spans: Iterable[Span]) -> str:
    """Render the prefix-cache block as a report section; empty string when
    no prefix-cached run was traced."""
    summary = prefix_cache_summary(spans)
    if not summary:
        return ""
    rows = [{"metric": k, "value": v} for k, v in summary.items()]
    return comparison_table(rows, ("metric", "value"))


def fleet_summary(spans: Iterable[Span]) -> Dict[str, float]:
    """Summarize a fault-tolerant fleet run from ``fleet:*``/``fault:*`` events.

    The fleet router publishes one ``fleet:commit`` per terminal completion
    (tagged ``duplicate`` / ``within_deadline`` / ``latency_s``), a
    ``fleet:death`` per worker crash or lease expiry (tagged ``requeued``),
    a ``fleet:recovered`` per drained death whose span duration IS the
    recovery time (death observed -> every orphaned request terminal), plus
    ``fleet:requeue`` / ``fleet:failed`` / ``fleet:shed`` / ``fleet:hedge``
    / ``fleet:degrade`` transitions, and the fault injector publishes one
    ``fault:*`` event per fired fault.  This aggregates them into the
    robustness block of the analysis workflow: goodput (completed within
    deadline over all admitted-and-terminal requests) says how much service
    the fleet retained through the faults, and recovery time how quickly
    orphaned work was replayed onto survivors."""
    commits = 0
    dups = 0
    within = 0
    failed = 0
    shed = 0
    requeued = 0
    deaths = 0
    hedged = 0
    degrades = 0
    max_level = 0
    rounds = 0
    peak_pressure = 0.0
    recovery: List[float] = []
    latencies: List[float] = []
    faults: Dict[str, int] = {}
    for s in spans:
        if s.name == "fleet:commit":
            if s.tags.get("duplicate"):
                dups += 1
            else:
                commits += 1
                within += int(bool(s.tags.get("within_deadline", 1)))
                latencies.append(float(s.tags.get("latency_s", 0.0)))
        elif s.name == "fleet:failed":
            failed += 1
        elif s.name == "fleet:shed":
            shed += 1
        elif s.name == "fleet:requeue":
            requeued += 1
        elif s.name == "fleet:death":
            deaths += 1
        elif s.name == "fleet:hedge":
            hedged += 1
        elif s.name == "fleet:recovered":
            recovery.append(s.duration)
        elif s.name == "fleet:degrade":
            degrades += 1
            max_level = max(max_level, int(s.tags.get("to", 0)))
        elif s.name == "fleet:round":
            rounds += 1
            peak_pressure = max(
                peak_pressure, float(s.tags.get("pressure", 0.0))
            )
        elif s.name.startswith("fault:") and s.name != "fault:pressure_release":
            kind = s.name.split(":", 1)[1]
            faults[kind] = faults.get(kind, 0) + 1
    terminal = commits + failed
    if not terminal and not deaths and not shed and not faults:
        return {}
    out = {
        "rounds": float(rounds),
        "completed": float(commits),
        "failed": float(failed),
        "shed": float(shed),
        "goodput": within / terminal if terminal else 0.0,
        "requeued": float(requeued),
        "deaths": float(deaths),
        "hedged": float(hedged),
        "duplicate_commits": float(dups),
        "degrade_transitions": float(degrades),
        "max_degrade_level": float(max_level),
        "peak_pressure": peak_pressure,
    }
    if latencies:
        out["latency_p90_ms"] = percentile(latencies, 90.0) * 1e3
    if recovery:
        out["recoveries"] = float(len(recovery))
        out["recovery_mean_s"] = sum(recovery) / len(recovery)
        out["recovery_max_s"] = max(recovery)
    for kind in sorted(faults):
        out[f"faults_{kind}"] = float(faults[kind])
    return out


def fleet_section(spans: Iterable[Span]) -> str:
    """Render the fleet-robustness block as a report section; empty string
    when no fleet run was traced."""
    summary = fleet_summary(spans)
    if not summary:
        return ""
    rows = [{"metric": k, "value": v} for k, v in summary.items()]
    return comparison_table(rows, ("metric", "value"))


def recovery_summary(spans: Iterable[Span]) -> Dict[str, float]:
    """Summarize KV-migration recovery from ``ckpt:*``/``migrate:*`` events.

    The engine publishes one ``ckpt:save`` per slot snapshot (tagged
    ``bytes``/``pages``/``tokens``), one ``migrate:restore`` per orphan
    rebuilt from a snapshot on a survivor (the O(bytes) failover path) and
    one ``migrate:checksum_fail`` per snapshot whose per-page checksums
    failed verification (downgraded to replay — corrupted state is never
    served).  The router tags each ``fleet:death``/``fleet:drain`` with how
    many orphans are migrating vs how many prompt tokens the replay path
    must recompute.  Together: migrated vs recomputed tokens, bytes moved,
    and recovery time — the ledger deciding whether failover cost scales
    with bytes moved or tokens recomputed."""
    ckpts = 0
    ckpt_bytes = 0
    migrated = 0
    migrated_tokens = 0
    bytes_moved = 0
    checksum_failures = 0
    recomputed_tokens = 0
    drains = 0
    joins = 0
    recovery: List[float] = []
    restore_s: List[float] = []
    saw = False
    for s in spans:
        if s.name == "ckpt:save":
            saw = True
            ckpts += 1
            ckpt_bytes += int(s.tags.get("bytes", 0))
        elif s.name == "migrate:restore":
            saw = True
            migrated += 1
            migrated_tokens += int(s.tags.get("length", 0))
            bytes_moved += int(s.tags.get("bytes", 0))
            restore_s.append(s.duration)
        elif s.name == "migrate:checksum_fail":
            saw = True
            checksum_failures += 1
        elif s.name in ("fleet:death", "fleet:drain"):
            saw = saw or s.name == "fleet:drain"
            recomputed_tokens += int(s.tags.get("recompute_tokens", 0))
            if s.name == "fleet:drain":
                drains += 1
        elif s.name == "fleet:join":
            saw = True
            joins += 1
        elif s.name == "fleet:recovered":
            recovery.append(s.duration)
    if not saw:
        return {}
    out = {
        "checkpoints_saved": float(ckpts),
        "checkpoint_bytes": float(ckpt_bytes),
        "migrated": float(migrated),
        "migrated_tokens": float(migrated_tokens),
        "recomputed_prefill_tokens": float(recomputed_tokens),
        "bytes_moved": float(bytes_moved),
        "checksum_failures": float(checksum_failures),
        "drains": float(drains),
        "joins": float(joins),
    }
    total = migrated_tokens + recomputed_tokens
    if total:
        out["migrated_token_fraction"] = migrated_tokens / total
    if restore_s:
        out["restore_mean_s"] = sum(restore_s) / len(restore_s)
    if recovery:
        out["recovery_mean_s"] = sum(recovery) / len(recovery)
        out["recovery_max_s"] = max(recovery)
    return out


def recovery_section(spans: Iterable[Span]) -> str:
    """Render the KV-migration recovery block as a report section; empty
    string when no checkpoint/migration activity was traced."""
    summary = recovery_summary(spans)
    if not summary:
        return ""
    rows = [{"metric": k, "value": v} for k, v in summary.items()]
    return comparison_table(rows, ("metric", "value"))


def jain_index(shares: Sequence[float]) -> float:
    """Jain's fairness index over per-tenant shares: (Σx)² / (n·Σx²).

    1.0 when every tenant got an equal share, 1/n when one tenant got
    everything.  Defined as 1.0 for empty or all-zero inputs (no
    contention — nothing was unfair)."""
    xs = [float(x) for x in shares if x > 0]
    if not xs:
        return 1.0
    total = sum(xs)
    sq = sum(x * x for x in xs)
    return total * total / (len(xs) * sq) if sq > 0 else 1.0


def slo_summary(spans: Iterable[Span]) -> Dict[str, float]:
    """Summarize a multi-tenant SLO run from ``sched:tenant`` events.

    The scheduler and the paged engine publish one ``sched:tenant`` event
    per terminal request, tagged ``tenant`` / ``priority`` / ``status``
    (completed | failed | rejected) / ``latency_s`` / ``slo_ms`` /
    ``slo_ok`` (completed within its SLO) / ``tokens`` (admission cost).
    This aggregates them into the SLO block of the analysis workflow:
    goodput-under-SLO (the headline — completed-within-SLO over all
    terminal requests, so shed and late work both count against it),
    per-tenant p99 latency (``<tenant>_p99_ms``), shed/defer counters and
    Jain's fairness index over per-tenant *served* tokens — the number
    token buckets + weighted fair dequeue are supposed to hold near 1.0
    when tenants offer equal load."""
    terminal = 0
    completed = 0
    rejected = 0
    failed = 0
    slo_ok = 0
    deferred = 0
    latencies: Dict[str, List[float]] = {}
    served_tokens: Dict[str, float] = {}
    shed_by: Dict[str, float] = {}
    for s in spans:
        if s.name == "sched:defer":
            deferred += 1
            continue
        if s.name != "sched:tenant":
            continue
        tenant = str(s.tags.get("tenant", "default"))
        status = str(s.tags.get("status", "completed"))
        terminal += 1
        if status == "completed":
            completed += 1
            latencies.setdefault(tenant, []).append(
                float(s.tags.get("latency_s", 0.0))
            )
            served_tokens[tenant] = served_tokens.get(tenant, 0.0) + float(
                s.tags.get("tokens", 0.0)
            )
            if s.tags.get("slo_ok", True):
                slo_ok += 1
        elif status == "rejected":
            rejected += 1
            shed_by[tenant] = shed_by.get(tenant, 0.0) + 1.0
        else:
            failed += 1
    if not terminal:
        return {}
    out: Dict[str, float] = {
        "requests": float(terminal),
        "completed": float(completed),
        "rejected": float(rejected),
        "failed": float(failed),
        "deferred": float(deferred),
        "goodput_slo": slo_ok / terminal,
        "slo_attainment": slo_ok / completed if completed else 0.0,
        "jain_index": jain_index(list(served_tokens.values())),
        "tenants": float(len(set(latencies) | set(shed_by))),
    }
    for tenant in sorted(latencies):
        ls = latencies[tenant]
        out[f"{tenant}_p99_ms"] = percentile(ls, 99.0) * 1e3
        out[f"{tenant}_completed"] = float(len(ls))
        out[f"{tenant}_served_tokens"] = served_tokens.get(tenant, 0.0)
    for tenant in sorted(shed_by):
        out[f"{tenant}_shed"] = shed_by[tenant]
    return out


def slo_section(spans: Iterable[Span]) -> str:
    """Render the multi-tenant SLO block as a report section; empty string
    when no tenant-tagged run was traced."""
    summary = slo_summary(spans)
    if not summary:
        return ""
    rows = [{"metric": k, "value": v} for k, v in summary.items()]
    return comparison_table(rows, ("metric", "value"))


def itl_summary(itls_s: Sequence[float]) -> Dict[str, float]:
    """Inter-token latency block: the serving-quality metric the paged
    decode loop optimizes (speculative boundaries emit several tokens at
    one instant, so accepted drafts surface as near-zero gaps)."""
    if not itls_s:
        return {}
    return {
        "samples": float(len(itls_s)),
        "itl_mean_ms": sum(itls_s) / len(itls_s) * 1e3,
        "itl_p50_ms": percentile(itls_s, 50.0) * 1e3,
        "itl_p99_ms": percentile(itls_s, 99.0) * 1e3,
    }


def kv_divergence_summary(
    ref_tokens: Sequence[Sequence[int]],
    test_tokens: Sequence[Sequence[int]],
) -> Dict[str, float]:
    """Token-divergence block for the KV-quantization accuracy harness.

    Compares per-request greedy token streams from a quantized-KV serving
    run against the full-precision replay of the SAME workload (greedy
    decoding is deterministic per request, so any mismatch is caused by the
    quantization error, not scheduling).  Reports the exact-match fraction,
    the position of the first diverging token (later is better — the
    quantized run tracked the reference longer), and the mean matched-prefix
    fraction across requests.
    """
    if len(ref_tokens) != len(test_tokens):
        raise ValueError(
            f"mismatched request counts: {len(ref_tokens)} reference vs "
            f"{len(test_tokens)} test streams"
        )
    n = len(ref_tokens)
    if not n:
        return {}
    exact = 0
    first_div: List[int] = []
    prefix_frac: List[float] = []
    for r, t in zip(ref_tokens, test_tokens):
        r = [int(x) for x in r]
        t = [int(x) for x in t]
        m = min(len(r), len(t))
        i = next((j for j in range(m) if r[j] != t[j]), m)
        if i == m and len(r) == len(t):
            exact += 1
        else:
            first_div.append(i)
        prefix_frac.append(i / max(len(r), 1))
    out = {
        "requests": float(n),
        "exact_matches": float(exact),
        "exact_match_fraction": exact / n,
        "diverged_requests": float(n - exact),
        "divergence_fraction": (n - exact) / n,
        "matched_prefix_fraction": float(sum(prefix_frac) / n),
    }
    if first_div:
        out["first_divergence_min"] = float(min(first_div))
        out["first_divergence_mean"] = float(sum(first_div) / len(first_div))
    return out


def kv_divergence_section(
    ref_tokens: Sequence[Sequence[int]],
    test_tokens: Sequence[Sequence[int]],
) -> str:
    """Render the KV-quantization divergence block as a report section;
    empty string when there are no requests to compare."""
    summary = kv_divergence_summary(ref_tokens, test_tokens)
    if not summary:
        return ""
    rows = [{"metric": k, "value": v} for k, v in summary.items()]
    return comparison_table(rows, ("metric", "value"))


def throughput_scalability(
    per_batch: Dict[int, float]
) -> Dict[int, float]:
    """Figure 6: throughput speedup over batch size 1 for each batch size."""
    if not per_batch:
        return {}
    base = per_batch.get(1)
    if base is None or base <= 0:
        base = per_batch[min(per_batch)]
    return {bs: tput / base for bs, tput in sorted(per_batch.items())}


# --------------------------------------------------------------------------
# Trace analysis (Table 3 / Figure 8)
# --------------------------------------------------------------------------
@dataclass
class LayerStat:
    name: str
    count: int
    total_s: float
    mean_s: float
    tags: Dict[str, Any]


def layer_breakdown(
    spans: Iterable[Span], level: TraceLevel = TraceLevel.FRAMEWORK
) -> List[LayerStat]:
    """Aggregate FRAMEWORK-level layer spans; sorted by total time desc."""
    agg: Dict[str, Dict[str, Any]] = {}
    for s in spans:
        if s.level != level:
            continue
        a = agg.setdefault(s.name, {"count": 0, "total": 0.0, "tags": dict(s.tags)})
        a["count"] += 1
        a["total"] += s.duration
    stats = [
        LayerStat(
            name=k,
            count=v["count"],
            total_s=v["total"],
            mean_s=v["total"] / max(v["count"], 1),
            tags=v["tags"],
        )
        for k, v in agg.items()
    ]
    stats.sort(key=lambda x: -x.total_s)
    return stats


def top_layers(spans: Iterable[Span], k: int = 5) -> List[LayerStat]:
    """Table 3: the top-K most time-consuming layers."""
    return layer_breakdown(spans)[:k]


def critical_path(spans: Sequence[Span]) -> List[Span]:
    """Longest chain of non-overlapping child spans under the root span
    (the "zoom-in" path of Figure 8)."""
    if not spans:
        return []
    by_parent: Dict[Optional[int], List[Span]] = {}
    for s in spans:
        by_parent.setdefault(s.parent_id, []).append(s)
    roots = by_parent.get(None, [])
    if not roots:
        return []
    root = max(roots, key=lambda s: s.duration)
    path = [root]
    cur = root
    while True:
        children = by_parent.get(cur.span_id, [])
        if not children:
            return path
        cur = max(children, key=lambda s: s.duration)
        path.append(cur)


def level_breakdown(spans: Iterable[Span]) -> Dict[str, float]:
    """Total time spent per trace level (hierarchical view)."""
    out: Dict[str, float] = {}
    for s in spans:
        out[s.level.name] = out.get(s.level.name, 0.0) + s.duration
    return out


# --------------------------------------------------------------------------
# Reports (F8 reporting; consumed by the CLI/web clients)
# --------------------------------------------------------------------------
def comparison_table(
    rows: List[Dict[str, Any]], columns: Sequence[str], sort_by: Optional[str] = None
) -> str:
    """Render an aligned text table (the paper's summary reports)."""
    if sort_by:
        rows = sorted(rows, key=lambda r: r.get(sort_by, 0), reverse=True)
    headers = list(columns)
    table = [headers] + [
        [_fmt(r.get(c)) for c in columns] for r in rows
    ]
    widths = [max(len(row[i]) for row in table) for i in range(len(headers))]
    lines = []
    for i, row in enumerate(table):
        lines.append("  ".join(cell.ljust(w) for cell, w in zip(row, widths)))
        if i == 0:
            lines.append("  ".join("-" * w for w in widths))
    return "\n".join(lines)


def _fmt(v: Any) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        return f"{v:.2f}" if abs(v) >= 0.01 else f"{v:.3g}"
    return str(v)


def markdown_report(
    title: str, sections: List[Tuple[str, str]]
) -> str:
    """Assemble a markdown report (analysis workflow output, step e)."""
    parts = [f"# {title}", ""]
    for heading, body in sections:
        parts += [f"## {heading}", "", body, ""]
    return "\n".join(parts)
