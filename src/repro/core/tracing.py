"""Across-stack tracing (paper F9, §4.4.4/§4.5.3).

MLModelScope captures profiles at model-, framework-, and system-level via
"tracing hooks" (a pair of start/end snippets producing *trace events*), and
aggregates all events into a single timeline on a *tracing server*.

Here the stack levels adapt to JAX/TPU:

  MODEL      spans around pipeline operators (pre-process, predict, post-process)
  FRAMEWORK  spans around jit/AOT executions and per-layer ``named_scope``
             regions emitted by instrumented model code
  SYSTEM     spans/counters derived from the compiled artifact (cost analysis,
             collective schedule) and host /proc counters

Events are published asynchronously to a :class:`TracingServer` which merges
them (by trace id) into one end-to-end timeline — timestamps need not be wall
clock (simulated clocks are allowed, mirroring the paper).
"""
from __future__ import annotations

import itertools
import json
import os
import queue
import threading
import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from enum import IntEnum
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence


class TraceLevel(IntEnum):
    """Listing 4's TraceLevel enum."""

    NONE = 0
    MODEL = 1       # steps in the evaluation pipeline
    FRAMEWORK = 2   # + layers within the framework
    SYSTEM = 3      # + system profilers
    FULL = 4        # all of the above

    @classmethod
    def parse(cls, value: "TraceLevel | str | int") -> "TraceLevel":
        if isinstance(value, TraceLevel):
            return value
        if isinstance(value, int):
            return cls(value)
        return cls[str(value).upper()]


_span_ids = itertools.count(1)


@dataclass
class Span:
    """A trace event: a named interval with context + metadata."""

    name: str
    level: TraceLevel
    trace_id: str
    span_id: int = field(default_factory=lambda: next(_span_ids))
    parent_id: Optional[int] = None
    begin: float = 0.0
    end: float = 0.0
    tags: Dict[str, Any] = field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.end - self.begin

    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "level": int(self.level),
            "trace_id": self.trace_id,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "begin": self.begin,
            "end": self.end,
            "tags": self.tags,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Span":
        return cls(
            name=d["name"],
            level=TraceLevel(d["level"]),
            trace_id=d["trace_id"],
            span_id=d["span_id"],
            parent_id=d.get("parent_id"),
            begin=d["begin"],
            end=d["end"],
            tags=d.get("tags", {}),
        )


class TracingServer:
    """Aggregates asynchronously-published spans into per-trace timelines.

    Thread-safe; spans may arrive out of order (the paper publishes events
    asynchronously) and are merged by ``trace_id`` and sorted by begin time.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._queue: "queue.SimpleQueue[Span]" = queue.SimpleQueue()
        self._traces: Dict[str, List[Span]] = {}

    def publish(self, span: Span) -> None:
        self._queue.put(span)

    def _drain(self) -> None:
        while True:
            try:
                span = self._queue.get_nowait()
            except queue.Empty:
                return
            with self._lock:
                self._traces.setdefault(span.trace_id, []).append(span)

    def timeline(self, trace_id: str) -> List[Span]:
        """The single end-to-end timeline for one evaluation."""
        self._drain()
        with self._lock:
            spans = list(self._traces.get(trace_id, ()))
        spans.sort(key=lambda s: (s.begin, s.span_id))
        return spans

    def trace_ids(self) -> List[str]:
        self._drain()
        with self._lock:
            return list(self._traces)

    def clear(self, trace_id: Optional[str] = None) -> None:
        self._drain()
        with self._lock:
            if trace_id is None:
                self._traces.clear()
            else:
                self._traces.pop(trace_id, None)

    # -- persistence ---------------------------------------------------
    def dump(self, trace_id: str, path: str) -> None:
        spans = self.timeline(trace_id)
        with open(path, "w") as f:
            json.dump([s.to_dict() for s in spans], f)

    @staticmethod
    def load(path: str) -> List[Span]:
        with open(path) as f:
            return [Span.from_dict(d) for d in json.load(f)]


class Tracer:
    """A tracing hook factory bound to one evaluation (``trace_id``).

    Only spans at or below the configured :class:`TraceLevel` are recorded —
    the user-selectable granularity of Listing 4. ``clock`` is injectable so
    simulators can publish virtual time (explicitly allowed by the paper).
    """

    def __init__(
        self,
        trace_id: str,
        server: TracingServer,
        level: TraceLevel = TraceLevel.FULL,
        clock: Callable[[], float] = time.perf_counter,
    ) -> None:
        self.trace_id = trace_id
        self.server = server
        self.level = TraceLevel.parse(level)
        self.clock = clock
        self._stack: threading.local = threading.local()

    def enabled(self, level: TraceLevel) -> bool:
        if self.level == TraceLevel.NONE:
            return False
        if self.level == TraceLevel.FULL:
            return True
        return int(level) <= int(self.level)

    def _parent(self) -> Optional[int]:
        stack = getattr(self._stack, "spans", None)
        return stack[-1].span_id if stack else None

    @contextmanager
    def span(
        self, name: str, level: TraceLevel = TraceLevel.MODEL, **tags: Any
    ) -> Iterator[Optional[Span]]:
        """The start/end tracing-hook pair of §4.4.4."""
        if not self.enabled(level):
            yield None
            return
        sp = Span(
            name=name,
            level=level,
            trace_id=self.trace_id,
            parent_id=self._parent(),
            tags=dict(tags),
        )
        stack = getattr(self._stack, "spans", None)
        if stack is None:
            stack = []
            self._stack.spans = stack
        stack.append(sp)
        sp.begin = self.clock()
        try:
            yield sp
        finally:
            sp.end = self.clock()
            stack.pop()
            self.server.publish(sp)

    def event(
        self,
        name: str,
        begin: float,
        end: float,
        level: TraceLevel = TraceLevel.SYSTEM,
        parent_id: Optional[int] = None,
        **tags: Any,
    ) -> Span:
        """Publish an externally-timed event (e.g. from a profile dump)."""
        sp = Span(
            name=name,
            level=level,
            trace_id=self.trace_id,
            parent_id=parent_id if parent_id is not None else self._parent(),
            begin=begin,
            end=end,
            tags=dict(tags),
        )
        if self.enabled(level):
            self.server.publish(sp)
        return sp


class NullTracer(Tracer):
    """Trace level NONE — all hooks are no-ops (conditional-disable, §4.6)."""

    def __init__(self) -> None:
        super().__init__("null", TracingServer(), TraceLevel.NONE)


def host_counters() -> Dict[str, float]:
    """SYSTEM-level host counters from /proc (the PAPI/perf stand-in)."""
    out: Dict[str, float] = {}
    try:
        with open("/proc/self/stat") as f:
            parts = f.read().split()
        tick = os.sysconf("SC_CLK_TCK")
        out["utime_s"] = int(parts[13]) / tick
        out["stime_s"] = int(parts[14]) / tick
        out["rss_bytes"] = int(parts[23]) * os.sysconf("SC_PAGE_SIZE")
    except (OSError, IndexError, ValueError):  # pragma: no cover
        pass
    return out


def summarize(spans: Sequence[Span]) -> Dict[str, Dict[str, float]]:
    """Aggregate spans by name: count/total/mean duration (report helper)."""
    agg: Dict[str, Dict[str, float]] = {}
    for s in spans:
        a = agg.setdefault(s.name, {"count": 0, "total_s": 0.0})
        a["count"] += 1
        a["total_s"] += s.duration
    for a in agg.values():
        a["mean_s"] = a["total_s"] / max(a["count"], 1)
    return agg
