"""MLModelScope server (paper §4.3).

The server accepts client requests, resolves capable agents via the
registry, dispatches evaluations (to one agent, or at user request to all
matching agents in parallel), and runs the analysis workflow over the
evaluation database.

Scalability/fault-tolerance beyond the paper:

* failed agents (lease expiry or raised errors) trigger re-dispatch to the
  next least-loaded capable agent (node-failure handling);
* ``straggler_factor`` optionally duplicates a dispatch onto a second agent
  and takes the first result (straggler mitigation);
* dispatches run on a thread pool so N-system comparisons proceed in
  parallel (the paper's "choose the best hardware out of N in parallel").
"""
from __future__ import annotations

import random
import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from ..serve.scheduler import SchedulerConfig, backoff_delay
from .agent import Agent, EvaluationRequest
from .analysis import (
    comparison_table,
    latency_summary,
    layer_breakdown,
    level_breakdown,
    markdown_report,
    scheduler_summary,
    top_layers,
    throughput_scalability,
)
from .evaldb import EvalDB
from .manifest import SystemRequirements
from .registry import AgentRecord, Registry
from .tracing import Span, TracingServer


class DispatchError(RuntimeError):
    pass


@dataclass
class DispatchPolicy:
    """Server-side scheduling knobs (F4)."""

    max_attempts: int = 3              # re-dispatch on agent failure
    straggler_factor: float = 0.0      # >0: duplicate dispatch, first wins
    all_agents: bool = False           # fan out to every capable agent
    timeout_s: Optional[float] = None  # per-attempt wait (every attempt)
    backoff_base_s: float = 0.0        # retry backoff base (0 = immediate,
    #                                    the legacy behavior)
    backoff_cap_s: float = 1.0         # retry backoff cap
    backoff_jitter: float = 0.5        # ±fraction jitter on each delay
    backoff_seed: int = 0              # jitter rng seed (determinism)


class Server:
    """In-process MLModelScope server. Subprocess agents attach through the
    same interface via proxy Agent objects (launch/agent_main.py)."""

    def __init__(
        self,
        registry: Registry,
        tracing_server: TracingServer,
        evaldb: EvalDB,
        max_workers: int = 8,
        sleep: Callable[[float], None] = time.sleep,
    ) -> None:
        self.registry = registry
        self.tracing_server = tracing_server
        self.evaldb = evaldb
        self._agents: Dict[str, Agent] = {}
        self._pool = ThreadPoolExecutor(max_workers=max_workers)
        self._lock = threading.Lock()
        self._sleep = sleep            # injectable for fake-clock tests

    # -- agent attachment -----------------------------------------------------
    def attach_agent(self, agent: Agent) -> None:
        with self._lock:
            self._agents[agent.agent_id] = agent

    def detach_agent(self, agent_id: str) -> None:
        with self._lock:
            self._agents.pop(agent_id, None)

    def _lookup(self, record: AgentRecord) -> Optional[Agent]:
        with self._lock:
            return self._agents.get(record.agent_id)

    # -- evaluation workflow (steps 2-4, 8-9) -----------------------------------
    def evaluate(
        self,
        req: EvaluationRequest,
        requirements: Optional[SystemRequirements] = None,
        policy: Optional[DispatchPolicy] = None,
        scheduler: Optional[SchedulerConfig] = None,
    ) -> List[Dict[str, Any]]:
        """Dispatch an evaluation; returns one result per served agent.

        ``scheduler`` threads a request-scheduler configuration through
        dispatch so the agent runs the scenario on the scheduler-backed
        executor (micro-batching + bounded queue); a config already present
        on the request wins."""
        policy = policy or DispatchPolicy()
        if scheduler is not None and req.scheduler is None:
            req.scheduler = scheduler
        model_key = self._model_key(req)
        records = self.registry.resolve(
            model_key,
            backend_name=req.backend,
            requirements=requirements,
        )
        if not records:
            raise DispatchError(
                f"no agent can serve model={model_key} backend={req.backend!r}"
            )
        if policy.all_agents:
            futures = {
                self._pool.submit(self._dispatch_one, rec, req, policy): rec
                for rec in records
            }
            results = []
            for fut in futures:
                results.append(fut.result(timeout=policy.timeout_s))
            return results
        return [self._dispatch_with_retry(records, req, policy)]

    def _model_key(self, req: EvaluationRequest) -> str:
        if req.model_version:
            return f"{req.model}:{req.model_version}"
        found = self.registry.find_manifest(req.model)
        if found is None:
            raise DispatchError(f"model {req.model!r} not in registry")
        return found.key

    def _dispatch_with_retry(
        self,
        records: List[AgentRecord],
        req: EvaluationRequest,
        policy: DispatchPolicy,
    ) -> Dict[str, Any]:
        """Least-loaded-first dispatch with failover + straggler duplication.

        ``timeout_s`` bounds EVERY attempt's wait (not just the first); a
        timed-out attempt cancels its still-pending futures and counts as a
        failure.  Between attempts the server backs off with capped
        exponential delay + seeded jitter (``backoff_base_s = 0`` keeps the
        legacy retry-immediately behavior)."""
        errors: List[str] = []
        rng = random.Random(policy.backoff_seed)
        attempt = 0
        idx = 0
        while attempt < policy.max_attempts and idx < len(records):
            if attempt > 0 and policy.backoff_base_s > 0:
                self._sleep(backoff_delay(
                    attempt, policy.backoff_base_s, policy.backoff_cap_s,
                    policy.backoff_jitter, rng,
                ))
            primary = records[idx]
            candidates = [primary]
            if policy.straggler_factor > 0 and idx + 1 < len(records):
                candidates.append(records[idx + 1])  # duplicate dispatch
            futures: List[Future] = [
                self._pool.submit(self._dispatch_one, rec, req, policy)
                for rec in candidates
            ]
            done, pending = wait(
                futures, timeout=policy.timeout_s, return_when=FIRST_COMPLETED
            )
            winner: Optional[Dict[str, Any]] = None
            for fut in done:
                try:
                    winner = fut.result()
                    break
                except Exception as e:  # noqa: BLE001 - collected for report
                    errors.append(str(e))
            if winner is not None:
                for fut in pending:
                    fut.cancel()
                return winner
            if not done:
                # attempt timed out: give up on these candidates (cancel
                # what hasn't started; a running dispatch is abandoned) and
                # fail over to the next records
                for fut in pending:
                    fut.cancel()
                errors.append(
                    f"attempt {attempt + 1} timed out after "
                    f"{policy.timeout_s}s on "
                    f"{[r.agent_id for r in candidates]}"
                )
            # all completed candidates failed -> advance past them
            idx += len(candidates)
            attempt += 1
        raise DispatchError(
            f"evaluation failed after {attempt} attempt(s): {errors or 'no agents left'}"
        )

    def _dispatch_one(
        self, record: AgentRecord, req: EvaluationRequest, policy: DispatchPolicy
    ) -> Dict[str, Any]:
        agent = self._lookup(record)
        if agent is None:
            raise DispatchError(f"agent {record.agent_id} not attached")
        if not self.registry.heartbeat(record.agent_id, ttl=agent.lease_ttl):
            # lease expired: the "node" is considered failed
            raise DispatchError(f"agent {record.agent_id} lease expired")
        self.registry.update_load(record.agent_id, +1)
        try:
            return agent.evaluate(req)
        finally:
            self.registry.update_load(record.agent_id, -1)

    # -- analysis workflow (steps a-e) -------------------------------------------
    def analyze(
        self,
        model: str = "",
        backend: str = "",
        system: str = "",
        scenario: str = "",
    ) -> Dict[str, Any]:
        """Aggregate evaluation results matching the constraints (§4.3)."""
        recs = self.evaldb.query(
            model=model, backend=backend, system=system, scenario=scenario
        )
        rows = []
        for r in recs:
            row: Dict[str, Any] = {
                "model": r.model,
                "version": r.model_version,
                "backend": r.backend,
                "system": r.system,
                "scenario": r.scenario,
                "batch": r.batch_size,
            }
            row.update(
                {
                    k: v
                    for k, v in r.metrics.items()
                    if isinstance(v, (int, float))
                }
            )
            rows.append(row)
        return {"count": len(recs), "rows": rows, "records": recs}

    def report(self, model: str = "", **constraints) -> str:
        """Generate the markdown summary report (workflow step e)."""
        res = self.analyze(model=model, **constraints)
        sections = []
        if res["rows"]:
            cols = sorted({k for row in res["rows"] for k in row})
            # keep identity columns first
            ident = [c for c in ("model", "version", "backend", "system", "scenario", "batch") if c in cols]
            rest = [c for c in cols if c not in ident]
            sections.append(
                ("Evaluations", comparison_table(res["rows"], ident + rest))
            )
        # trace-derived sections for the most recent evaluation
        if res["records"]:
            last = res["records"][-1]
            spans = [Span.from_dict(d) for d in self.evaldb.spans(last.eval_id)]
            if spans:
                tl = top_layers(spans, k=5)
                body = comparison_table(
                    [
                        {
                            "layer": s.name,
                            "count": s.count,
                            "total_ms": s.total_s * 1e3,
                            "mean_ms": s.mean_s * 1e3,
                        }
                        for s in tl
                    ],
                    ["layer", "count", "total_ms", "mean_ms"],
                )
                sections.append(("Top layers (most recent evaluation)", body))
                lv = level_breakdown(spans)
                sections.append(
                    (
                        "Per-level time",
                        "\n".join(f"- {k}: {v*1e3:.3f} ms" for k, v in sorted(lv.items())),
                    )
                )
                sched = scheduler_summary(spans)
                if sched:
                    sections.append(
                        (
                            "Scheduler (queueing + micro-batching)",
                            "\n".join(f"- {k}: {v:.3f}" for k, v in sorted(sched.items())),
                        )
                    )
        return markdown_report(f"MLModelScope report: {model or 'all models'}", sections)

    def shutdown(self) -> None:
        self._pool.shutdown(wait=False, cancel_futures=True)
