"""Benchmarking specification (paper F1/F2/F5, §4.1).

MLModelScope defines all four aspects of an evaluation — model, software
stack, system, benchmarking scenario — in textual manifests so the platform
can *provision* a reproducible evaluation. We keep the paper's YAML schema
(Listing 1 & 2) and adapt the fields to the JAX/TPU world:

* model manifest     — names an architecture config + shapes + processing
                       steps + asset (checkpoint) references with checksums.
* backend manifest   — the "framework manifest" analogue: names a compute
                       backend (``ref`` | ``pallas``), its version constraint,
                       and the mesh stacks it provides (the paper's per-arch
                       docker containers become per-topology mesh specs).

Version constraints use the paper's ``'>=1.12.0 <2.0'`` syntax.
"""
from __future__ import annotations

import hashlib
import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import yaml


# --------------------------------------------------------------------------
# Semantic versions + constraints (F5 artifact versioning)
# --------------------------------------------------------------------------
_VER_RE = re.compile(r"^(\d+)(?:\.(\d+))?(?:\.(\d+))?$")
_CONS_RE = re.compile(r"(>=|<=|==|>|<|~)?\s*(\d+(?:\.\d+){0,2})")


def parse_version(text: str) -> Tuple[int, int, int]:
    m = _VER_RE.match(str(text).strip())
    if not m:
        raise ValueError(f"invalid semantic version: {text!r}")
    major, minor, patch = (int(g) if g else 0 for g in m.groups())
    return (major, minor, patch)


class VersionConstraint:
    """A conjunction of comparator clauses, e.g. ``'>=1.12.0 <2.0'``."""

    def __init__(self, spec: str = "") -> None:
        self.spec = str(spec or "").strip()
        self.clauses: List[Tuple[str, Tuple[int, int, int]]] = []
        if self.spec:
            for op, ver in _CONS_RE.findall(self.spec):
                self.clauses.append((op or "==", parse_version(ver)))

    def satisfied_by(self, version: str) -> bool:
        v = parse_version(version)
        for op, ref in self.clauses:
            ok = {
                "==": v == ref,
                ">=": v >= ref,
                "<=": v <= ref,
                ">": v > ref,
                "<": v < ref,
                "~": v[:2] == ref[:2],  # compatible-release on major.minor
            }[op]
            if not ok:
                return False
        return True

    def __repr__(self) -> str:  # pragma: no cover
        return f"VersionConstraint({self.spec!r})"


# --------------------------------------------------------------------------
# Processing steps (built-in pipeline operators, §4.1.1)
# --------------------------------------------------------------------------
@dataclass
class ProcessingStep:
    """One built-in pre/post-processing pipeline operator."""

    op: str
    params: Dict[str, Any] = field(default_factory=dict)

    @classmethod
    def from_entry(cls, entry: Any) -> "ProcessingStep":
        if isinstance(entry, str):
            return cls(op=entry)
        if isinstance(entry, dict) and len(entry) == 1:
            (op, params), = entry.items()
            return cls(op=op, params=dict(params or {}))
        raise ValueError(f"invalid processing step: {entry!r}")


@dataclass
class IOSpec:
    """One input/output modality (type + layer name + element type + steps)."""

    type: str
    layer_name: str = ""
    element_type: str = "float32"
    steps: List[ProcessingStep] = field(default_factory=list)


# --------------------------------------------------------------------------
# Model manifest (Listing 1)
# --------------------------------------------------------------------------
@dataclass
class ModelManifest:
    name: str
    version: str = "1.0.0"
    description: str = ""
    backend_name: str = "ref"                # paper: framework.name
    backend_constraint: str = ""             # paper: framework.version
    arch: str = ""                           # architecture config id
    reduced: bool = False                    # use the smoke-scale config
    inputs: List[IOSpec] = field(default_factory=list)
    outputs: List[IOSpec] = field(default_factory=list)
    model_assets: Dict[str, Any] = field(default_factory=dict)  # checkpoint dir, checksum, seed
    attributes: Dict[str, Any] = field(default_factory=dict)

    # -- construction ----------------------------------------------------
    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ModelManifest":
        fw = d.get("framework", d.get("backend", {})) or {}
        def _iospecs(key: str) -> List[IOSpec]:
            specs = []
            for e in d.get(key, []) or []:
                specs.append(
                    IOSpec(
                        type=e.get("type", "tensor"),
                        layer_name=e.get("layer_name", ""),
                        element_type=e.get("element_type", "float32"),
                        steps=[ProcessingStep.from_entry(s) for s in e.get("steps", []) or []],
                    )
                )
            return specs

        m = cls(
            name=d["name"],
            version=str(d.get("version", "1.0.0")),
            description=d.get("description", ""),
            backend_name=fw.get("name", "ref"),
            backend_constraint=str(fw.get("version", "")),
            arch=d.get("arch", d.get("model", {}).get("arch", "")) or "",
            reduced=bool(d.get("reduced", False)),
            inputs=_iospecs("inputs"),
            outputs=_iospecs("outputs"),
            model_assets=dict(d.get("model", {}) or {}),
            attributes=dict(d.get("attributes", {}) or {}),
        )
        m.validate()
        return m

    @classmethod
    def from_yaml(cls, text: str) -> "ModelManifest":
        return cls.from_dict(yaml.safe_load(text))

    @classmethod
    def load(cls, path: str) -> "ModelManifest":
        with open(path) as f:
            return cls.from_yaml(f.read())

    # -- serialization (round-trip for the registry) ----------------------
    def to_dict(self) -> Dict[str, Any]:
        def _io(specs: Sequence[IOSpec]) -> List[Dict[str, Any]]:
            return [
                {
                    "type": s.type,
                    "layer_name": s.layer_name,
                    "element_type": s.element_type,
                    "steps": [{p.op: p.params} for p in s.steps],
                }
                for s in specs
            ]

        return {
            "name": self.name,
            "version": self.version,
            "description": self.description,
            "framework": {"name": self.backend_name, "version": self.backend_constraint},
            "arch": self.arch,
            "reduced": self.reduced,
            "inputs": _io(self.inputs),
            "outputs": _io(self.outputs),
            "model": self.model_assets,
            "attributes": self.attributes,
        }

    def to_yaml(self) -> str:
        return yaml.safe_dump(self.to_dict(), sort_keys=False)

    # -- validation --------------------------------------------------------
    def validate(self) -> None:
        if not self.name:
            raise ValueError("model manifest requires a name")
        parse_version(self.version)
        VersionConstraint(self.backend_constraint)  # raises on bad spec

    @property
    def key(self) -> str:
        """Registry key: name:version (artifact versioning, F5)."""
        return f"{self.name}:{self.version}"

    def checksum(self) -> str:
        """Content checksum of the manifest itself (reproducibility aid)."""
        return hashlib.sha256(self.to_yaml().encode()).hexdigest()[:16]


# --------------------------------------------------------------------------
# Backend ("framework") manifest (Listing 2)
# --------------------------------------------------------------------------
@dataclass
class BackendManifest:
    """The software stack: a compute backend + the mesh stacks it serves.

    The paper's ``containers: {amd64: {cpu: ..., gpu: ...}}`` becomes
    ``meshes: {host: ..., pod: ..., multipod: ...}`` — named device
    topologies the backend can provision.
    """

    name: str                                 # "ref" | "pallas"
    version: str = "1.0.0"
    description: str = ""
    meshes: Dict[str, Dict[str, Any]] = field(default_factory=dict)
    attributes: Dict[str, Any] = field(default_factory=dict)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "BackendManifest":
        m = cls(
            name=d["name"],
            version=str(d.get("version", "1.0.0")),
            description=d.get("description", ""),
            meshes=dict(d.get("meshes", d.get("containers", {})) or {}),
            attributes=dict(d.get("attributes", {}) or {}),
        )
        parse_version(m.version)
        return m

    @classmethod
    def from_yaml(cls, text: str) -> "BackendManifest":
        return cls.from_dict(yaml.safe_load(text))

    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "version": self.version,
            "description": self.description,
            "meshes": self.meshes,
            "attributes": self.attributes,
        }

    def to_yaml(self) -> str:
        return yaml.safe_dump(self.to_dict(), sort_keys=False)

    @property
    def key(self) -> str:
        return f"{self.name}:{self.version}"


# --------------------------------------------------------------------------
# System requirements + scenario options (the other two user inputs, §4.1)
# --------------------------------------------------------------------------
@dataclass
class SystemRequirements:
    """Hardware constraints used for agent resolution (§4.7)."""

    platform: str = ""          # "cpu" | "tpu" | ""
    min_devices: int = 0
    min_memory_bytes: int = 0
    mesh: str = ""              # named mesh topology ("host", "pod", "multipod")

    def satisfied_by(self, info: Dict[str, Any]) -> bool:
        if self.platform and info.get("platform") != self.platform:
            return False
        if self.min_devices and int(info.get("num_devices", 0)) < self.min_devices:
            return False
        if self.min_memory_bytes and int(info.get("memory_bytes", 0)) < self.min_memory_bytes:
            return False
        if self.mesh and info.get("mesh") != self.mesh:
            return False
        return True


# --------------------------------------------------------------------------
# Engine knobs (serving-engine configuration, part of the evaluation spec)
# --------------------------------------------------------------------------
@dataclass
class EngineKnobs:
    """The serving-engine configuration an evaluation ran under.

    The paper's manifests make the model and software stack self-describing;
    the serving engine grew its own knobs (paged KV, speculative decoding,
    prefix caching, tensor parallelism, KV quantization) that change the
    measured numbers just as much — so they are recorded with every run and
    printed in the serve report header.
    """

    engine: str = "static"          # static | continuous | paged
    kv_dtype: str = "float32"       # KV pool storage dtype (int8/fp8 = quantized)
    page_size: int = 0              # tokens per KV page (0 = not paged)
    spec_k: int = 0                 # speculative draft depth (0 = off)
    prefix_cache: bool = False      # automatic prefix caching on?
    tp: int = 1                     # tensor-parallel degree
    recovery: str = "replay"        # fleet orphan recovery: replay | migrate
    checkpoint_every: int = 0       # decode steps between KV checkpoints

    def to_dict(self) -> Dict[str, Any]:
        return {
            "engine": self.engine,
            "kv_dtype": self.kv_dtype,
            "page_size": int(self.page_size),
            "spec_k": int(self.spec_k),
            "prefix_cache": bool(self.prefix_cache),
            "tp": int(self.tp),
            "recovery": self.recovery,
            "checkpoint_every": int(self.checkpoint_every),
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "EngineKnobs":
        return cls(**{k: v for k, v in d.items() if k in cls.__dataclass_fields__})

    def describe(self) -> str:
        """One-line report header, e.g.
        ``engine=paged kv_dtype=int8 page_size=16 spec_k=0 prefix_cache=on tp=1``.
        Recovery knobs print only when armed (old headers stay byte-stable)."""
        out = (
            f"engine={self.engine} kv_dtype={self.kv_dtype} "
            f"page_size={self.page_size} spec_k={self.spec_k} "
            f"prefix_cache={'on' if self.prefix_cache else 'off'} tp={self.tp}"
        )
        if self.recovery != "replay" or self.checkpoint_every:
            out += (f" recovery={self.recovery}"
                    f" checkpoint_every={self.checkpoint_every}")
        return out
