"""MLModelScope agent (paper §4.4).

An agent is a model-serving process on a system of interest. It:

* self-registers its HW/SW stack + built-in models in the registry (init
  workflow, step 0),
* on an evaluation request: downloads/validates assets via the *data
  manager*, runs the evaluation pipeline (pre-process -> predict ->
  post-process) under the requested benchmarking scenario,
* publishes trace events to the tracing server and results to the
  evaluation database.

Everything except the framework predictor is shared across backends.
"""
from __future__ import annotations

import hashlib
import os
import socket
import time
import uuid
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional

import numpy as np

from ..serve.scheduler import SchedulerConfig
from .evaldb import EvalDB, EvaluationRecord
from .manifest import ModelManifest
from .pipeline import Pipeline, build_steps
from .predictor import OpenRequest, make_predictor
from .registry import AgentRecord, Registry
from .scenarios import ScenarioSpec, run_scenario
from .tracing import (
    host_counters,
    NullTracer,
    Tracer,
    TraceLevel,
    TracingServer,
)


@dataclass
class EvaluationRequest:
    """The dispatched unit of work (server -> agent, step 4)."""

    model: str
    model_version: str = ""
    backend: str = "ref"
    scenario: ScenarioSpec = field(default_factory=ScenarioSpec)
    trace_level: str = "MODEL"
    batch_size: int = 1
    seq_len: int = 128
    mode: str = "serve"
    options: Dict[str, Any] = field(default_factory=dict)
    # when set, the evaluation runs through the scheduler-backed executor
    # with these micro-batching / admission knobs (F7 under concurrent load)
    scheduler: Optional[SchedulerConfig] = None

    def to_dict(self) -> Dict[str, Any]:
        return {
            "model": self.model,
            "model_version": self.model_version,
            "backend": self.backend,
            "scenario": self.scenario.to_dict(),
            "trace_level": self.trace_level,
            "batch_size": self.batch_size,
            "seq_len": self.seq_len,
            "mode": self.mode,
            "options": self.options,
            "scheduler": self.scheduler.to_dict() if self.scheduler else None,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "EvaluationRequest":
        d = dict(d)
        d["scenario"] = ScenarioSpec.from_dict(d.get("scenario", {}))
        if d.get("scheduler"):
            d["scheduler"] = SchedulerConfig.from_dict(d["scheduler"])
        return cls(**{k: v for k, v in d.items() if k in cls.__dataclass_fields__})


class DataManager:
    """§4.4.1 — asset management with checksum validation and caching.

    Model assets here are checkpoint directories / data files on local disk
    (the offline stand-in for the artifact store); checksums still guard
    integrity exactly as in the paper.
    """

    def __init__(self, cache_dir: Optional[str] = None) -> None:
        self.cache_dir = cache_dir or os.path.join(
            os.environ.get("TMPDIR", "/tmp"), "mlms-cache"
        )
        os.makedirs(self.cache_dir, exist_ok=True)

    def fetch(self, path: str, checksum: str = "") -> str:
        """Resolve an asset path; validate checksum when provided."""
        if not os.path.exists(path):
            raise FileNotFoundError(f"model asset not found: {path}")
        if checksum:
            actual = self.checksum(path)
            if not actual.startswith(checksum) and actual != checksum:
                raise ValueError(
                    f"checksum mismatch for {path}: {actual} != {checksum}"
                )
        return path

    @staticmethod
    def checksum(path: str) -> str:
        h = hashlib.sha256()
        if os.path.isdir(path):
            for root, _, files in sorted(os.walk(path)):
                for fn in sorted(files):
                    with open(os.path.join(root, fn), "rb") as f:
                        for chunk in iter(lambda: f.read(1 << 20), b""):
                            h.update(chunk)
        else:
            with open(path, "rb") as f:
                for chunk in iter(lambda: f.read(1 << 20), b""):
                    h.update(chunk)
        return h.hexdigest()


class Agent:
    """An in-process MLModelScope agent."""

    def __init__(
        self,
        backend: str,
        registry: Registry,
        tracing_server: TracingServer,
        evaldb: EvalDB,
        system: Optional[Dict[str, Any]] = None,
        agent_id: Optional[str] = None,
        data_manager: Optional[DataManager] = None,
        lease_ttl: Optional[float] = None,
    ) -> None:
        self.agent_id = agent_id or f"{backend}-{uuid.uuid4().hex[:8]}"
        self.backend = backend
        self.registry = registry
        self.tracing_server = tracing_server
        self.evaldb = evaldb
        self.data_manager = data_manager or DataManager()
        self.system = system or default_system_info()
        # in-process agents share the host process' liveness; subprocess
        # agents heartbeat on the paper's short TTL
        self.lease_ttl = lease_ttl
        self.manifests: Dict[str, ModelManifest] = {}
        self._predictor = make_predictor(backend)
        # fault-injection hook for platform tests (simulated node failure)
        self.fail_next: int = 0

    # -- initialization workflow (step 0) -----------------------------------
    def register_models(self, manifests: Iterable[ModelManifest]) -> None:
        for m in manifests:
            self.manifests[m.key] = m
            self.registry.register_manifest(m)
        self.announce()

    def announce(self) -> None:
        """Self-register in the distributed registry with a TTL lease."""
        self.registry.register_agent(
            AgentRecord(
                agent_id=self.agent_id,
                backend=self.backend,
                backend_version=self._predictor.version,
                system=self.system,
                models=sorted(self.manifests),
                address=f"inproc://{self.agent_id}",
            ),
            ttl=self.lease_ttl,
        )

    def heartbeat(self) -> bool:
        return self.registry.heartbeat(self.agent_id)

    # -- evaluation workflow (steps 5-7) -------------------------------------
    def evaluate(self, req: EvaluationRequest) -> Dict[str, Any]:
        manifest = self._resolve_manifest(req)
        trace_id = f"eval-{uuid.uuid4().hex[:12]}"
        tracer = Tracer(
            trace_id, self.tracing_server, TraceLevel.parse(req.trace_level)
        )
        if self.fail_next > 0:
            self.fail_next -= 1
            raise RuntimeError(f"injected agent failure on {self.agent_id}")

        with tracer.span("evaluation", TraceLevel.MODEL, agent=self.agent_id):
            # 5. fetch + validate assets
            assets = manifest.model_assets
            if assets.get("base_path"):
                with tracer.span("data_manager:fetch", TraceLevel.MODEL):
                    self.data_manager.fetch(
                        assets["base_path"], assets.get("checksum", "")
                    )
            # open the predictor (model load; cold-start cost is traced)
            open_req = OpenRequest(
                manifest=manifest,
                backend=self.backend,
                batch_size=req.batch_size,
                seq_len=req.seq_len,
                mode=req.mode,
                options=req.options,
            )
            handle = self._predictor.open(open_req, tracer)
            try:
                pre_ops = build_steps(
                    manifest.inputs[0].steps if manifest.inputs else []
                )
                post_ops = build_steps(
                    manifest.outputs[0].steps if manifest.outputs else []
                )

                def predict_once(batch_size: int) -> Any:
                    batch = self._make_batch(manifest, req, batch_size, pre_ops, tracer)
                    out = self._predictor.predict(handle, batch, tracer)
                    return self._post(out, post_ops, tracer)

                if tracer.enabled(TraceLevel.SYSTEM):
                    before = host_counters()
                metrics = run_scenario(
                    req.scenario, predict_once, tracer, scheduler=req.scheduler
                )
                if tracer.enabled(TraceLevel.SYSTEM):
                    after = host_counters()
                    tracer.event(
                        "system:host_counters",
                        0.0,
                        0.0,
                        TraceLevel.SYSTEM,
                        **{
                            k: after.get(k, 0.0) - before.get(k, 0.0)
                            for k in ("utime_s", "stime_s")
                        },
                        rss_bytes=after.get("rss_bytes", 0.0),
                    )
            finally:
                self._predictor.close(handle)

        # 6-7. publish results + trace
        spans = [s.to_dict() for s in self.tracing_server.timeline(trace_id)]
        record = EvaluationRecord(
            model=manifest.name,
            model_version=manifest.version,
            backend=self.backend,
            backend_version=self._predictor.version,
            system=self.system.get("name", "local"),
            scenario=req.scenario.kind,
            batch_size=req.batch_size,
            trace_level=req.trace_level,
            agent_id=self.agent_id,
            metrics=metrics,
            user_input=req.to_dict(),
        )
        eval_id = self.evaldb.insert(record, spans)
        return {
            "eval_id": eval_id,
            "trace_id": trace_id,
            "agent_id": self.agent_id,
            "model": manifest.key,
            "metrics": metrics,
        }

    # -- helpers -------------------------------------------------------------
    def _resolve_manifest(self, req: EvaluationRequest) -> ModelManifest:
        if req.model_version:
            key = f"{req.model}:{req.model_version}"
            m = self.manifests.get(key)
            if m is None:
                raise KeyError(f"agent {self.agent_id} has no model {key}")
            return m
        found = self.registry.find_manifest(req.model)
        if found is not None and found.key in self.manifests:
            return self.manifests[found.key]
        # fall back to highest local version
        candidates = [m for m in self.manifests.values() if m.name == req.model]
        if not candidates:
            raise KeyError(f"agent {self.agent_id} has no model {req.model!r}")
        return max(candidates, key=lambda m: m.version)

    def _make_batch(
        self,
        manifest: ModelManifest,
        req: EvaluationRequest,
        batch_size: int,
        pre_ops: List[tuple],
        tracer: Tracer,
    ) -> np.ndarray:
        """Produce a model batch by streaming raw inputs through the
        pre-processing pipeline (F6: operators overlap on threads)."""
        raw = self._synthetic_inputs(manifest, req, batch_size)
        if pre_ops:
            pipe = Pipeline(pre_ops, tracer=tracer)
            processed = pipe.run(raw)
        else:
            processed = raw
        return np.stack([np.asarray(x) for x in processed])

    def _synthetic_inputs(
        self, manifest: ModelManifest, req: EvaluationRequest, batch_size: int
    ) -> List[Any]:
        """Deterministic synthetic raw inputs per modality."""
        rng = np.random.default_rng(abs(hash((manifest.key, batch_size))) % (2**32))
        modality = manifest.inputs[0].type if manifest.inputs else "tokens"
        if modality == "image":
            return [
                rng.integers(0, 255, size=(288, 288, 3)).astype(np.uint8)
                for _ in range(batch_size)
            ]
        # token inputs: ints in [0, vocab)
        vocab = int(manifest.attributes.get("vocab_size", 256))
        return [
            rng.integers(0, vocab, size=(req.seq_len,)).astype(np.int32)
            for _ in range(batch_size)
        ]

    def _post(self, out: Any, post_ops: List[tuple], tracer: Tracer) -> Any:
        if not post_ops:
            return out
        arr = np.asarray(out)
        batch = list(arr) if arr.ndim > 1 else [arr]
        pipe = Pipeline(post_ops, tracer=tracer)
        return pipe.run(batch)

    # -- teardown -------------------------------------------------------------
    def shutdown(self) -> None:
        self.registry.deregister_agent(self.agent_id)


def default_system_info() -> Dict[str, Any]:
    import jax

    dev = jax.devices()[0]
    return {
        "name": socket.gethostname(),
        "platform": dev.platform,
        "num_devices": jax.device_count(),
        "memory_bytes": 0,
        "mesh": "host",
        "host": socket.gethostname(),
    }
