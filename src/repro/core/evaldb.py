"""Evaluation database (paper §4.5.2).

After each evaluation the agent stores the benchmarking result and the
profiling trace keyed by the full user input, so historical evaluations can
be queried by input constraints and compared across model versions. Backed
by sqlite (stdlib) — file-based or in-memory.
"""
from __future__ import annotations

import json
import sqlite3
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

_SCHEMA = """
CREATE TABLE IF NOT EXISTS evaluations (
    id INTEGER PRIMARY KEY AUTOINCREMENT,
    created_at REAL NOT NULL,
    model TEXT NOT NULL,
    model_version TEXT NOT NULL,
    backend TEXT NOT NULL,
    backend_version TEXT NOT NULL,
    system TEXT NOT NULL,
    scenario TEXT NOT NULL,
    batch_size INTEGER NOT NULL,
    trace_level TEXT NOT NULL,
    agent_id TEXT NOT NULL,
    metrics_json TEXT NOT NULL,
    user_input_json TEXT NOT NULL
);
CREATE INDEX IF NOT EXISTS idx_eval_model ON evaluations(model, model_version);
CREATE TABLE IF NOT EXISTS traces (
    eval_id INTEGER NOT NULL REFERENCES evaluations(id),
    spans_json TEXT NOT NULL
);
"""


@dataclass
class EvaluationRecord:
    model: str
    model_version: str
    backend: str
    backend_version: str
    system: str
    scenario: str
    batch_size: int
    trace_level: str
    agent_id: str
    metrics: Dict[str, Any]
    user_input: Dict[str, Any] = field(default_factory=dict)
    created_at: float = 0.0
    eval_id: Optional[int] = None


class EvalDB:
    """Thread-safe sqlite-backed evaluation store."""

    def __init__(self, path: str = ":memory:") -> None:
        self._conn = sqlite3.connect(path, check_same_thread=False)
        self._lock = threading.Lock()
        with self._lock:
            self._conn.executescript(_SCHEMA)
            self._conn.commit()

    def insert(self, rec: EvaluationRecord, spans: Optional[List[Dict[str, Any]]] = None) -> int:
        created = rec.created_at or time.time()
        with self._lock:
            cur = self._conn.execute(
                "INSERT INTO evaluations (created_at, model, model_version, backend,"
                " backend_version, system, scenario, batch_size, trace_level, agent_id,"
                " metrics_json, user_input_json) VALUES (?,?,?,?,?,?,?,?,?,?,?,?)",
                (
                    created,
                    rec.model,
                    rec.model_version,
                    rec.backend,
                    rec.backend_version,
                    rec.system,
                    rec.scenario,
                    rec.batch_size,
                    rec.trace_level,
                    rec.agent_id,
                    json.dumps(rec.metrics),
                    json.dumps(rec.user_input),
                ),
            )
            eval_id = int(cur.lastrowid)
            if spans:
                self._conn.execute(
                    "INSERT INTO traces (eval_id, spans_json) VALUES (?,?)",
                    (eval_id, json.dumps(spans)),
                )
            self._conn.commit()
        rec.eval_id = eval_id
        return eval_id

    def query(
        self,
        model: str = "",
        model_version: str = "",
        backend: str = "",
        system: str = "",
        scenario: str = "",
    ) -> List[EvaluationRecord]:
        """Query historical evaluations by input constraints (§4.5.2)."""
        clauses, params = ["1=1"], []
        for col, val in (
            ("model", model),
            ("model_version", model_version),
            ("backend", backend),
            ("system", system),
            ("scenario", scenario),
        ):
            if val:
                clauses.append(f"{col} = ?")
                params.append(val)
        with self._lock:
            rows = self._conn.execute(
                "SELECT id, created_at, model, model_version, backend, backend_version,"
                " system, scenario, batch_size, trace_level, agent_id, metrics_json,"
                " user_input_json FROM evaluations WHERE "
                + " AND ".join(clauses)
                + " ORDER BY id",
                params,
            ).fetchall()
        out = []
        for r in rows:
            out.append(
                EvaluationRecord(
                    eval_id=r[0],
                    created_at=r[1],
                    model=r[2],
                    model_version=r[3],
                    backend=r[4],
                    backend_version=r[5],
                    system=r[6],
                    scenario=r[7],
                    batch_size=r[8],
                    trace_level=r[9],
                    agent_id=r[10],
                    metrics=json.loads(r[11]),
                    user_input=json.loads(r[12]),
                )
            )
        return out

    def spans(self, eval_id: int) -> List[Dict[str, Any]]:
        with self._lock:
            rows = self._conn.execute(
                "SELECT spans_json FROM traces WHERE eval_id = ?", (eval_id,)
            ).fetchall()
        spans: List[Dict[str, Any]] = []
        for (blob,) in rows:
            spans.extend(json.loads(blob))
        return spans

    def best_version(self, model: str, metric: str, maximize: bool = True) -> Optional[str]:
        """Which model version produced the best result (§4.5.2)."""
        best_v, best_m = None, None
        for rec in self.query(model=model):
            val = rec.metrics.get(metric)
            if val is None:
                continue
            if best_m is None or (val > best_m if maximize else val < best_m):
                best_v, best_m = rec.model_version, val
        return best_v

    def close(self) -> None:
        with self._lock:
            self._conn.close()
