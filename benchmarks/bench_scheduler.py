"""Serving hot path: sequential vs micro-batched vs continuous batching.

Issues the same offline request load (N prompts, M new tokens each) through
the toy LM three ways:

* ``sequential``  — one batch-1 ``engine.generate`` per request (the seed's
                    request loop: no batching at all)
* ``microbatch``  — the offline scenario through the RequestScheduler:
                    requests coalesce into micro-batches of ``max_batch``
                    and run through the static batched engine
* ``continuous``  — slot-based continuous batching: a fixed pool of KV
                    slots, per-slot admission at decode-step boundaries

Acceptance target: continuous batching >= 1.5x sequential-issue throughput
on the offline scenario (it should land near the slot count on the decode-
bound toy LM).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve.engine import ServeRequest, ServingEngine
from repro.serve.scheduler import RequestScheduler, SchedulerConfig

from .common import emit

NUM_REQUESTS = 16
MAX_NEW_TOKENS = 8
PROMPT_LEN = 8
SLOTS = 4


def _prompts(cfg):
    rng = np.random.default_rng(0)
    return [
        rng.integers(0, cfg.vocab_size, (PROMPT_LEN,)).astype(np.int32)
        for _ in range(NUM_REQUESTS)
    ]


def _run_sequential(engine, prompts) -> float:
    t0 = time.perf_counter()
    for p in prompts:
        engine.generate([p], MAX_NEW_TOKENS)
    return time.perf_counter() - t0


def _run_microbatch(engine, prompts) -> float:
    def execute(batch):
        engine.generate([r.payload for r in batch], MAX_NEW_TOKENS)

    sched = RequestScheduler(
        execute, SchedulerConfig(max_batch=SLOTS, batch_timeout_ms=0.0)
    )
    t0 = time.perf_counter()
    for p in prompts:
        sched.submit(payload=p, arrival_s=t0)
    sched.run_until_idle()
    return time.perf_counter() - t0


def _run_continuous(engine, prompts) -> float:
    reqs = [
        ServeRequest(request_id=i, prompt=p, max_new_tokens=MAX_NEW_TOKENS)
        for i, p in enumerate(prompts)
    ]
    stats = engine.serve_continuous(reqs, num_slots=SLOTS)
    return stats.wall_s


def run() -> None:
    cfg = get_config("glm4-9b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(
        model, params, max_batch=SLOTS, max_seq=PROMPT_LEN + 4 * MAX_NEW_TOKENS + 8
    )
    prompts = _prompts(cfg)
    total_tokens = NUM_REQUESTS * MAX_NEW_TOKENS

    # warm the three compile paths (batch-1 generate, batch-N generate,
    # ragged decode + slot writer) so timings measure steady state
    engine.generate([prompts[0]], 2)
    engine.generate(prompts[:SLOTS], 2)
    engine.serve_continuous(
        [ServeRequest(request_id=0, prompt=prompts[0], max_new_tokens=2)],
        num_slots=SLOTS,
    )

    t_seq = _run_sequential(engine, prompts)
    t_micro = _run_microbatch(engine, prompts)
    t_cont = _run_continuous(engine, prompts)

    emit("scheduler/sequential", t_seq / NUM_REQUESTS,
         f"tok_s={total_tokens / t_seq:.1f};speedup=1.00x")
    emit("scheduler/microbatch", t_micro / NUM_REQUESTS,
         f"tok_s={total_tokens / t_micro:.1f};speedup={t_seq / t_micro:.2f}x")
    emit("scheduler/continuous", t_cont / NUM_REQUESTS,
         f"tok_s={total_tokens / t_cont:.1f};speedup={t_seq / t_cont:.2f}x")
    if t_cont * 1.5 > t_seq:
        print(f"# WARNING: continuous batching speedup "
              f"{t_seq / t_cont:.2f}x below the 1.5x target")

    # ragged generation lengths: static micro-batches convoy on the longest
    # sequence in each batch, continuous batching retires slots early
    rng = np.random.default_rng(1)
    lengths = rng.integers(2, 4 * MAX_NEW_TOKENS + 1, NUM_REQUESTS).tolist()
    ragged_tokens = sum(lengths)

    def execute_ragged(batch):
        engine.generate(
            [r.payload[0] for r in batch], max(r.payload[1] for r in batch)
        )

    sched = RequestScheduler(
        execute_ragged, SchedulerConfig(max_batch=SLOTS, batch_timeout_ms=0.0)
    )
    t0 = time.perf_counter()
    for p, n in zip(prompts, lengths):
        sched.submit(payload=(p, n), arrival_s=t0)
    sched.run_until_idle()
    t_micro_r = time.perf_counter() - t0
    reqs = [
        ServeRequest(request_id=i, prompt=p, max_new_tokens=n)
        for i, (p, n) in enumerate(zip(prompts, lengths))
    ]
    t_cont_r = engine.serve_continuous(reqs, num_slots=SLOTS).wall_s
    emit("scheduler/microbatch_ragged", t_micro_r / NUM_REQUESTS,
         f"tok_s={ragged_tokens / t_micro_r:.1f};speedup=1.00x")
    emit("scheduler/continuous_ragged", t_cont_r / NUM_REQUESTS,
         f"tok_s={ragged_tokens / t_cont_r:.1f};"
         f"speedup={t_micro_r / t_cont_r:.2f}x")


if __name__ == "__main__":
    from .common import emit_header

    emit_header()
    run()
