"""Benchmark harness: one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (see benchmarks/common.py).
Run:  PYTHONPATH=src python -m benchmarks.run [--only fig2,table2]
"""
import argparse
import sys
import traceback

from .common import emit_header

BENCHES = [
    ("table2", "benchmarks.bench_table2_models"),
    ("fig2", "benchmarks.bench_fig2_dispatch"),
    ("fig6", "benchmarks.bench_fig6_scalability"),
    ("fig7", "benchmarks.bench_fig7_systems"),
    ("table3", "benchmarks.bench_table3_layers"),
    ("fig8", "benchmarks.bench_fig8_coldstart"),
    ("scheduler", "benchmarks.bench_scheduler"),
    ("paged", "benchmarks.bench_paged"),
    ("prefill", "benchmarks.bench_prefill"),
    ("spec", "benchmarks.bench_spec"),
    ("prefix", "benchmarks.bench_prefix"),
    ("tp", "benchmarks.bench_tp"),
    ("kvquant", "benchmarks.bench_kvquant"),
    ("faults", "benchmarks.bench_faults"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated bench keys")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    emit_header()
    failures = []
    for key, module in BENCHES:
        if only and key not in only:
            continue
        try:
            mod = __import__(module, fromlist=["run"])
            mod.run()
        except Exception:
            failures.append(key)
            traceback.print_exc()
    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
