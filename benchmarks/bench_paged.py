"""Paged vs dense-slot serving at a FIXED KV-cache memory budget.

The dense continuous-batching engine allocates ``num_slots * max_seq``
cache tokens whether or not they are live, so at a fixed HBM budget its
concurrency is ``budget // max_seq``.  The paged engine spends the same
budget as ``budget // page_size`` pages shared across many more slots:
ragged generation lengths mean most requests never touch ``max_seq``, so
the pool sustains far more concurrent requests (preempting the youngest
when it overcommits), and throughput follows occupancy on the decode-bound
toy LM.

Acceptance targets (ISSUE 2): paged sustains >= 1.5x the concurrency of the
dense-slot engine at an equal token budget (equivalently >= 1.5x throughput
on ragged lengths), and the Pallas paged-attention kernel matches the
reference within 1e-3 (f32, interpret mode).

Emits ``name,us_per_call,derived`` CSV rows plus a ``BENCH_paged.json``
artifact (uploaded by the CI smoke job) so the perf trajectory is tracked
per PR.  ``--smoke`` shrinks everything for CI.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.manifest import EngineKnobs
from repro.kernels import ref
from repro.kernels.paged_attention import paged_attention as pallas_paged
from repro.models import build_model
from repro.serve.engine import ServeRequest, ServingEngine

from .common import bench_meta, emit


def _kernel_max_err(rng) -> float:
    """Pallas paged kernel vs the dense reference (interpret mode, f32)."""
    from repro.serve.page_table import scatter_cache_to_pages

    b, S, h, kvh, d, ps = 3, 40, 4, 2, 16, 8
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(b, S, kvh, d)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(b, S, kvh, d)), jnp.float32)
    lengths = jnp.asarray(rng.integers(1, S + 1, size=(b,)), jnp.int32)
    kp, vp, pt = scatter_cache_to_pages(kc, vc, ps, rng)
    a = ref.decode_attention(q, kc, vc, lengths)
    f = pallas_paged(q, jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(pt), lengths)
    return float(jnp.max(jnp.abs(a.astype(jnp.float32) - f.astype(jnp.float32))))


def run(smoke: bool = False, seed: int = 0) -> dict:
    max_seq, page_size, dense_slots = 128, 8, 2
    prompt_lo, prompt_hi, prefill_chunk, paged_slots = 4, 12, 16, 12
    num_requests, gen_hi = (24, 24) if smoke else (32, 32)
    # fixed KV budget: the dense engine's whole cache, counted in tokens.
    # the tight budget is the regime the ISSUE targets — each dense slot
    # must provision worst-case max_seq, so its concurrency collapses while
    # paged slots provision only the pages their ragged lengths touch
    budget_tokens = dense_slots * max_seq
    num_pages = budget_tokens // page_size          # same HBM spent as pages
    paged_slots = min(num_requests, paged_slots)

    cfg = get_config("glm4-9b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(
        model, params, max_batch=paged_slots, max_seq=max_seq, page_size=page_size
    )

    rng = np.random.default_rng(seed)
    prompts = [
        rng.integers(0, cfg.vocab_size, (int(n),)).astype(np.int32)
        for n in rng.integers(prompt_lo, prompt_hi + 1, num_requests)
    ]
    gen_lens = rng.integers(2, gen_hi + 1, num_requests).tolist()
    reqs = lambda: [
        ServeRequest(request_id=i, prompt=p, max_new_tokens=int(n))
        for i, (p, n) in enumerate(zip(prompts, gen_lens))
    ]
    total_tokens = sum(gen_lens)

    # warm every compile path the timed runs will hit (decode kv/page-bound
    # buckets grow with sequence length, chunked prefill has per-(len, pos)
    # shapes): run the identical workload once untimed
    engine.serve_continuous(reqs(), num_slots=dense_slots)
    engine.serve_paged(
        reqs(), num_slots=paged_slots, page_size=page_size,
        num_pages=num_pages + 1, prefill_chunk=prefill_chunk,
    )

    cont = engine.serve_continuous(reqs(), num_slots=dense_slots)
    paged = engine.serve_paged(
        reqs(), num_slots=paged_slots, page_size=page_size,
        num_pages=num_pages + 1,  # +1: reserved scratch page (not allocatable)
        prefill_chunk=prefill_chunk,
    )
    for a, b in zip(cont.results, paged.results):
        assert a.tokens.tolist() == b.tokens.tolist(), "paged tokens diverged"

    speedup = paged.throughput_tps / cont.throughput_tps
    concurrency_ratio = paged.peak_slot_occupancy / dense_slots
    kernel_err = _kernel_max_err(np.random.default_rng(seed + 7))

    emit("paged/dense_continuous", cont.wall_s / num_requests,
         f"tok_s={cont.throughput_tps:.1f};slots={dense_slots};"
         f"budget_tokens={budget_tokens};speedup=1.00x")
    emit("paged/paged", paged.wall_s / num_requests,
         f"tok_s={paged.throughput_tps:.1f};slots={paged_slots};"
         f"peak_concurrency={paged.peak_slot_occupancy};"
         f"pages={paged.num_pages}x{page_size};"
         f"preemptions={paged.preemptions};speedup={speedup:.2f}x")
    emit("paged/kernel_abs_err", kernel_err, "target=1e-3")
    if speedup < 1.5 and concurrency_ratio < 1.5:
        print(f"# WARNING: paged speedup {speedup:.2f}x and concurrency "
              f"{concurrency_ratio:.2f}x both below the 1.5x target")
    if kernel_err > 1e-3:
        print(f"# WARNING: paged kernel error {kernel_err:.2e} above 1e-3")

    out = {
        "bench": "paged",
        "smoke": smoke,
        **bench_meta(seed, EngineKnobs(engine="paged", page_size=page_size)),
        "budget_tokens": budget_tokens,
        "max_seq": max_seq,
        "page_size": page_size,
        "total_generated_tokens": total_tokens,
        "dense": {
            "slots": dense_slots,
            "tokens_per_s": cont.throughput_tps,
            "wall_s": cont.wall_s,
            "mean_slot_occupancy": cont.mean_slot_occupancy,
        },
        "paged": {
            "slots": paged_slots,
            "tokens_per_s": paged.throughput_tps,
            "wall_s": paged.wall_s,
            "mean_slot_occupancy": paged.mean_slot_occupancy,
            "peak_concurrency": paged.peak_slot_occupancy,
            "num_pages": paged.num_pages,
            "peak_pages_in_use": paged.peak_pages_in_use,
            "preemptions": paged.preemptions,
            "prefill_chunks": paged.prefill_chunks,
            "compile_stats": paged.compile_stats,
        },
        "throughput_speedup": speedup,
        "concurrency_ratio": concurrency_ratio,
        "kernel_abs_err_f32": kernel_err,
    }
    with open("BENCH_paged.json", "w") as f:
        json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    from .common import emit_header

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (interpret-mode kernels, CPU)")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload RNG seed (recorded in BENCH_paged.json)")
    args = ap.parse_args()
    emit_header()
    t0 = time.perf_counter()
    run(smoke=args.smoke, seed=args.seed)
    print(f"# bench_paged done in {time.perf_counter() - t0:.1f}s")
