"""SLO-aware multi-tenant scheduling benchmark: goodput at SLO.

Three deterministic discrete-event scenarios over the RequestScheduler with
a virtual clock and a token-proportional service model (no accelerator in
the loop, so every number is bit-reproducible across machines and the CI
gate is exact):

* ``capacity`` — sweep offered load (Poisson arrivals) and report the
  highest offered QPS whose goodput-under-SLO stays >= 99% — the
  max-QPS-at-p99-SLO operating point;
* ``noisy``   — a rate-limited noisy neighbor offers ~1.5x the engine's
  capacity next to a small victim tenant; the victim's p99 with fairness
  on must stay within 1.2x of its isolated run (token buckets contain the
  neighbor), while the FIFO baseline's victim p99 blows up;
* ``burst``   — a 3x overload burst over a mixed standard/best-effort
  population; SLO shedding keeps goodput-at-SLO >= 80% of capacity through
  the burst while the FIFO baseline (no fairness, no shedding) serves the
  same work hopelessly late.

Every scenario asserts ZERO silent loss: each submitted request reaches
exactly one terminal status (completed or rejected), and the counters are
gated in CI from both directions.
"""
from __future__ import annotations

import json
import threading

import numpy as np

from repro.core.analysis import percentile, slo_summary
from repro.core.tracing import Tracer, TracingServer
from repro.serve.scheduler import (
    RequestScheduler,
    SchedulerConfig,
    TenantSpec,
)

from .common import bench_meta, bench_main, emit

# simulated engine: a fixed decode rate plus a per-batch launch overhead.
# With 40-token requests and max_batch=8 the saturated service rate is
# 8 / (0.001 + 320/4000) s ~= 98.8 requests/s
CAPACITY_TPS = 4000.0     # tokens/s the simulated engine sustains
BATCH_OVERHEAD_S = 1e-3   # per-batch launch cost
TOKENS_PER_REQ = 40.0     # prompt + decode tokens per request
MAX_BATCH = 8
CAP_QPS = MAX_BATCH / (BATCH_OVERHEAD_S + MAX_BATCH * TOKENS_PER_REQ / CAPACITY_TPS)


class VirtualTime:
    def __init__(self):
        self.t = 0.0
        self._lock = threading.Lock()

    def clock(self):
        with self._lock:
            return self.t

    def sleep(self, dt):
        with self._lock:
            self.t += dt


def _poisson_trace(phases, rng):
    """Arrival times for piecewise-constant-rate Poisson phases
    ``[(duration_s, rate_qps), ...]`` — the interrupted-Poisson shape of
    the overload story, restarted at each phase boundary."""
    out = []
    t0 = 0.0
    for dur, rate in phases:
        t = t0
        while True:
            t += float(rng.exponential(1.0 / rate))
            if t >= t0 + dur:
                break
            out.append(t)
        t0 += dur
    return out


def _simulate(arrivals, *, tenants=(), fairness=True, slo_shed=True,
              tracer=None, max_batch=MAX_BATCH):
    """Drive one scheduler over ``arrivals`` = [(t, submit_kwargs), ...];
    returns (scheduler, futures, makespan_s)."""
    vt = VirtualTime()

    def execute(batch):
        cost = sum(r.cost_tokens for r in batch)
        vt.sleep(BATCH_OVERHEAD_S + cost / CAPACITY_TPS)

    sched = RequestScheduler(
        execute,
        SchedulerConfig(max_batch=max_batch, batch_timeout_ms=0.0,
                        queue_depth=1 << 20, fairness=fairness,
                        slo_shed=slo_shed),
        clock=vt.clock, sleep=vt.sleep, tracer=tracer,
        tenants=list(tenants),
    )
    futs = [sched.submit(arrival_s=t, cost_tokens=TOKENS_PER_REQ, **kw)
            for t, kw in arrivals]
    sched.run_until_idle()
    return sched, futs, vt.t


def _conserve(sched, futs):
    """The zero-silent-loss invariant: every submission is terminal."""
    statuses = [f.request.status for f in futs]
    completed = statuses.count("completed")
    rejected = statuses.count("rejected")
    lost = len(futs) - completed - rejected
    assert lost == 0, f"{lost} requests lost without a terminal status"
    assert sched.completed == completed
    assert sched.shed + sched.deadline_failures == rejected
    return {"submitted": len(futs), "completed": completed,
            "rejected": rejected, "lost": lost}


def _latencies_ms(futs, pred=lambda f: True):
    return [(f.request.end_s - f.request.arrival_s) * 1e3 for f in futs
            if f.request.status == "completed" and pred(f)]


def _capacity_sweep(seed, num_requests, slo_ms):
    """Find the highest offered load whose goodput-under-SLO stays >= 99%."""
    rows = {}
    max_qps = 0.0
    for frac in (0.5, 0.7, 0.85, 1.0, 1.2):
        qps = frac * CAP_QPS
        rng = np.random.default_rng((seed, int(frac * 100)))
        arrivals = [(t, {"slo_ms": slo_ms})
                    for t in _poisson_trace([(num_requests / qps, qps)], rng)]
        sched, futs, makespan = _simulate(arrivals)
        row = _conserve(sched, futs)
        lat = _latencies_ms(futs)
        ok = sum(1 for f in futs if f.request.status == "completed"
                 and (f.request.end_s - f.request.arrival_s) * 1e3 <= slo_ms)
        row.update({
            "offered_qps": qps,
            "p99_ms": percentile(lat, 99.0) if lat else float("nan"),
            "goodput_slo": ok / len(futs),
        })
        if row["goodput_slo"] >= 0.99:
            max_qps = max(max_qps, qps)
        rows[f"load{int(frac * 100)}"] = row
        emit(f"slo/capacity-{int(frac * 100)}", makespan,
             f"qps={qps:.1f};p99_ms={row['p99_ms']:.1f};"
             f"goodput={row['goodput_slo']:.3f}")
    return rows, max_qps


def _noisy_neighbor(seed, victim_n, slo_ms):
    """Token buckets + the premium tier contain a 1.5x-capacity neighbor:
    the victim's p99 with fairness on stays within 1.2x of its isolated
    run.  This scenario schedules unbatched (max_batch=1) so the POLICY —
    not micro-batch head-of-line granularity — sets the victim's latency;
    the capacity and burst scenarios exercise the batched path."""
    cap_qps = 1.0 / (BATCH_OVERHEAD_S + TOKENS_PER_REQ / CAPACITY_TPS)
    victim_qps = 0.8 * cap_qps
    noisy_qps = 1.5 * cap_qps
    span_s = victim_n / victim_qps
    tenants = [
        # the production tenant: premium tier, latency SLO
        TenantSpec("victim", priority=2, slo_ms=slo_ms),
        # the batch tenant: bucket caps it at half the engine's token rate
        TenantSpec("noisy", rate_tokens_per_s=CAPACITY_TPS / 2,
                   burst_tokens=10 * TOKENS_PER_REQ),
    ]

    def victim_arrivals():
        rng = np.random.default_rng((seed, 1))
        return [(t, {"tenant": "victim", "slo_ms": slo_ms})
                for t in _poisson_trace([(span_s, victim_qps)], rng)]

    def noisy_arrivals():
        rng = np.random.default_rng((seed, 2))
        return [(t, {"tenant": "noisy"})
                for t in _poisson_trace([(span_s, noisy_qps)], rng)]

    # isolated victim -> the reference p99
    sched, futs, _ = _simulate(victim_arrivals(), tenants=tenants,
                               max_batch=1)
    _conserve(sched, futs)
    iso_p99 = percentile(_latencies_ms(futs), 99.0)

    def contested(fairness, slo_shed):
        server = TracingServer()
        vt_probe = VirtualTime()
        tracer = Tracer("slo-noisy", server, clock=vt_probe.clock)
        arrivals = sorted(victim_arrivals() + noisy_arrivals(),
                          key=lambda a: a[0])
        sched, futs, makespan = _simulate(
            arrivals, tenants=tenants, fairness=fairness,
            slo_shed=slo_shed, tracer=tracer, max_batch=1)
        row = _conserve(sched, futs)
        vic = [f for f in futs if f.request.tenant == "victim"]
        row["victim_p99_ms"] = percentile(_latencies_ms(vic), 99.0)
        row["victim_p99_ratio"] = row["victim_p99_ms"] / iso_p99
        row["victim_shed"] = sum(1 for f in vic
                                 if f.request.status == "rejected")
        row["makespan_s"] = makespan
        summary = slo_summary(server.timeline("slo-noisy"))
        row["jain_index"] = summary.get("jain_index", 0.0)
        row["deferred"] = summary.get("deferred", 0.0)
        return row

    fair = contested(fairness=True, slo_shed=True)
    fifo = contested(fairness=False, slo_shed=False)
    assert fair["victim_shed"] == 0, "fair policy shed premium victims"
    assert fair["victim_p99_ratio"] <= 1.2, (
        f"victim p99 {fair['victim_p99_ms']:.1f}ms is "
        f"{fair['victim_p99_ratio']:.2f}x its isolated {iso_p99:.1f}ms"
    )
    emit("slo/noisy-fair", fair["makespan_s"],
         f"victim_p99_ratio={fair['victim_p99_ratio']:.2f};"
         f"jain={fair['jain_index']:.3f}")
    emit("slo/noisy-fifo", fifo["makespan_s"],
         f"victim_p99_ratio={fifo['victim_p99_ratio']:.2f}")
    return {"isolated_p99_ms": iso_p99, "fair": fair, "fifo": fifo}


def _burst(seed, scale_s, slo_ms):
    """3x overload burst over a 30% best-effort / 70% standard mix."""
    phases = [(1.0 * scale_s, 0.8 * CAP_QPS),
              (2.0 * scale_s, 3.0 * CAP_QPS),
              (1.5 * scale_s, 0.8 * CAP_QPS)]
    burst_lo = phases[0][0]
    burst_hi = burst_lo + phases[1][0]
    tenants = [TenantSpec("std", priority=1, slo_ms=slo_ms),
               TenantSpec("be", priority=0, slo_ms=slo_ms)]

    def arrivals():
        rng = np.random.default_rng((seed, 3))
        out = []
        for t in _poisson_trace(phases, rng):
            tenant = "be" if rng.random() < 0.3 else "std"
            out.append((t, {"tenant": tenant, "slo_ms": slo_ms}))
        return out

    def goodput_ratio(futs):
        # in-SLO tokens from burst-window arrivals vs what the engine could
        # possibly serve in that window — the goodput-at-SLO retention
        ok_tokens = sum(
            f.request.cost_tokens for f in futs
            if f.request.status == "completed"
            and burst_lo <= f.request.arrival_s < burst_hi
            and (f.request.end_s - f.request.arrival_s) * 1e3 <= slo_ms
        )
        return ok_tokens / (CAPACITY_TPS * (burst_hi - burst_lo))

    def run_one(fairness, slo_shed):
        sched, futs, makespan = _simulate(
            arrivals(), tenants=tenants, fairness=fairness,
            slo_shed=slo_shed)
        row = _conserve(sched, futs)
        row["goodput_ratio"] = goodput_ratio(futs)
        row["makespan_s"] = makespan
        # priority-aware shedding: best-effort absorbs the overload first
        by_tier = {"std": 0, "be": 0}
        for f in futs:
            if f.request.status == "rejected":
                by_tier[f.request.tenant] += 1
        row["shed_std"] = by_tier["std"]
        row["shed_be"] = by_tier["be"]
        return row

    fair = run_one(fairness=True, slo_shed=True)
    fifo = run_one(fairness=False, slo_shed=False)
    assert fair["goodput_ratio"] >= 0.8, (
        f"goodput through the 3x burst fell to "
        f"{fair['goodput_ratio']:.2f}x of capacity"
    )
    assert fair["goodput_ratio"] > 2 * fifo["goodput_ratio"], (
        "FIFO baseline did not collapse vs SLO-aware scheduling: "
        f"{fifo['goodput_ratio']:.2f} vs {fair['goodput_ratio']:.2f}"
    )
    emit("slo/burst-fair", fair["makespan_s"],
         f"goodput_ratio={fair['goodput_ratio']:.2f};"
         f"shed={fair['rejected']}")
    emit("slo/burst-fifo", fifo["makespan_s"],
         f"goodput_ratio={fifo['goodput_ratio']:.2f}")
    return {"fair": fair, "fifo": fifo}


def run(smoke: bool = False, seed: int = 0) -> dict:
    slo_ms = 150.0
    if smoke:
        cap_n, victim_n, scale_s = 150, 60, 0.5
    else:
        cap_n, victim_n, scale_s = 400, 120, 1.0

    capacity, max_qps = _capacity_sweep(seed, cap_n, slo_ms)
    capacity["max_qps_at_slo"] = max_qps
    assert max_qps > 0, "no offered load met the SLO"
    emit("slo/max-qps", 0.0, f"max_qps_at_slo={max_qps:.1f}")

    noisy = _noisy_neighbor(seed, victim_n, slo_ms=slo_ms)
    burst = _burst(seed, scale_s, slo_ms)

    out = {
        "bench": "slo",
        "smoke": smoke,
        **bench_meta(seed),
        "capacity_tps": CAPACITY_TPS,
        "capacity_qps": CAP_QPS,
        "tokens_per_request": TOKENS_PER_REQ,
        "max_batch": MAX_BATCH,
        "slo_ms": slo_ms,
        "capacity": capacity,
        "noisy": noisy,
        "burst": burst,
    }
    with open("BENCH_slo.json", "w") as f:
        json.dump(out, f, indent=2)
    print("# wrote BENCH_slo.json")
    return out


if __name__ == "__main__":
    bench_main(run, "slo")
