"""Speculative decoding vs plain paged decode (ISSUE 4).

Both serving runs share the SAME paged engine, page budget, packed-prefill
pipeline and admission policy; only the decode loop differs:

* ``spec_k=0``  — one fused decode launch per boundary, one token per slot.
* ``spec_k>=3`` — a host-side prompt-lookup drafter proposes up to k tokens
  per slot (n-gram match against the request's prompt + committed output),
  and ONE paged multi-token verification launch scores every slot's
  ``[next_token, draft_1..draft_k]`` window — the KV working set streams
  once for up to k+1 tokens.  Acceptance is greedy exact-match, so tokens
  are bit-identical to the non-speculative engine (asserted below).

Two workloads bracket the drafter:

* ``lookup``      — repetitive, summarization/extraction-style prompts with
  long continuations (greedy continuations of the reduced model settle into
  repeating phrases, exactly the structure prompt-lookup exploits): high
  acceptance, decode tokens/sec should gain >= 1.3x at spec_k >= 3.
* ``adversarial`` — i.i.d.-random prompts with short continuations: n-grams
  (almost) never match, every boundary falls back to the plain one-token
  step, and the run must stay within 1.05x of the non-spec decode time
  (the drafter's host-side scan is the only overhead).

The benchmark runs at low concurrency (``num_slots=2``) — the latency-bound
regime speculation targets in practice; at large batch the accelerator is
compute-saturated and extra verify FLOPs stop being free.

Emits ``name,us_per_call,derived`` CSV rows plus a ``BENCH_spec.json``
artifact (seed + git rev recorded) uploaded by the CI smoke job; the
deterministic decode-step speedup (greedy acceptance doesn't depend on
timing), the spec decode tokens/sec and the adversarial wall ratio are
gated against ``benchmarks/baselines/BENCH_spec_smoke.json``.  ``--smoke``
keeps the same request mix so baseline and CI numbers are one-to-one
comparable.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.manifest import EngineKnobs
from repro.kernels import ref
from repro.kernels.spec_verify import spec_verify as pallas_spec
from repro.models import build_model
from repro.serve.engine import ServeRequest, ServingEngine

from .common import bench_meta, emit


def _kernel_max_err(rng) -> float:
    """Pallas spec-verify kernel vs the host-loop oracle (interpret, f32):
    ragged window lengths, page-boundary-straddling windows, an idle row."""
    ps, kvh, h, d, P, num_pages = 8, 2, 4, 16, 5, 24
    rows = [(13, 4), (7, 2), (16, 3), (0, 0)]   # (committed, window_len)
    W = 4
    lens = np.array([r[0] for r in rows], np.int32)
    wlens = np.array([r[1] for r in rows], np.int32)
    tables = np.zeros((len(rows), P), np.int32)
    nxt = 1
    for i, (L, wl) in enumerate(rows):
        for j in range((L + wl + ps - 1) // ps):
            tables[i, j] = nxt
            nxt += 1
    args = (
        jnp.asarray(rng.normal(size=(len(rows), W, h, d)), jnp.float32),
        jnp.asarray(rng.normal(size=(num_pages, ps, kvh, d)), jnp.float32),
        jnp.asarray(rng.normal(size=(num_pages, ps, kvh, d)), jnp.float32),
        jnp.asarray(tables), jnp.asarray(lens), jnp.asarray(wlens),
    )
    a = ref.spec_verify(*args)
    b = pallas_spec(*args)
    return float(jnp.max(jnp.abs(a - b)))


def _tiled_prompts(cfg, rng, n, lo, hi):
    """Repetitive prompts: a short phrase tiled — the document-grounded
    structure (summaries, extraction, code edits) that prompt-lookup
    drafting exploits."""
    prompts = []
    for _ in range(n):
        phrase = rng.integers(0, cfg.vocab_size, (rng.integers(3, 6),))
        length = int(rng.integers(lo, hi + 1))
        tiled = np.tile(phrase, length // len(phrase) + 1)[:length]
        prompts.append(tiled.astype(np.int32))
    return prompts


def _predictability(prompt, cont, ngram, k) -> float:
    """Fraction of a greedy continuation the prompt-lookup drafter would
    have produced for free: replay the draft/accept loop against the known
    token stream (greedy tokens are engine-independent, so scoring with the
    dense ``generate`` path transfers exactly to the paged engine)."""
    from repro.serve.engine import ngram_propose

    ctx = list(int(t) for t in prompt) + [int(cont[0])]
    i, accepted = 1, 0
    while i < len(cont):
        d = ngram_propose(np.asarray(ctx, np.int32), ngram, k)
        a = 0
        while a < len(d) and i + a < len(cont) and d[a] == int(cont[i + a]):
            a += 1
        accepted += a
        adv = min(a + 1, len(cont) - i)
        ctx.extend(int(t) for t in cont[i : i + adv])
        i += adv
    return accepted / max(len(cont) - 1, 1)


def _select_prompts(engine, cfg, candidates, gen, ngram, k, n, friendly):
    """Score candidate prompts by drafter-predictability of their greedy
    continuations and keep the ``n`` most (lookup workload) or least
    (adversarial workload) predictable — the two ends of the bracket the
    benchmark gates."""
    scored = []
    bs = engine.max_batch
    for i in range(0, len(candidates), bs):
        group = candidates[i : i + bs]
        res = engine.generate(group, gen)
        for p, cont in zip(group, res.tokens):
            scored.append((_predictability(p, cont, ngram, k), p))
    scored.sort(key=lambda t: t[0], reverse=friendly)
    picked = scored[:n]
    return [p for _, p in picked], float(np.mean([s for s, _ in picked]))


def run(smoke: bool = False, seed: int = 0) -> dict:
    max_seq, page_size, num_slots = 128, 8, 2
    prefill_budget = 64
    spec_k, spec_ngram = 4, 3
    # the full workload already runs in CI time: --smoke keeps the same
    # request mix so the committed baseline and CI numbers are comparable.
    # lookup generations are long enough that the repetitive continuation
    # regime (where drafting pays) dominates the measured decode time
    lookup_requests, lookup_gen = 6, 96
    adv_requests, adv_gen = 20, 12

    cfg = get_config("glm4-9b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(
        model, params, max_batch=num_slots, max_seq=max_seq, page_size=page_size
    )

    rng = np.random.default_rng(seed)
    lookup, lookup_score = _select_prompts(
        engine, cfg, _tiled_prompts(cfg, rng, 3 * lookup_requests, 12, 24),
        lookup_gen, spec_ngram, spec_k, lookup_requests, friendly=True,
    )
    adversarial, adv_score = _select_prompts(
        engine, cfg,
        [rng.integers(0, cfg.vocab_size, (int(n),)).astype(np.int32)
         for n in rng.integers(24, 48, 2 * adv_requests)],
        adv_gen, spec_ngram, spec_k, adv_requests, friendly=False,
    )

    def serve(prompts, gen, k):
        reqs = [
            ServeRequest(request_id=i, prompt=p, max_new_tokens=gen)
            for i, p in enumerate(prompts)
        ]
        return engine.serve_paged(
            reqs, num_slots=num_slots, page_size=page_size,
            prefill_budget=prefill_budget, spec_k=k, spec_ngram=spec_ngram,
        )

    def decode_tps(s, n_req):
        # the prefill launch emits each request's first token; everything
        # else comes out of the decode/verify loop being compared here
        return (s.total_tokens - n_req) / s.decode_s if s.decode_s > 0 else 0.0

    def timed(prompts, gen, repeats=4):
        # INTERLEAVED best-of-N decode times: single-run jitter on shared CI
        # machines is larger than the effect being gated, and a load spike
        # during one mode's timing phase would skew the ratio — alternating
        # base/spec runs exposes both modes to the same conditions
        base = spec = None
        for _ in range(repeats):
            b = serve(prompts, gen, 0)
            s = serve(prompts, gen, spec_k)
            if base is None or b.decode_s < base.decode_s:
                base = b
            if spec is None or s.decode_s < spec.decode_s:
                spec = s
        return base, spec

    results = {}
    for name, prompts, gen in (
        ("lookup", lookup, lookup_gen),
        ("adversarial", adversarial, adv_gen),
    ):
        n_req = len(prompts)
        serve(prompts, gen, 0)            # warm every compile path
        serve(prompts, gen, spec_k)
        base, spec = timed(prompts, gen)
        by_id = {r.request_id: r for r in base.results}
        for r in spec.results:
            assert r.tokens.tolist() == by_id[r.request_id].tokens.tolist(), (
                f"{name}: speculative tokens diverged from the non-spec path"
            )
        ratio = decode_tps(spec, n_req) / max(decode_tps(base, n_req), 1e-12)
        # decode-boundary count is deterministic for a fixed seed (greedy
        # tokens and the acceptance pattern don't depend on timing), so the
        # step speedup is the noise-free CI gate; the wall-clock ratio is
        # reported (and warned on) but swings with shared-machine load
        step_ratio = base.steps / max(spec.steps, 1)
        results[name] = {
            "base": {
                "tokens_per_s": base.throughput_tps,
                "decode_tokens_per_s": decode_tps(base, n_req),
                "decode_s": base.decode_s,
                "decode_steps": base.steps,
                "itl_p99_ms": base.itl_p99_ms,
            },
            "spec": {
                "tokens_per_s": spec.throughput_tps,
                "decode_tokens_per_s": decode_tps(spec, n_req),
                "decode_s": spec.decode_s,
                "decode_steps": spec.steps,
                "itl_p99_ms": spec.itl_p99_ms,
                "acceptance_rate": spec.spec_stats["acceptance_rate"],
                "spec_launches": spec.spec_stats["spec_launches"],
                "fallback_steps": spec.spec_stats["fallback_steps"],
                "rollback_pages": spec.spec_stats["rollback_pages"],
                "compile_stats": spec.compile_stats,
            },
            "decode_speedup": ratio,
            "step_speedup": step_ratio,
        }
        emit(
            f"spec/{name}", spec.decode_s / max(spec.steps, 1),
            f"decode_tok_s={decode_tps(spec, n_req):.1f};"
            f"base_tok_s={decode_tps(base, n_req):.1f};"
            f"accept={spec.spec_stats['acceptance_rate']:.2f};"
            f"steps={spec.steps}v{base.steps};"
            f"itl_p99_ms={spec.itl_p99_ms:.1f};"
            f"speedup={ratio:.2f}x",
        )

    kernel_err = _kernel_max_err(np.random.default_rng(seed + 7))
    emit("spec/kernel_abs_err", kernel_err, "target=1e-3")
    speedup = results["lookup"]["decode_speedup"]
    adv_ratio = results["adversarial"]["decode_speedup"]
    if speedup < 1.3:
        print(f"# WARNING: lookup-workload decode speedup {speedup:.2f}x "
              f"below the 1.3x target")
    if adv_ratio < 1 / 1.05:
        print(f"# WARNING: adversarial decode ratio {adv_ratio:.2f} worse "
              f"than the 1.05x slowdown budget")
    if kernel_err > 1e-3:
        print(f"# WARNING: spec-verify kernel error {kernel_err:.2e} above 1e-3")

    out = {
        "bench": "spec",
        "smoke": smoke,
        **bench_meta(seed, EngineKnobs(engine="paged", page_size=page_size,
                                       spec_k=spec_k)),
        "max_seq": max_seq,
        "page_size": page_size,
        "num_slots": num_slots,
        "prefill_budget": prefill_budget,
        "spec_k": spec_k,
        "spec_ngram": spec_ngram,
        "lookup_requests": lookup_requests,
        "lookup_gen_tokens": lookup_gen,
        "lookup_predictability": lookup_score,
        "adversarial_requests": adv_requests,
        "adversarial_gen_tokens": adv_gen,
        "adversarial_predictability": adv_score,
        **results,
        "kernel_abs_err_f32": kernel_err,
    }
    with open("BENCH_spec.json", "w") as f:
        json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    from .common import bench_main

    bench_main(run, "spec")
