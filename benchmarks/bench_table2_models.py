"""Table 2 analogue: per-model latency/throughput comparison.

The paper evaluates 37 models, reporting trimmed-mean latency, p90 latency,
max throughput, and the optimal batch size per model. We run the platform's
built-in zoo (reduced configs, CPU) through the SAME evaluation workflow:
online scenario (batch 1) for latency, batched scenario sweep for max
throughput — all metrics produced by the platform's analysis layer.
"""
from __future__ import annotations

from repro.core import DispatchPolicy, EvaluationRequest, ScenarioSpec
from repro.core.platform import LocalPlatform

from .common import emit

MODELS = [
    "mamba2-130m",
    "glm4-9b",
    "gemma2-27b",
    "zamba2-2.7b",
    "qwen3-moe-30b-a3b",
    "resnet50",
]


def run() -> None:
    platform = LocalPlatform(backends=("ref",))
    try:
        for model in MODELS:
            req = EvaluationRequest(
                model=model,
                backend="ref",
                scenario=ScenarioSpec(kind="online", num_requests=5, rate_hz=1000.0, warmup=2),
                trace_level="NONE",
                seq_len=32,
            )
            res = platform.evaluate(req)[0]
            m = res["metrics"]
            online_tm = m["trimmed_mean_ms"]
            online_p90 = m["p90_ms"]
            req2 = EvaluationRequest(
                model=model,
                backend="ref",
                scenario=ScenarioSpec(kind="batched", num_requests=3, batch_sizes=[1, 4], warmup=1),
                trace_level="NONE",
                seq_len=32,
            )
            res2 = platform.evaluate(req2)[0]
            m2 = res2["metrics"]
            emit(
                f"table2/{model}",
                online_tm / 1e3,
                f"p90_ms={online_p90:.2f};max_tput_ips={m2['max_throughput_ips']:.2f};"
                f"opt_batch={m2['optimal_batch_size']}",
            )
    finally:
        platform.shutdown()
