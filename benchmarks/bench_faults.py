"""Fault-tolerant fleet serving: kill a worker mid-run, keep the answers.

Three scenarios over one deterministic workload (reduced glm4-9b, greedy
decode) drive the FleetRouter's whole failure model:

* ``baseline`` — 3 fault-free workers; its per-request greedy tokens are
  the bit-identity oracle for the faulted runs.
* ``killone``  — the same workload with worker 1 crashing at its second
  decode boundary (``crash@1:2``).  Every request the dead worker orphaned
  is requeued onto the survivors and replayed from its prompt; greedy
  decoding is deterministic, so every completed request must be
  BIT-IDENTICAL to the baseline, anything else must carry an attributed
  failure, and nothing may be silently lost (completed + failed +
  rejected == submitted).  Goodput retained vs baseline is the headline
  number; the ISSUE floor is (N-1)/N, the CI gate 0.75x baseline.
* ``degrade``  — one worker, 28 requests: demand pressure walks the
  degrade ladder to the shed level and the requests that never fit are
  rejected EXPLICITLY (counted, attributed) instead of queueing forever.
  Sequential dispatch makes the shed count deterministic.

Wall-clock metrics (recovery time, tokens/sec) are recorded for the
trajectory but not gated — the gated metrics are the robustness counters:
zero lost requests, zero token mismatches, zero duplicate commits, the
deterministic requeue count (gated from BOTH directions, so it is an
equality check up to the CI tolerance), and a shed count that stays
deterministic.

Emits ``name,us_per_call,derived`` CSV rows plus ``BENCH_faults.json``
(seed + git rev recorded).  ``--smoke`` keeps the same workload so
baseline and CI numbers compare one-to-one.
"""
from __future__ import annotations

import json

import numpy as np

from .common import bench_meta, emit

NUM_WORKERS = 3
NUM_REQUESTS = 12
PROMPT_LEN, GEN_TOKENS = 16, 6
PAGE_SIZE, NUM_SLOTS, MAX_SEQ = 8, 4, 64
DEGRADE_REQUESTS = 28


def _scenario_row(stats, submitted: int) -> dict:
    terminal = stats.completed + stats.failed + stats.rejected
    return {
        "submitted": submitted,
        "completed": stats.completed,
        "failed": stats.failed,
        "rejected": stats.rejected,
        "lost": submitted - terminal,
        "deaths": stats.deaths,
        "requeued": stats.requeued,
        "duplicate_commits": stats.duplicate_commits,
        "rounds": stats.rounds,
        "goodput": stats.goodput,
        "max_degrade_level": stats.max_degrade_level,
        "tokens_per_s": stats.throughput_tps,
        "wall_s": stats.wall_s,
    }


def run(smoke: bool = False, seed: int = 0) -> dict:
    import jax

    from repro.configs import get_config
    from repro.core.manifest import EngineKnobs
    from repro.models import build_model
    from repro.serve.engine import ServeRequest, ServingEngine
    from repro.serve.faults import FaultPlan
    from repro.serve.fleet import FleetConfig, FleetRouter

    cfg = get_config("glm4-9b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    prompts = [
        rng.integers(0, cfg.vocab_size, (PROMPT_LEN,)).astype(np.int32)
        for _ in range(max(NUM_REQUESTS, DEGRADE_REQUESTS))
    ]

    # workers share weights (read-only under serving); each engine owns its
    # page pool.  The same engines serve every scenario so the jit caches
    # stay warm across them.
    engines = [
        ServingEngine(model, params, max_batch=NUM_SLOTS, max_seq=MAX_SEQ,
                      page_size=PAGE_SIZE)
        for _ in range(NUM_WORKERS)
    ]
    kwargs = dict(num_slots=NUM_SLOTS, page_size=PAGE_SIZE, prefill_budget=32)

    def reqs(n):
        return [
            ServeRequest(request_id=i, prompt=prompts[i],
                         max_new_tokens=GEN_TOKENS)
            for i in range(n)
        ]

    def fleet(workers, plan="", spec_k=0, **cfg_kw):
        return FleetRouter(
            workers,
            FleetConfig(seed=seed, **cfg_kw),
            engine_kwargs={**kwargs, "spec_k": spec_k},
            fault_plan=FaultPlan.parse(plan) if plan else None,
        )

    out = {
        "bench": "faults",
        "smoke": smoke,
        **bench_meta(seed, EngineKnobs(engine="paged", page_size=PAGE_SIZE)),
        "num_workers": NUM_WORKERS,
        "num_requests": NUM_REQUESTS,
        "prompt_len": PROMPT_LEN,
        "gen_tokens": GEN_TOKENS,
        "page_size": PAGE_SIZE,
        "num_slots": NUM_SLOTS,
    }

    # -- baseline: fault-free fleet -> the bit-identity oracle --------------
    base = fleet(engines).serve(reqs(NUM_REQUESTS))
    oracle = {r.request_id: r.tokens for r in base.results
              if r.status == "completed"}
    row = _scenario_row(base, NUM_REQUESTS)
    out["baseline"] = row
    emit("faults/baseline", base.wall_s,
         f"completed={base.completed};lost={row['lost']};"
         f"rounds={base.rounds}")
    assert row["lost"] == 0 and base.completed == NUM_REQUESTS, (
        f"fault-free fleet must complete everything: {row}"
    )

    # -- killone: crash worker 1 mid-run, survivors replay its work --------
    kill = fleet(engines, plan="crash@1:2").serve(reqs(NUM_REQUESTS))
    mismatched = sum(
        1 for r in kill.results
        if r.status == "completed"
        and not np.array_equal(r.tokens, oracle[r.request_id])
    )
    row = _scenario_row(kill, NUM_REQUESTS)
    row["mismatched_tokens"] = mismatched
    row["goodput_retained"] = (
        kill.goodput / base.goodput if base.goodput else 0.0
    )
    row["recovery_max_s"] = max(kill.recovery_s) if kill.recovery_s else 0.0
    out["killone"] = row
    emit("faults/killone", kill.wall_s,
         f"completed={kill.completed};deaths={kill.deaths};"
         f"requeued={kill.requeued};mismatched={mismatched};"
         f"retained={row['goodput_retained']:.2f};"
         f"recovery={row['recovery_max_s'] * 1e3:.0f}ms")
    assert row["lost"] == 0, f"killone lost requests silently: {row}"
    assert mismatched == 0, (
        f"{mismatched} replayed requests diverged from the fault-free run"
    )
    assert kill.deaths == 1 and kill.requeued > 0, (
        f"the injected crash must kill one worker and requeue its work: {row}"
    )
    assert row["goodput_retained"] >= (NUM_WORKERS - 1) / NUM_WORKERS, (
        f"goodput retained {row['goodput_retained']:.2f} below the "
        f"(N-1)/N floor"
    )

    # -- degrade: demand pressure walks the ladder to explicit shed --------
    deg = fleet(engines[:1], spec_k=2).serve(reqs(DEGRADE_REQUESTS))
    row = _scenario_row(deg, DEGRADE_REQUESTS)
    row["shed"] = deg.rejected
    row["degrade_transitions"] = len(deg.degrade_transitions)
    out["degrade"] = row
    emit("faults/degrade", deg.wall_s,
         f"completed={deg.completed};shed={deg.rejected};"
         f"max_level={deg.max_degrade_level};lost={row['lost']}")
    assert row["lost"] == 0, f"degrade lost requests silently: {row}"
    assert deg.rejected > 0 and deg.max_degrade_level == 3, (
        f"sustained overload must reach the shed level and reject "
        f"explicitly: {row}"
    )
    assert deg.completed + deg.rejected == DEGRADE_REQUESTS, (
        f"every request must end completed or explicitly rejected: {row}"
    )

    with open("BENCH_faults.json", "w") as f:
        json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    from .common import bench_main

    bench_main(run, "faults")
