"""Figure 2 analogue: host-side input-staging / dispatch overhead.

The paper shows TF's Python binding costs 64% (CPU) to 3-11x (GPU) over the
C API because Python lists must be unboxed; NumPy costs ~10-15% over C. The
JAX analogues of the same overhead axis:

    python-list input  -> jnp.asarray(list)       (unboxing, the "Python" bar)
    numpy input        -> jnp.asarray(ndarray)    (zero-copy-ish, "NumPy" bar)
    device-resident    -> pre-committed jax.Array (the "C API" bar)
    per-call jit       -> dispatch through jit cache lookup
    AOT compiled call  -> compiled.__call__ (minimum dispatch)

Measured per batch size, like the paper's batch sweep.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model

from .common import emit, time_call


def run() -> None:
    cfg = get_config("glm4-9b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    fwd = jax.jit(lambda p, t: model.forward(p, {"tokens": t})[0])
    seq = 32
    for batch in (1, 8, 32):
        base = np.random.default_rng(0).integers(
            0, cfg.vocab_size, (batch, seq)
        ).astype(np.int32)
        as_list = base.tolist()
        on_device = jax.device_put(jnp.asarray(base))
        compiled = fwd.lower(params, on_device).compile()

        t_list = time_call(lambda: fwd(params, jnp.asarray(as_list, jnp.int32)))
        t_numpy = time_call(lambda: fwd(params, jnp.asarray(base)))
        t_device = time_call(lambda: fwd(params, on_device))
        t_aot = time_call(lambda: compiled(params, on_device))
        emit(f"fig2/python_list/b{batch}", t_list,
             f"vs_aot={t_list / t_aot:.2f}x")
        emit(f"fig2/numpy/b{batch}", t_numpy, f"vs_aot={t_numpy / t_aot:.2f}x")
        emit(f"fig2/device_jit/b{batch}", t_device, f"vs_aot={t_device / t_aot:.2f}x")
        emit(f"fig2/aot_call/b{batch}", t_aot, "baseline=1.00x")
