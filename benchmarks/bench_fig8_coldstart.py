"""Figure 8 analogue: "cold-start" inference inspection.

The paper's case study: one-off AlexNet inference where lazy weight copies
stall the fc6 layer; eager/async copy (the better strategy) hides them. The
JAX cold-start anatomy is weight materialization + first-call compile +
host->device transfer. We trace both strategies through the platform:

    lazy  — weights stay as host numpy; first predict pays the transfer
    eager — weights device_put ahead of time (the Caffe2/TF/TRT strategy)

and report the timeline split (the paper's "zoom-in"), using the tracing
hooks + critical-path analysis.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.analysis import critical_path
from repro.core.tracing import Tracer, TraceLevel, TracingServer
from repro.models import build_model

from .common import emit


def run() -> None:
    cfg = get_config("glm4-9b", reduced=True)
    model = build_model(cfg)
    server = TracingServer()

    def cold_start(eager: bool, trace_id: str) -> float:
        tracer = Tracer(trace_id, server, TraceLevel.FULL)
        fwd = jax.jit(lambda p, t: model.forward(p, {"tokens": t})[0])
        tokens = jnp.zeros((1, 32), jnp.int32)
        t0 = time.perf_counter()
        with tracer.span("cold_start", TraceLevel.MODEL, eager=eager):
            with tracer.span("weight_init", TraceLevel.MODEL):
                host_params = jax.tree.map(
                    np.asarray, jax.block_until_ready(model.init(jax.random.PRNGKey(0)))
                )
            if eager:
                with tracer.span("weight_transfer", TraceLevel.MODEL):
                    params = jax.block_until_ready(
                        jax.tree.map(jax.device_put, host_params)
                    )
            else:
                params = host_params   # transfers happen lazily inside predict
            with tracer.span("first_inference", TraceLevel.MODEL):
                with tracer.span("compile+transfer+run", TraceLevel.FRAMEWORK):
                    jax.block_until_ready(fwd(params, tokens))
            with tracer.span("steady_inference", TraceLevel.MODEL):
                jax.block_until_ready(fwd(params, tokens))
        return time.perf_counter() - t0

    t_lazy = cold_start(False, "cold-lazy")
    t_eager = cold_start(True, "cold-eager")
    for tid, total in (("cold-lazy", t_lazy), ("cold-eager", t_eager)):
        spans = server.timeline(tid)
        path = critical_path(spans)
        parts = {s.name: s.duration for s in spans if s.parent_id is not None}
        first = parts.get("first_inference", 0.0)
        steady = parts.get("steady_inference", 0.0)
        emit(
            f"fig8/{tid}",
            total,
            f"first_ms={first*1e3:.1f};steady_ms={steady*1e3:.1f};"
            f"coldstart_overhead={first / max(steady, 1e-9):.1f}x;"
            f"critical={'>'.join(s.name for s in path)}",
        )
