"""Table 3 analogue: top-5 most time-consuming layers from the trace.

The paper correlates GPU kernels to layers for ResNet-50 @ bs 256 and lists
the top-5 layers by latency. We run a FRAMEWORK-level traced evaluation
through the platform and report its automated top-layers analysis —
same workflow, JAX layers instead of cuDNN kernels.
"""
from __future__ import annotations

from repro.core import EvaluationRequest, ScenarioSpec, Span
from repro.core.analysis import top_layers
from repro.core.platform import LocalPlatform

from .common import emit

ARCH = "gemma2-27b"   # alternating local/global layers show up in the names


def run() -> None:
    platform = LocalPlatform(backends=("ref",))
    try:
        req = EvaluationRequest(
            model=ARCH,
            backend="ref",
            scenario=ScenarioSpec(kind="online", num_requests=2, rate_hz=1000.0, warmup=1),
            trace_level="FRAMEWORK",
            seq_len=32,
        )
        res = platform.evaluate(req)[0]
        spans = [Span.from_dict(d) for d in platform.evaldb.spans(res["eval_id"])]
        for stat in top_layers(spans, k=5):
            emit(
                f"table3/{ARCH}/{stat.name}",
                stat.mean_s,
                f"count={stat.count};total_ms={stat.total_s * 1e3:.2f}",
            )
    finally:
        platform.shutdown()
