"""Figure 6 analogue: throughput speedup over batch-1 across batch sizes.

The paper's heatmap shows per-model throughput scalability. We measure the
zoo (reduced configs) across a batch sweep via the platform's batched
scenario and report the speedup-over-batch-1 matrix (CSV rows per cell).
"""
from __future__ import annotations

from repro.core import EvaluationRequest, ScenarioSpec
from repro.core.analysis import throughput_scalability
from repro.core.platform import LocalPlatform

from .common import emit

MODELS = ["mamba2-130m", "glm4-9b", "zamba2-2.7b", "whisper-large-v3"]
BATCHES = [1, 2, 4, 8]


def run() -> None:
    platform = LocalPlatform(backends=("ref",))
    try:
        for model in MODELS:
            req = EvaluationRequest(
                model=model,
                backend="ref",
                scenario=ScenarioSpec(
                    kind="batched", num_requests=3, batch_sizes=BATCHES, warmup=1
                ),
                trace_level="NONE",
                seq_len=32,
            )
            res = platform.evaluate(req)[0]
            per_batch = {
                int(bs): v["throughput_ips"]
                for bs, v in res["metrics"]["per_batch"].items()
            }
            speedups = throughput_scalability(per_batch)
            for bs in BATCHES:
                emit(
                    f"fig6/{model}/b{bs}",
                    1.0 / max(per_batch[bs], 1e-9),
                    f"speedup_over_b1={speedups[bs]:.2f}x;tput_ips={per_batch[bs]:.2f}",
                )
    finally:
        platform.shutdown()
