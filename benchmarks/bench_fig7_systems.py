"""Figure 7 analogue: one model across "systems".

The paper runs ResNet-50 across 4 GPU/CPU systems. Our "systems" axis is
the (backend × mesh) grid the platform serves: the measured CPU host (ref
and pallas-interpret backends), plus the two production TPU meshes whose
latency bound comes from the dry-run roofline (step-time lower bound =
dominant roofline term) — the cross-system comparison MLModelScope's
registry/dispatch was built for.
"""
from __future__ import annotations

import glob
import json
import os

from repro.core import EvaluationRequest, ScenarioSpec
from repro.core.platform import LocalPlatform

from .common import emit

ARCH = "glm4-9b"


def run() -> None:
    platform = LocalPlatform(backends=("ref", "pallas"))
    try:
        for backend in ("ref", "pallas"):
            req = EvaluationRequest(
                model=ARCH,
                backend=backend,
                scenario=ScenarioSpec(kind="online", num_requests=3, rate_hz=1000.0, warmup=1),
                trace_level="NONE",
                seq_len=32,
            )
            res = platform.evaluate(req)[0]
            emit(
                f"fig7/{ARCH}/cpu-{backend}",
                res["metrics"]["trimmed_mean_ms"] / 1e3,
                "measured=trimmed_mean",
            )
    finally:
        platform.shutdown()
    # dry-run-derived bounds for the TPU meshes
    for mesh in ("16x16", "2x16x16"):
        path = f"results/dryrun/{ARCH}__decode_32k__{'pod' if mesh == '16x16' else 'multipod'}.json"
        if not os.path.exists(path):
            continue
        d = json.load(open(path))
        if d.get("status") != "ok":
            continue
        r = d["roofline"]
        emit(
            f"fig7/{ARCH}/tpu-v5e-{mesh}",
            r["step_time_bound_s"],
            f"bound={r['dominant']};decode_step",
        )
