"""Automatic prefix caching vs cache-off serving at a FIXED page budget.

Three workloads, same paged engine, same pool, same admission policy — only
``prefix_cache`` flips:

* ``shared``      — shared-system-prompt mix: every prompt opens with the
  same long prefix (one group, share ratio 1.0) followed by a short unique
  tail.  After the first wave of misses populates the cache, admissions map
  the prefix pages read-only and prefill only the tail; the commitment
  ledger counts the shared pages once globally, so peak concurrency at the
  fixed budget multiplies and queued requests stop paying the long prefill.
* ``fewshot``     — few-shot-template replay: page-aligned prompts repeated
  verbatim.  Hits are FULL hits — prefill is skipped outright, the last
  prompt token replays through the decode path, and its append splits the
  shared last page copy-on-write (the COW counter must be non-zero).
* ``adversarial`` — fully unique random prompts: zero hit-rate by
  construction; the cache must cost ~nothing (ratios ~1.0).

Acceptance targets (ISSUE 5): on the shared-prefix workload the cache cuts
TTFT p99 by >= 1.5x and lifts peak concurrency by >= 1.3x at the fixed page
budget, with ~1.0x and zero hit-rate on the adversarial workload, and
greedy tokens bit-identical to ``prefix_cache=off`` everywhere.  Emits
``name,us_per_call,derived`` CSV rows plus a ``BENCH_prefix.json`` artifact
(seed + git rev recorded) uploaded by the CI smoke job.  ``--smoke`` keeps
the same workload so baseline and CI numbers compare one-to-one.
"""
from __future__ import annotations

import json

import jax
import numpy as np

from repro.configs import get_config
from repro.core.analysis import percentile
from repro.core.manifest import EngineKnobs
from repro.models import build_model
from repro.serve.engine import ServeRequest, ServingEngine

from .common import bench_meta, emit


def _workloads(vocab: int, seed: int, num_requests: int, prefix_len: int,
               suffix_len: int):
    """Three deterministic prompt sets: shared prefix + unique tails,
    verbatim-repeated page-aligned templates, and fully unique prompts of
    the same total length."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, (prefix_len,)).astype(np.int32)
    shared = [
        np.concatenate(
            [prefix, rng.integers(0, vocab, (suffix_len,)).astype(np.int32)]
        )
        for _ in range(num_requests)
    ]
    fewshot = [prefix.copy() for _ in range(num_requests)]
    adversarial = [
        rng.integers(0, vocab, (prefix_len + suffix_len,)).astype(np.int32)
        for _ in range(num_requests)
    ]
    return {"shared": shared, "fewshot": fewshot, "adversarial": adversarial}


def run(smoke: bool = False, seed: int = 0) -> dict:
    max_seq, page_size, num_slots = 160, 8, 12
    prefix_len, suffix_len, gen_tokens = 64, 9, 6
    num_requests = 16
    # fixed page budget sized so the cache-off engine's worst-case page
    # commitment caps concurrency at ~3 requests: pages_needed(73 + 6) = 10
    # pages per request, 31 usable pages.  The cache-on run pays the shared
    # prefix once (8 pages pinned globally) and each hit commits only its
    # private tail, so many more requests fit the same HBM
    num_pages = 32

    cfg = get_config("glm4-9b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(
        model, params, max_batch=num_slots, max_seq=max_seq, page_size=page_size
    )
    loads = _workloads(cfg.vocab_size, seed, num_requests, prefix_len, suffix_len)

    def serve(prompts, on):
        reqs = [
            ServeRequest(request_id=i, prompt=p, max_new_tokens=gen_tokens)
            for i, p in enumerate(prompts)
        ]
        return engine.serve_paged(
            reqs, num_slots=num_slots, page_size=page_size,
            num_pages=num_pages, prefix_cache=on,
        )

    def ttft(s, pct):
        return percentile([r.ttft_s for r in s.results], pct)

    out = {
        "bench": "prefix",
        "smoke": smoke,
        **bench_meta(seed, EngineKnobs(engine="paged", page_size=page_size,
                                       prefix_cache=True)),
        "max_seq": max_seq,
        "page_size": page_size,
        "num_slots": num_slots,
        "num_pages": num_pages,
        "num_requests": num_requests,
        "prefix_len": prefix_len,
        "suffix_len": suffix_len,
        "gen_tokens": gen_tokens,
    }
    for name, prompts in loads.items():
        serve(prompts, False)            # warm every compile path
        serve(prompts, True)
        # interleaved repeats: wall-clock TTFT is noisy on shared CI
        # runners, so the timing ratio uses the per-mode median of three
        # alternating runs (the structural metrics — concurrency, hit rate,
        # saved tokens — are deterministic and come from the last pair)
        offs, ons = [], []
        for _ in range(3):
            offs.append(serve(prompts, False))
            ons.append(serve(prompts, True))
        off, on = offs[-1], ons[-1]
        by_id = {r.request_id: r for r in off.results}
        for r in on.results:
            assert r.tokens.tolist() == by_id[r.request_id].tokens.tolist(), (
                f"{name}: prefix-cache tokens diverged from the cache-off run"
            )
        assert on.prompt_tokens_admitted == (
            on.saved_prefill_tokens + on.prefill_tokens
            + on.prefill_tokens_dropped
        ), f"{name}: saved-prefill ledger out of balance"
        ttft_ratio = float(
            np.median([ttft(s, 99.0) for s in offs])
            / max(np.median([ttft(s, 99.0) for s in ons]), 1e-12)
        )
        conc_ratio = on.peak_slot_occupancy / max(off.peak_slot_occupancy, 1)
        hit_rate = on.prefix_stats.get("hit_rate", 0.0)
        saved_frac = on.saved_prefill_tokens / max(on.prompt_tokens_admitted, 1)
        out[name] = {
            "off": {
                "ttft_p50_ms": float(np.median([ttft(s, 50.0) for s in offs])) * 1e3,
                "ttft_p99_ms": float(np.median([ttft(s, 99.0) for s in offs])) * 1e3,
                "peak_concurrency": off.peak_slot_occupancy,
                "prefill_tokens": off.prefill_tokens,
                "tokens_per_s": off.throughput_tps,
                "wall_s": off.wall_s,
            },
            "on": {
                "ttft_p50_ms": float(np.median([ttft(s, 50.0) for s in ons])) * 1e3,
                "ttft_p99_ms": float(np.median([ttft(s, 99.0) for s in ons])) * 1e3,
                "peak_concurrency": on.peak_slot_occupancy,
                "prefill_tokens": on.prefill_tokens,
                "saved_prefill_tokens": on.saved_prefill_tokens,
                "cow_copies": on.cow_copies,
                "cache_evictions": on.cache_evictions,
                "tokens_per_s": on.throughput_tps,
                "wall_s": on.wall_s,
                "prefix_stats": on.prefix_stats,
            },
            "ttft_p99_ratio": ttft_ratio,
            "concurrency_ratio": conc_ratio,
            "hit_rate": hit_rate,
            "saved_fraction": saved_frac,
        }
        emit(
            f"prefix/{name}", on.wall_s,
            f"ttft_p99_ratio={ttft_ratio:.2f}x;"
            f"concurrency={off.peak_slot_occupancy}->{on.peak_slot_occupancy};"
            f"hit_rate={hit_rate:.2f};saved_tok={on.saved_prefill_tokens};"
            f"cow={on.cow_copies}",
        )

    assert out["adversarial"]["hit_rate"] == 0.0, (
        "adversarial workload must never hit the cache"
    )
    assert out["fewshot"]["on"]["cow_copies"] > 0, (
        "few-shot full hits must exercise copy-on-write"
    )
    if out["shared"]["ttft_p99_ratio"] < 1.5:
        print(f"# WARNING: shared ttft_p99_ratio "
              f"{out['shared']['ttft_p99_ratio']:.2f}x below the 1.5x target")
    if out["shared"]["concurrency_ratio"] < 1.3:
        print(f"# WARNING: shared concurrency_ratio "
              f"{out['shared']['concurrency_ratio']:.2f}x below the 1.3x target")

    with open("BENCH_prefix.json", "w") as f:
        json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    from .common import bench_main

    bench_main(run, "prefix")
