"""Tensor-parallel paged serving at a FIXED per-shard page budget.

The point of heads-splitting the KV pool: each page holds ``kv/tp`` heads
per shard, so the SAME per-device HBM budget (``pages_per_shard`` pages
here) funds a pool of ``pages_per_shard x tp`` logical pages.  This sweep
serves one deterministic workload at tp in {1, 2, 4} on forced host
devices, scaling ``num_pages`` with the effective tp exactly as a fixed
HBM budget would, and reports

* effective pool capacity (pages, = per-shard budget x tp) and the
  capacity ratio vs tp=1 — deterministic, CI-gated;
* servable peak concurrency at that budget (admission is keyed on free
  pages, so concurrency rises with the pool) and its ratio vs tp=1 —
  deterministic, CI-gated;
* greedy-token bit-identity vs the tp=1 run (1.0/0.0) — CI-gated;
* decode tokens/sec, TTFT p50/p99 and the analytic collective ledger
  (psum bytes moved) — recorded for trajectory, not gated (host-device
  shard_map on one CPU adds orchestration overhead, not speedup).

The model is the reduced glm4-9b with heads widened to 8/4 so tp=4
genuinely splits (the stock reduced config has 2 kv heads and would fall
back to replication).  Needs 8 visible devices: when the current process
booted without ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` the
benchmark re-execs itself in a subprocess with the flag set (jax fixes the
device count at backend init, so an in-process retry can't work).

Emits ``name,us_per_call,derived`` CSV rows plus ``BENCH_tp.json`` (seed +
git rev recorded).  ``--smoke`` keeps the same workload so baseline and CI
numbers compare one-to-one.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys

import numpy as np

from .common import bench_meta, emit

TP_SWEEP = (1, 2, 4)
NEEDED_DEVICES = 8
_CHILD_ENV = "REPRO_BENCH_TP_CHILD"


def _reexec_with_devices(smoke: bool, seed: int) -> dict:
    """Re-run this benchmark in a subprocess with forced host devices."""
    if os.environ.get(_CHILD_ENV):
        raise RuntimeError(
            f"still only saw < {NEEDED_DEVICES} devices after forcing "
            f"host devices; is another XLA_FLAGS value overriding it?"
        )
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={NEEDED_DEVICES} "
        + env.get("XLA_FLAGS", "")
    ).strip()
    env[_CHILD_ENV] = "1"
    env.setdefault("PYTHONPATH", "src")
    cmd = [sys.executable, "-m", "benchmarks.bench_tp", "--seed", str(seed)]
    if smoke:
        cmd.append("--smoke")
    subprocess.run(cmd, env=env, check=True)
    with open("BENCH_tp.json") as f:
        return json.load(f)


def run(smoke: bool = False, seed: int = 0) -> dict:
    import jax

    if jax.device_count() < NEEDED_DEVICES:
        return _reexec_with_devices(smoke, seed)

    from repro.configs import get_config
    from repro.core.analysis import percentile, tp_summary
    from repro.core.manifest import EngineKnobs
    from repro.core.tracing import Tracer, TracingServer
    from repro.launch.mesh import make_host_mesh
    from repro.models import build_model
    from repro.serve.engine import ServeRequest, ServingEngine
    from repro.sharding.specs import serve_rules

    pages_per_shard, page_size, num_slots = 10, 8, 8
    num_requests, prompt_len, gen_tokens = 12, 24, 6
    max_seq = 64

    # widen the reduced config's heads to 8 q / 4 kv so every sweep point
    # genuinely splits (stock reduced glm4-9b has 2 kv heads -> tp=4 would
    # replicate); pages_needed(24 + 6) = 4 pages per request, so the
    # 10-page tp=1 budget caps concurrency at 2 and the sweep has headroom
    cfg = dataclasses.replace(
        get_config("glm4-9b", reduced=True),
        name="glm4-9b-reduced-tp", num_heads=8, num_kv_heads=4,
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    prompts = [
        rng.integers(0, cfg.vocab_size, (prompt_len,)).astype(np.int32)
        for _ in range(num_requests)
    ]

    def serve(tp: int, tracer=None):
        rules = serve_rules(make_host_mesh(tp=tp)) if tp > 1 else None
        engine = ServingEngine(
            model, params, max_batch=num_slots, max_seq=max_seq,
            page_size=page_size, rules=rules,
        )
        # +1: page 0 is reserved scratch, so ALLOCATABLE capacity is exactly
        # pages_per_shard x tp and the capacity ratio lands on whole numbers
        num_pages = pages_per_shard * engine.tp + 1
        reqs = [
            ServeRequest(request_id=i, prompt=p, max_new_tokens=gen_tokens)
            for i, p in enumerate(prompts)
        ]
        engine.serve_paged(                       # warm the compile caches
            reqs[:2], num_slots=2, page_size=page_size, num_pages=num_pages,
        )
        reqs = [
            ServeRequest(request_id=i, prompt=p, max_new_tokens=gen_tokens)
            for i, p in enumerate(prompts)
        ]
        stats = engine.serve_paged(
            reqs, num_slots=num_slots, page_size=page_size,
            num_pages=num_pages, tracer=tracer,
        )
        return stats

    out = {
        "bench": "tp",
        "smoke": smoke,
        **bench_meta(seed, EngineKnobs(engine="paged", page_size=page_size,
                                       tp=TP_SWEEP[-1])),
        "devices": jax.device_count(),
        "pages_per_shard": pages_per_shard,
        "page_size": page_size,
        "num_slots": num_slots,
        "num_requests": num_requests,
        "prompt_len": prompt_len,
        "gen_tokens": gen_tokens,
        "heads": cfg.num_heads,
        "kv_heads": cfg.num_kv_heads,
    }
    base = None
    for tp in TP_SWEEP:
        server = TracingServer()
        tracer = Tracer(f"bench-tp{tp}", server)
        stats = serve(tp, tracer=tracer)
        if base is None:
            base = stats
        by_id = {r.request_id: r for r in base.results}
        identical = all(
            np.array_equal(r.tokens, by_id[r.request_id].tokens)
            for r in stats.results
        )
        ttfts = [r.ttft_s for r in stats.results]
        comm = tp_summary(server.timeline(f"bench-tp{tp}"))
        row = {
            "requested_tp": tp,
            "effective_tp": stats.tp,
            "num_pages": stats.num_pages,
            "capacity_ratio": stats.num_pages / base.num_pages,
            "peak_concurrency": stats.peak_slot_occupancy,
            "concurrency_ratio": (
                stats.peak_slot_occupancy / max(base.peak_slot_occupancy, 1)
            ),
            "tokens_identical": 1.0 if identical else 0.0,
            "decode_tokens_per_s": stats.total_tokens / max(stats.decode_s, 1e-12),
            "tokens_per_s": stats.throughput_tps,
            "ttft_p50_ms": percentile(ttfts, 50.0) * 1e3,
            "ttft_p99_ms": percentile(ttfts, 99.0) * 1e3,
            "wall_s": stats.wall_s,
            "preemptions": stats.preemptions,
            "psum_count": comm.get("psum_count", 0.0),
            "moved_bytes": comm.get("total_moved_bytes", 0.0),
        }
        out[f"tp{tp}"] = row
        emit(
            f"tp/{tp}", stats.wall_s,
            f"eff={stats.tp};pages={stats.num_pages};"
            f"capacity={row['capacity_ratio']:.1f}x;"
            f"peak_conc={stats.peak_slot_occupancy};"
            f"identical={int(identical)};"
            f"ttft_p99={row['ttft_p99_ms']:.1f}ms",
        )
        assert identical, f"tp={tp}: greedy tokens diverged from tp=1"

    for tp in TP_SWEEP[1:]:
        row = out[f"tp{tp}"]
        assert row["capacity_ratio"] == float(tp), (
            f"tp={tp}: pool capacity must scale with the heads split"
        )
        assert row["concurrency_ratio"] > 1.0, (
            f"tp={tp}: bigger pool must admit more concurrent requests"
        )

    with open("BENCH_tp.json", "w") as f:
        json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    from .common import bench_main

    # re-exec'd child: the parent already printed the CSV header
    bench_main(run, "tp", suppress_header_env=_CHILD_ENV)
