"""Quantized KV pages (int8/fp8) vs bf16 at a FIXED byte budget (ISSUE 7).

The paged pool's capacity is bytes, not pages: storing K/V as int8/fp8 with
per-page-per-head scales shrinks a page to ~53% of its bf16 size, so the
SAME HBM budget funds ~1.9x the pages — and admission is keyed on free
pages, so peak concurrency and queueing TTFT follow.  This benchmark serves
identical workloads through three engines that differ ONLY in ``kv_dtype``
(bf16 reference, int8, fp8), each given ``BUDGET_PAGES_BF16`` bf16-pages'
worth of bytes, and reports

* effective pool capacity (allocatable pages in the budget) and the
  capacity ratio vs bf16 — deterministic byte math, CI-gated (>= 1.8x for
  int8 at this config);
* peak admitted concurrency at the budget and its ratio vs bf16 —
  deterministic admission math, CI-gated (>= 1.5x);
* TTFT p50/p99 and decode tokens/sec — recorded for trajectory (timing is
  machine-dependent, not gated);
* token divergence vs the bf16 replay per workload — greedy decoding is
  deterministic per request, so exact-match fraction and first-divergence
  position measure the quantization error and nothing else
  (``analysis.kv_divergence_summary``); deterministic for a fixed seed and
  CI-gated.

Two workloads bracket the accuracy question: ``short`` (random prompts,
short continuations — the capacity/concurrency measurement) and ``long``
(repetitive prompts, long continuations — quantization error compounds
across every decode step reading the quantized pool, the divergence
stress).

The model is the reduced glm4-9b with ``head_dim`` widened to 64 so scale
overhead is realistic (at the stock head_dim=16 the 4-byte-per-row-per-head
scales eat 1/5 of the win; real serving head dims are 64-128).  Emits
``name,us_per_call,derived`` CSV rows plus ``BENCH_kvquant.json`` (seed +
git rev recorded).  ``--smoke`` keeps the same workload so baseline and CI
numbers compare one-to-one.
"""
from __future__ import annotations

import dataclasses
import json

import jax
import numpy as np

from repro.configs import get_config
from repro.core.analysis import kv_divergence_summary, percentile
from repro.core.manifest import EngineKnobs
from repro.kernels import kvquant
from repro.models import build_model
from repro.serve.engine import ServeRequest, ServingEngine

from .common import bench_meta, emit

MODES = ("bfloat16", "int8", "fp8")
BUDGET_PAGES_BF16 = 10


def _tiled_prompts(vocab: int, rng, n: int, length: int):
    """Repetitive prompts whose greedy continuations settle into repeating
    phrases — long continuations re-read the (quantized) KV of their own
    output, compounding the quantization error step over step."""
    prompts = []
    for _ in range(n):
        phrase = rng.integers(0, vocab, (int(rng.integers(3, 6)),))
        prompts.append(np.tile(phrase, length // len(phrase) + 1)[:length].astype(np.int32))
    return prompts


def run(smoke: bool = False, seed: int = 0) -> dict:
    page_size, num_slots, max_seq = 8, 8, 64
    prompt_len = 24
    short_requests, short_gen = 12, 6
    long_requests, long_gen = 8, 24

    # widen the reduced config's head_dim to 64 so the per-row scale
    # overhead (4 B per kv head per pool) is amortized as it is at real
    # serving head dims; heads/layers stay tiny so CI wall time doesn't move
    cfg = dataclasses.replace(
        get_config("glm4-9b", reduced=True),
        name="glm4-9b-reduced-kvq", head_dim=64,
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(seed)
    workloads = {
        "short": (
            [rng.integers(0, cfg.vocab_size, (prompt_len,)).astype(np.int32)
             for _ in range(short_requests)],
            short_gen,
        ),
        "long": (_tiled_prompts(cfg.vocab_size, rng, long_requests, prompt_len),
                 long_gen),
    }

    def page_bytes(mode: str) -> int:
        return kvquant.kv_bytes_per_token(
            cfg.num_layers, cfg.num_kv_heads, cfg.head_dim, mode
        ) * page_size

    # every mode gets the BYTES of BUDGET_PAGES_BF16 bf16 pages; +1 because
    # page 0 is reserved scratch, so ALLOCATABLE capacity is what the
    # budget buys and the capacity ratio is pure byte math
    budget_bytes = BUDGET_PAGES_BF16 * page_bytes("bfloat16")

    def serve(mode: str, prompts, gen: int):
        engine = ServingEngine(
            model, params, max_batch=num_slots, max_seq=max_seq,
            page_size=page_size, kv_dtype=mode,
        )
        num_pages = budget_bytes // page_bytes(mode) + 1
        def reqs():
            return [
                ServeRequest(request_id=i, prompt=p, max_new_tokens=gen)
                for i, p in enumerate(prompts)
            ]
        engine.serve_paged(                       # warm the compile caches
            reqs()[:2], num_slots=2, page_size=page_size, num_pages=num_pages,
        )
        return engine.serve_paged(
            reqs(), num_slots=num_slots, page_size=page_size,
            num_pages=num_pages,
        )

    out = {
        "bench": "kvquant",
        "smoke": smoke,
        **bench_meta(seed, EngineKnobs(engine="paged", kv_dtype="int8",
                                       page_size=page_size)),
        "page_size": page_size,
        "num_slots": num_slots,
        "budget_bytes": budget_bytes,
        "budget_pages_bf16": BUDGET_PAGES_BF16,
        "prompt_len": prompt_len,
        "short_requests": short_requests,
        "short_gen_tokens": short_gen,
        "long_requests": long_requests,
        "long_gen_tokens": long_gen,
        "head_dim": cfg.head_dim,
        "kv_heads": cfg.num_kv_heads,
    }
    ref_tokens = {}
    base_row = None
    for mode in MODES:
        row = {
            "kv_bytes_per_token": float(
                kvquant.kv_bytes_per_token(
                    cfg.num_layers, cfg.num_kv_heads, cfg.head_dim, mode
                )
            ),
            "capacity_pages": float(budget_bytes // page_bytes(mode)),
        }
        for name, (prompts, gen) in workloads.items():
            stats = serve(mode, prompts, gen)
            assert stats.kv_dtype == mode
            assert stats.kv_bytes_per_token == row["kv_bytes_per_token"], (
                f"{mode}: PagedStats byte accounting disagrees with "
                f"kvquant.kv_bytes_per_token"
            )
            tokens = [
                r.tokens.tolist()
                for r in sorted(stats.results, key=lambda r: r.request_id)
            ]
            ttfts = [r.ttft_s for r in stats.results]
            wl = {
                "peak_concurrency": float(stats.peak_slot_occupancy),
                "decode_tokens_per_s": (
                    stats.total_tokens / max(stats.decode_s, 1e-12)
                ),
                "tokens_per_s": stats.throughput_tps,
                "ttft_p50_ms": percentile(ttfts, 50.0) * 1e3,
                "ttft_p99_ms": percentile(ttfts, 99.0) * 1e3,
                "wall_s": stats.wall_s,
                "preemptions": float(stats.preemptions),
            }
            if mode == MODES[0]:
                ref_tokens[name] = tokens
            else:
                div = kv_divergence_summary(ref_tokens[name], tokens)
                wl["divergence"] = div
                wl["concurrency_ratio"] = (
                    wl["peak_concurrency"]
                    / max(base_row[name]["peak_concurrency"], 1.0)
                )
                wl["ttft_p99_ratio"] = (
                    base_row[name]["ttft_p99_ms"] / max(wl["ttft_p99_ms"], 1e-9)
                )
            row[name] = wl
        if mode == MODES[0]:
            base_row = row
        else:
            row["capacity_ratio"] = (
                row["capacity_pages"] / base_row["capacity_pages"]
            )
        out[mode] = row
        for name in workloads:
            wl = row[name]
            derived = (
                f"pages={row['capacity_pages']:.0f};"
                f"peak_conc={wl['peak_concurrency']:.0f};"
                f"ttft_p99={wl['ttft_p99_ms']:.1f}ms"
            )
            if "divergence" in wl:
                d = wl["divergence"]
                derived += (
                    f";exact={d['exact_match_fraction']:.2f}"
                    f";first_div={d.get('first_divergence_min', -1):.0f}"
                )
            emit(f"kvquant/{mode}/{name}", wl["wall_s"], derived)

    # deterministic gates (byte math + admission math, not timing): the
    # headline claim — int8 stretches a fixed byte budget ~2x
    for mode in MODES[1:]:
        assert out[mode]["capacity_ratio"] >= 1.8, (
            f"{mode}: capacity ratio {out[mode]['capacity_ratio']:.2f}x "
            f"below the 1.8x target at a fixed byte budget"
        )
        assert out[mode]["short"]["concurrency_ratio"] >= 1.5, (
            f"{mode}: peak-concurrency ratio "
            f"{out[mode]['short']['concurrency_ratio']:.2f}x below 1.5x"
        )
    for mode in MODES[1:]:
        for name in workloads:
            frac = out[mode][name]["divergence"]["exact_match_fraction"]
            if frac < 0.5:
                print(f"# WARNING: {mode}/{name} exact-match fraction "
                      f"{frac:.2f} — quantized tokens diverge early")

    with open("BENCH_kvquant.json", "w") as f:
        json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    from .common import bench_main

    bench_main(run, "kvquant")
