"""Shared benchmark utilities: timing + CSV emission + the CLI entry point.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (harness
contract) and may emit extra derived columns in the third field.  The
serving benchmarks (``bench_spec``/``bench_prefix``/``bench_tp``/
``bench_kvquant``) share one ``__main__`` shape — ``--smoke``/``--seed``
flags, CSV header, wall-clock footer — provided by :func:`bench_main` so
seed stamping stays consistent across all of them.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import time
from typing import Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


def bench_meta(seed: int, knobs=None) -> Dict[str, object]:
    """Reproducibility block for every ``BENCH_*.json`` artifact: the RNG
    seed the run used plus the git revision it ran at, so perf trajectories
    can be compared run-to-run (and regressions bisected).  ``knobs`` is
    the :class:`repro.core.manifest.EngineKnobs` the benchmark exercised —
    stamped alongside, because engine configuration moves the measured
    numbers as much as the code revision does."""
    try:
        rev = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=5,
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        rev = "unknown"
    meta: Dict[str, object] = {"seed": int(seed), "git_rev": rev}
    if knobs is not None:
        meta["engine_knobs"] = knobs.to_dict()
    return meta


def time_call(fn: Callable[[], object], repeats: int = 5, warmup: int = 2) -> float:
    """Median wall time (seconds) of fn(), blocking on jax values."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def emit(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def emit_header() -> None:
    print("name,us_per_call,derived")


def bench_main(
    run: Callable[..., dict],
    name: str,
    *,
    suppress_header_env: Optional[str] = None,
    argv: Optional[List[str]] = None,
) -> dict:
    """Uniform benchmark CLI: parse ``--smoke``/``--seed``, print the CSV
    header, call ``run(smoke=..., seed=...)`` and footer the wall time.

    Every serving benchmark routes through here so the seed always reaches
    ``bench_meta`` the same way (stamped into the ``BENCH_*.json``
    artifact).  ``suppress_header_env`` names an env var that, when set,
    skips the CSV header — for benchmarks that re-exec themselves in a
    child process (bench_tp) where the parent already printed it.
    """
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke mode (same workload, recorded in JSON)")
    ap.add_argument("--seed", type=int, default=0,
                    help=f"workload RNG seed (recorded in BENCH_{name}.json)")
    args = ap.parse_args(argv)
    if not (suppress_header_env and os.environ.get(suppress_header_env)):
        emit_header()
    t0 = time.perf_counter()
    out = run(smoke=args.smoke, seed=args.seed)
    print(f"# bench_{name} done in {time.perf_counter() - t0:.1f}s")
    return out
