"""Shared benchmark utilities: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (harness
contract) and may emit extra derived columns in the third field.
"""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax
import numpy as np


def time_call(fn: Callable[[], object], repeats: int = 5, warmup: int = 2) -> float:
    """Median wall time (seconds) of fn(), blocking on jax values."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def emit(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def emit_header() -> None:
    print("name,us_per_call,derived")
