"""Shared benchmark utilities: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (harness
contract) and may emit extra derived columns in the third field.
"""
from __future__ import annotations

import subprocess
import time
from typing import Callable, Dict, List, Tuple

import jax
import numpy as np


def bench_meta(seed: int) -> Dict[str, object]:
    """Reproducibility block for every ``BENCH_*.json`` artifact: the RNG
    seed the run used plus the git revision it ran at, so perf trajectories
    can be compared run-to-run (and regressions bisected)."""
    try:
        rev = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=5,
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        rev = "unknown"
    return {"seed": int(seed), "git_rev": rev}


def time_call(fn: Callable[[], object], repeats: int = 5, warmup: int = 2) -> float:
    """Median wall time (seconds) of fn(), blocking on jax values."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def emit(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}")


def emit_header() -> None:
    print("name,us_per_call,derived")
