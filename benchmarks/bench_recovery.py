"""Live KV page migration: O(bytes) failover vs O(tokens) replay.

Five scenarios over one deterministic workload (reduced glm4-9b, greedy
decode) drive the fleet's second recovery path end to end:

* ``baseline``        — 3 fault-free workers WITH periodic checkpointing
  armed: clean runs must never miss a checksum, and checkpointing must not
  perturb tokens (its per-request greedy tokens are the bit-identity
  oracle for every other scenario).
* ``killone_replay``  — worker 1 crashes at its second decode boundary
  (``crash@1:2``) under ``recovery="replay"``: every orphan re-prefills
  from its prompt.  The recompute bill is the orphans' prompt tokens.
* ``killone_migrate`` — the same crash under ``recovery="migrate"`` with
  ``checkpoint_every=1``: every orphan restores its checkpointed KV pages
  on a survivor and continues decoding.  Zero recomputed prefill tokens,
  and the continuation is BIT-IDENTICAL to the undisturbed run — the
  O(bytes) contract.  The headline gate: replay recomputes >= 5x more
  prefill tokens than migrate at equal goodput.
* ``corrupt``         — ``corrupt@1:4`` flips bytes in worker 1's latest
  checkpoint (checksums left stale), then ``crash@1:5`` orphans it before
  the next periodic refresh.  The survivor's import-side verify MUST
  detect the corruption (counted), never serve it, and downgrade that
  request to replay-from-prompt — still bit-identical.
* ``drain_join``      — planned elasticity: worker 1 drains at boundary 2
  (every live slot snapshots fresh and migrates with zero recompute; a
  drain is not a death) while a fourth engine joins mid-serve and picks up
  work.

Wall-clock metrics (restore time, tokens/sec) are recorded for the
trajectory but not gated — the gated metrics are the recovery counters:
zero lost or mismatched tokens everywhere, zero clean-run checksum
failures, the >= 5x recompute ratio, the migrated-token fraction, and
corruption detected exactly (never served).

Emits ``name,us_per_call,derived`` CSV rows plus ``BENCH_recovery.json``
(seed + git rev + recovery knobs recorded).  ``--smoke`` keeps the same
workload so baseline and CI numbers compare one-to-one.
"""
from __future__ import annotations

import json

import numpy as np

from .common import bench_meta, emit

NUM_WORKERS = 3
NUM_REQUESTS = 12
PROMPT_LEN, GEN_TOKENS = 16, 8
PAGE_SIZE, NUM_SLOTS, MAX_SEQ = 8, 4, 64
CKPT_EVERY = 1
# the corrupt scenario needs a cadence GAP between the corruption and the
# crash (a periodic refresh between them would heal the snapshot — which
# is correct behavior, but not what this scenario measures)
CORRUPT_CKPT_EVERY = 3
CORRUPT_PLAN = "corrupt@1:4,crash@1:5"


def _scenario_row(stats, submitted: int) -> dict:
    terminal = stats.completed + stats.failed + stats.rejected
    return {
        "submitted": submitted,
        "completed": stats.completed,
        "failed": stats.failed,
        "rejected": stats.rejected,
        "lost": submitted - terminal,
        "deaths": stats.deaths,
        "drains": stats.drains,
        "joins": stats.joins,
        "requeued": stats.requeued,
        "migrated": stats.migrated,
        "migrated_tokens": stats.migrated_tokens,
        "recomputed_prefill_tokens": stats.recomputed_prefill_tokens,
        "bytes_moved": stats.bytes_moved,
        "checkpoints_saved": stats.checkpoints_saved,
        "checkpoint_bytes": stats.checkpoint_bytes,
        "checksum_failures": stats.checksum_failures,
        "goodput": stats.goodput,
        "tokens_per_s": stats.throughput_tps,
        "wall_s": stats.wall_s,
        "recovery_max_s": max(stats.recovery_s) if stats.recovery_s else 0.0,
    }


def run(smoke: bool = False, seed: int = 0) -> dict:
    import jax

    from repro.configs import get_config
    from repro.core.manifest import EngineKnobs
    from repro.models import build_model
    from repro.serve.engine import ServeRequest, ServingEngine
    from repro.serve.faults import FaultPlan
    from repro.serve.fleet import FleetConfig, FleetRouter

    cfg = get_config("glm4-9b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    prompts = [
        rng.integers(0, cfg.vocab_size, (PROMPT_LEN,)).astype(np.int32)
        for _ in range(NUM_REQUESTS)
    ]

    # one spare engine for the join scenario; workers share weights
    # (read-only under serving), each engine owns its page pool, and the
    # same engines serve every scenario so the jit caches stay warm
    engines = [
        ServingEngine(model, params, max_batch=NUM_SLOTS, max_seq=MAX_SEQ,
                      page_size=PAGE_SIZE)
        for _ in range(NUM_WORKERS + 1)
    ]
    kwargs = dict(num_slots=NUM_SLOTS, page_size=PAGE_SIZE, prefill_budget=32)

    def reqs():
        return [
            ServeRequest(request_id=i, prompt=prompts[i],
                         max_new_tokens=GEN_TOKENS)
            for i in range(NUM_REQUESTS)
        ]

    def fleet(plan="", **cfg_kw):
        return FleetRouter(
            engines[:NUM_WORKERS],
            FleetConfig(seed=seed, **cfg_kw),
            engine_kwargs=dict(kwargs),
            fault_plan=FaultPlan.parse(plan) if plan else None,
        )

    out = {
        "bench": "recovery",
        "smoke": smoke,
        **bench_meta(seed, EngineKnobs(engine="paged", page_size=PAGE_SIZE,
                                       recovery="migrate",
                                       checkpoint_every=CKPT_EVERY)),
        "num_workers": NUM_WORKERS,
        "num_requests": NUM_REQUESTS,
        "prompt_len": PROMPT_LEN,
        "gen_tokens": GEN_TOKENS,
        "page_size": PAGE_SIZE,
        "num_slots": NUM_SLOTS,
        "checkpoint_every": CKPT_EVERY,
    }

    def check_identity(stats, name: str) -> int:
        mismatched = sum(
            1 for r in stats.results
            if r.status == "completed"
            and not np.array_equal(r.tokens, oracle[r.request_id])
        )
        assert mismatched == 0, (
            f"{name}: {mismatched} requests diverged from the fault-free run"
        )
        return mismatched

    # -- baseline: fault-free, checkpointing armed -> the oracle ------------
    base = fleet(recovery="migrate",
                 checkpoint_every=CKPT_EVERY).serve(reqs())
    oracle = {r.request_id: r.tokens for r in base.results
              if r.status == "completed"}
    row = _scenario_row(base, NUM_REQUESTS)
    out["baseline"] = row
    emit("recovery/baseline", base.wall_s,
         f"completed={base.completed};ckpts={base.checkpoints_saved};"
         f"checksum_failures={base.checksum_failures}")
    assert row["lost"] == 0 and base.completed == NUM_REQUESTS, (
        f"fault-free fleet must complete everything: {row}"
    )
    assert base.checksum_failures == 0, (
        f"clean run must never miss a checksum: {row}"
    )
    assert base.checkpoints_saved > 0, (
        f"checkpointing was armed but never fired: {row}"
    )

    # -- killone under replay: the O(prompt-tokens) recompute bill ----------
    rep = fleet(plan="crash@1:2", recovery="replay").serve(reqs())
    row = _scenario_row(rep, NUM_REQUESTS)
    row["mismatched_tokens"] = check_identity(rep, "killone_replay")
    out["killone_replay"] = row
    emit("recovery/killone_replay", rep.wall_s,
         f"completed={rep.completed};deaths={rep.deaths};"
         f"recomputed={rep.recomputed_prefill_tokens};"
         f"migrated={rep.migrated}")
    assert row["lost"] == 0 and rep.deaths == 1, row
    assert rep.migrated == 0 and rep.recomputed_prefill_tokens > 0, (
        f"replay recovery must recompute prompts, not migrate: {row}"
    )

    # -- killone under migrate: the O(bytes) failover -----------------------
    mig = fleet(plan="crash@1:2", recovery="migrate",
                checkpoint_every=CKPT_EVERY).serve(reqs())
    row = _scenario_row(mig, NUM_REQUESTS)
    row["mismatched_tokens"] = check_identity(mig, "killone_migrate")
    total = mig.migrated_tokens + mig.recomputed_prefill_tokens
    row["migrated_token_fraction"] = (
        mig.migrated_tokens / total if total else 0.0
    )
    out["killone_migrate"] = row
    emit("recovery/killone_migrate", mig.wall_s,
         f"completed={mig.completed};migrated={mig.migrated};"
         f"migrated_tokens={mig.migrated_tokens};"
         f"recomputed={mig.recomputed_prefill_tokens};"
         f"bytes_moved={mig.bytes_moved}")
    assert row["lost"] == 0 and mig.deaths == 1, row
    assert mig.migrated > 0 and mig.bytes_moved > 0, (
        f"migrate recovery must restore checkpointed pages: {row}"
    )
    assert mig.checksum_failures == 0, (
        f"clean migration must never miss a checksum: {row}"
    )

    # headline: recompute ratio at equal goodput
    ratio = (rep.recomputed_prefill_tokens
             / max(mig.recomputed_prefill_tokens, 1))
    out["recovery"] = {
        "recompute_ratio": ratio,
        "goodput_vs_replay": (mig.goodput / rep.goodput
                              if rep.goodput else 0.0),
    }
    emit("recovery/ratio", 0.0,
         f"recompute_ratio={ratio:.1f};"
         f"goodput_vs_replay={out['recovery']['goodput_vs_replay']:.2f}")
    assert ratio >= 5.0, (
        f"migrate must recompute >=5x fewer prefill tokens than replay "
        f"(got {ratio:.1f}x)"
    )
    assert out["recovery"]["goodput_vs_replay"] >= 1.0, (
        f"migrate must not trade goodput for the recompute win: {out}"
    )

    # -- corrupt: detected, never served, downgraded to replay --------------
    cor = fleet(plan=CORRUPT_PLAN, recovery="migrate",
                checkpoint_every=CORRUPT_CKPT_EVERY).serve(reqs())
    row = _scenario_row(cor, NUM_REQUESTS)
    row["mismatched_tokens"] = check_identity(cor, "corrupt")
    row["checksum_detected"] = cor.checksum_failures
    out["corrupt"] = row
    emit("recovery/corrupt", cor.wall_s,
         f"completed={cor.completed};detected={cor.checksum_failures};"
         f"migrated={cor.migrated};mismatched={row['mismatched_tokens']}")
    assert row["lost"] == 0, row
    assert cor.checksum_failures >= 1, (
        f"the injected corruption must be DETECTED at restore: {row}"
    )

    # -- drain + join: planned elasticity with zero recompute ---------------
    router = fleet(recovery="migrate")   # checkpoint_every=0: drains only
    router.drain(1, at_step=2)
    router.join(engines[NUM_WORKERS], at_round=1)
    drn = router.serve(reqs())
    row = _scenario_row(drn, NUM_REQUESTS)
    row["mismatched_tokens"] = check_identity(drn, "drain_join")
    out["drain_join"] = row
    emit("recovery/drain_join", drn.wall_s,
         f"completed={drn.completed};drains={drn.drains};joins={drn.joins};"
         f"migrated={drn.migrated};recomputed={drn.recomputed_prefill_tokens}")
    assert row["lost"] == 0 and drn.completed == NUM_REQUESTS, row
    assert drn.drains == 1 and drn.deaths == 0, (
        f"a drain is planned elasticity, not a death: {row}"
    )
    assert drn.joins == 1, f"the joined worker never entered the fleet: {row}"
    assert drn.migrated > 0 and drn.recomputed_prefill_tokens == 0, (
        f"drain must migrate every live slot with zero recompute: {row}"
    )

    with open("BENCH_recovery.json", "w") as f:
        json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    from .common import bench_main

    bench_main(run, "recovery")
