"""Packed varlen prefill vs per-chunk pow2-bucketed prefill (ISSUE 3).

Both serving runs share the SAME paged engine, page budget, admission policy
and decode path; only the prefill pipeline differs:

* ``chunked`` — the PR 2 path: one batch-1 ``prefill_chunk``-token chunk per
  prefilling slot per decode boundary, page-bucketed shapes, one jit variant
  per (chunk length, offset).  Queued ragged prompts serialize behind each
  other and TTFT p99 blows up under bursty arrivals.
* ``packed``  — one token-packed varlen launch per boundary holding chunks
  from MANY requests at once (``prefill_budget`` tokens, no pow2 padding,
  K/V scattered straight into the page pool, ONE compile for the fixed
  packed-buffer size however lengths mix).

Acceptance targets (ISSUE 3): packed prefill sustains >= 1.5x the prefill
tokens/sec of the chunked path on ragged prompts at a fixed page budget,
with materially lower TTFT p99, and greedy tokens bit-identical between the
two modes.  Emits ``name,us_per_call,derived`` CSV rows plus a
``BENCH_prefill.json`` artifact (seed + git rev recorded) uploaded by the CI
smoke job.  ``--smoke`` shrinks everything for CI.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.analysis import percentile
from repro.core.manifest import EngineKnobs
from repro.kernels import ref
from repro.kernels.varlen_prefill import varlen_prefill as pallas_varlen
from repro.models import build_model
from repro.serve.engine import ServeRequest, ServingEngine

from .common import bench_meta, emit


def _kernel_max_err(rng) -> float:
    """Pallas packed-varlen kernel vs the host-loop oracle (interpret, f32):
    ragged chunks, committed context pages, a buffer-tail pad."""
    ps, kvh, h, d, P, num_pages = 8, 2, 4, 16, 4, 12
    chunks = [(5, 0), (11, 2), (3, 1)]          # (real_len, ctx_pages)
    T = 40                                       # spans sum to 32 + tail pad
    cu, lens, pos0 = [0], [], []
    tables = np.zeros((len(chunks), P), np.int32)
    nxt = 1
    for c, (n, cp) in enumerate(chunks):
        cu.append(cu[-1] + (n + ps - 1) // ps * ps)
        lens.append(n)
        pos0.append(cp * ps)
        for j in range(cp):
            tables[c, j] = nxt
            nxt += 1
    args = tuple(
        jnp.asarray(x)
        for x in (
            rng.normal(size=(T, h, d)).astype(np.float32),
            rng.normal(size=(T, kvh, d)).astype(np.float32),
            rng.normal(size=(T, kvh, d)).astype(np.float32),
            rng.normal(size=(num_pages, ps, kvh, d)).astype(np.float32),
            rng.normal(size=(num_pages, ps, kvh, d)).astype(np.float32),
            np.array(cu, np.int32),
            np.array(lens, np.int32),
            np.array(pos0, np.int32),
            tables,
        )
    )
    a = ref.varlen_prefill(*args)
    b = pallas_varlen(*args)
    return float(jnp.max(jnp.abs(a - b)))


def run(smoke: bool = False, seed: int = 0) -> dict:
    max_seq, page_size, num_slots = 192, 8, 8
    prefill_chunk, prefill_budget = 16, 128
    prompt_lo, prompt_hi = 40, 96
    gen_tokens = 4                       # short decode: prefill-bound regime
    # the full workload already runs in CI time (~20 s): --smoke keeps the
    # same request mix so the committed baseline and CI numbers are
    # one-to-one comparable (the flag is still recorded in the artifact)
    num_requests = 16
    # fixed page budget shared by both modes (worst case fits: no preemption
    # noise in the comparison)
    num_pages = num_slots * ((max_seq + page_size - 1) // page_size) + 1

    cfg = get_config("glm4-9b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(
        model, params, max_batch=num_slots, max_seq=max_seq, page_size=page_size
    )

    rng = np.random.default_rng(seed)
    prompt_lens = rng.integers(prompt_lo, prompt_hi + 1, num_requests)
    prompts = [
        rng.integers(0, cfg.vocab_size, (int(n),)).astype(np.int32)
        for n in prompt_lens
    ]
    reqs = lambda: [
        ServeRequest(request_id=i, prompt=p, max_new_tokens=gen_tokens)
        for i, p in enumerate(prompts)
    ]
    total_prompt_tokens = int(prompt_lens.sum())

    def serve(mode):
        return engine.serve_paged(
            reqs(), num_slots=num_slots, page_size=page_size,
            num_pages=num_pages, prefill_chunk=prefill_chunk,
            prefill_mode=mode, prefill_budget=prefill_budget,
        )

    # warm every compile path the timed runs will hit
    serve("chunked")
    serve("packed")
    chunked = serve("chunked")
    packed = serve("packed")

    by_id = {r.request_id: r for r in chunked.results}
    for r in packed.results:
        assert r.tokens.tolist() == by_id[r.request_id].tokens.tolist(), (
            "packed prefill tokens diverged from the chunked path"
        )

    def prefill_tps(s):
        return s.prefill_tokens / s.prefill_s if s.prefill_s > 0 else float("inf")

    def ttft_p99(s):
        return percentile([r.ttft_s for r in s.results], 99.0)

    speedup = prefill_tps(packed) / prefill_tps(chunked)
    ttft_ratio = ttft_p99(chunked) / max(ttft_p99(packed), 1e-12)
    kernel_err = _kernel_max_err(np.random.default_rng(seed + 7))

    emit("prefill/chunked", chunked.prefill_s / max(chunked.prefill_launches, 1),
         f"prefill_tok_s={prefill_tps(chunked):.1f};"
         f"launches={chunked.prefill_launches};"
         f"ttft_p99_ms={ttft_p99(chunked)*1e3:.1f};"
         f"compiles={sum(chunked.compile_stats.values())};speedup=1.00x")
    emit("prefill/packed", packed.prefill_s / max(packed.prefill_launches, 1),
         f"prefill_tok_s={prefill_tps(packed):.1f};"
         f"launches={packed.prefill_launches};"
         f"ttft_p99_ms={ttft_p99(packed)*1e3:.1f};"
         f"budget={packed.prefill_budget};"
         f"buffer_util={packed.prefill_tokens / max(packed.prefill_tokens + packed.prefill_padded_tokens, 1):.2f};"
         f"compiles={sum(packed.compile_stats.values())};speedup={speedup:.2f}x")
    emit("prefill/kernel_abs_err", kernel_err, "target=1e-3")
    if speedup < 1.5:
        print(f"# WARNING: packed prefill speedup {speedup:.2f}x below the "
              f"1.5x target")
    if kernel_err > 1e-3:
        print(f"# WARNING: varlen kernel error {kernel_err:.2e} above 1e-3")

    def block(s):
        return {
            "tokens_per_s": s.throughput_tps,
            "wall_s": s.wall_s,
            "prefill_s": s.prefill_s,
            "prefill_tokens": s.prefill_tokens,
            "prefill_padded_tokens": s.prefill_padded_tokens,
            "prefill_tokens_per_s": prefill_tps(s),
            "prefill_launches": s.prefill_launches,
            "prefill_chunks": s.prefill_chunks,
            "ttft_p99_ms": ttft_p99(s) * 1e3,
            "ttft_mean_ms": float(np.mean([r.ttft_s for r in s.results]) * 1e3),
            "compile_stats": s.compile_stats,
        }

    out = {
        "bench": "prefill",
        "smoke": smoke,
        **bench_meta(seed, EngineKnobs(engine="paged", page_size=page_size)),
        "max_seq": max_seq,
        "page_size": page_size,
        "num_slots": num_slots,
        "num_pages": num_pages,
        "prefill_chunk": prefill_chunk,
        "prefill_budget": packed.prefill_budget,
        "num_requests": num_requests,
        "prompt_tokens": total_prompt_tokens,
        "chunked": block(chunked),
        "packed": block(packed),
        "prefill_speedup": speedup,
        "ttft_p99_ratio": ttft_ratio,
        "kernel_abs_err_f32": kernel_err,
    }
    with open("BENCH_prefill.json", "w") as f:
        json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    from .common import emit_header

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (interpret-mode kernels, CPU)")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload RNG seed (recorded in BENCH_prefill.json)")
    args = ap.parse_args()
    emit_header()
    t0 = time.perf_counter()
    run(smoke=args.smoke, seed=args.seed)
    print(f"# bench_prefill done in {time.perf_counter() - t0:.1f}s")
