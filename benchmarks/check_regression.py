"""Compare a fresh ``BENCH_*.json`` against a committed baseline.

The CI bench-smoke job runs the serving benchmarks and fails the build when
a headline throughput metric regresses more than ``--max-regression``
(default 25%) against the baseline committed under
``benchmarks/baselines/`` — the perf trajectory is enforced, not just
recorded.

``--metric`` names higher-is-better metrics (throughput, speedup ratios);
``--metric-lower`` names lower-is-better ones (divergence fractions,
latency) that fail when they RISE past ``1 + max_regression`` times the
baseline.  A lower-is-better baseline of exactly 0 is a hard gate: the
current value must stay 0 (e.g. "tokens never diverge" stays enforced).

    python -m benchmarks.check_regression BENCH_paged.json \
        benchmarks/baselines/BENCH_paged_smoke.json \
        --metric paged.tokens_per_s --max-regression 0.25

Baselines are refreshed by re-running the benchmark with ``--smoke`` on the
reference machine and committing the JSON (the recorded ``seed`` +
``git_rev`` say exactly what produced them).
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def lookup(doc: dict, dotted: str):
    cur = doc
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            raise KeyError(f"metric {dotted!r} not found (missing {part!r})")
        cur = cur[part]
    return float(cur)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="freshly emitted BENCH_*.json")
    ap.add_argument("baseline", help="committed baseline BENCH_*.json")
    ap.add_argument("--metric", action="append", default=[],
                    help="dotted path of a higher-is-better metric "
                         "(repeatable), e.g. paged.tokens_per_s")
    ap.add_argument("--metric-lower", action="append", default=[],
                    help="dotted path of a LOWER-is-better metric "
                         "(repeatable), e.g. int8.divergence_fraction; "
                         "fails when it rises past (1 + max-regression) x "
                         "baseline (baseline 0 must stay 0)")
    ap.add_argument("--max-regression", type=float,
                    default=float(os.environ.get("BENCH_MAX_REGRESSION", 0.25)),
                    help="allowed fractional drop vs baseline (default 0.25)")
    args = ap.parse_args(argv)
    if not args.metric and not args.metric_lower:
        ap.error("at least one --metric or --metric-lower is required")

    with open(args.current) as f:
        cur = json.load(f)
    with open(args.baseline) as f:
        base = json.load(f)
    failed = False

    def pair(metric: str):
        """Resolve ``metric`` in both documents; a missing path is a named
        failure (which file, which metric), never a KeyError traceback —
        a renamed bench field must fail CI legibly."""
        out = []
        for name, doc in (("current", args.current), ("baseline", args.baseline)):
            src = cur if name == "current" else base
            try:
                out.append(lookup(src, metric))
            except KeyError as e:
                print(f"[bench-check] MISSING METRIC: {metric!r} not in "
                      f"{name} file {doc}: {e.args[0]}")
                return None
        return out

    for metric in args.metric:
        got = pair(metric)
        if got is None:
            failed = True
            continue
        c, b = got
        if b <= 0:
            print(f"[bench-check] {metric}: baseline {b} <= 0, skipping")
            continue
        ratio = c / b
        status = "OK"
        if ratio < 1.0 - args.max_regression:
            status = "REGRESSION"
            failed = True
        print(f"[bench-check] {metric}: current={c:.2f} baseline={b:.2f} "
              f"ratio={ratio:.2f} (floor {1.0 - args.max_regression:.2f}) "
              f"[{status}]")
    for metric in args.metric_lower:
        got = pair(metric)
        if got is None:
            failed = True
            continue
        c, b = got
        if b < 0:
            print(f"[bench-check] {metric}: baseline {b} < 0, skipping")
            continue
        if b == 0:
            # the baseline says this never happens — keep it that way
            status = "OK" if c == 0 else "REGRESSION"
            failed |= c != 0
            print(f"[bench-check] {metric}: current={c:.2f} baseline=0.00 "
                  f"(must stay 0) [{status}]")
            continue
        ratio = c / b
        status = "OK"
        if ratio > 1.0 + args.max_regression:
            status = "REGRESSION"
            failed = True
        print(f"[bench-check] {metric}: current={c:.2f} baseline={b:.2f} "
              f"ratio={ratio:.2f} (ceiling {1.0 + args.max_regression:.2f}, "
              f"lower is better) [{status}]")
    if failed:
        print(f"[bench-check] FAILED: regression beyond "
              f"{args.max_regression:.0%} (or missing metric) vs {args.baseline} "
              f"(baseline rev {base.get('git_rev', '?')}, "
              f"seed {base.get('seed', '?')})")
        return 1
    print("[bench-check] all metrics within budget")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
