"""End-to-end platform behaviour tests (the paper's three workflows)."""
import numpy as np
import pytest

from repro.core import (
    DispatchError,
    DispatchPolicy,
    EvaluationRequest,
    ScenarioSpec,
    SystemRequirements,
)
from repro.core.platform import LocalPlatform, builtin_manifests


@pytest.fixture(scope="module")
def platform():
    p = LocalPlatform(backends=("ref",))
    yield p
    p.shutdown()


def test_initialization_workflow_registers_models_and_agents(platform):
    models = platform.registry.manifests()
    names = {m.name for m in models}
    assert "glm4-9b" in names and "resnet50" in names
    assert len(models) >= 11
    agents = platform.registry.agents()
    assert len(agents) == 1
    assert agents[0].backend == "ref"
    assert "glm4-9b:1.0.0" in agents[0].models


def test_evaluation_workflow_end_to_end(platform):
    req = EvaluationRequest(
        model="glm4-9b",
        backend="ref",
        scenario=ScenarioSpec(kind="online", num_requests=3, rate_hz=1000.0, warmup=1),
        trace_level="MODEL",
        seq_len=16,
    )
    results = platform.evaluate(req)
    assert len(results) == 1
    metrics = results[0]["metrics"]
    assert metrics["trimmed_mean_ms"] > 0
    assert metrics["p90_ms"] >= metrics["min_ms"]
    # result landed in the evaluation database
    recs = platform.evaldb.query(model="glm4-9b")
    assert recs and recs[-1].metrics["trimmed_mean_ms"] > 0
    # trace landed too
    spans = platform.evaldb.spans(recs[-1].eval_id)
    names = {s["name"] for s in spans}
    assert "evaluation" in names and "model_load" in names


def test_batched_scenario_reports_optimal_batch(platform):
    req = EvaluationRequest(
        model="mamba2-130m",
        backend="ref",
        scenario=ScenarioSpec(kind="batched", num_requests=2, batch_sizes=[1, 2], warmup=1),
        trace_level="NONE",
        seq_len=16,
    )
    res = platform.evaluate(req)[0]
    m = res["metrics"]
    assert m["optimal_batch_size"] in (1, 2)
    assert m["max_throughput_ips"] > 0


def test_scheduler_backed_offline_evaluation(platform):
    """SchedulerConfig threads client -> server dispatch -> agent -> scenario,
    and the queue/occupancy series land in the trace + report."""
    from repro.core import SchedulerConfig, scheduler_summary
    from repro.core.tracing import Span

    req = EvaluationRequest(
        model="mamba2-130m",
        backend="ref",
        scenario=ScenarioSpec(kind="offline", num_requests=8, warmup=1),
        trace_level="MODEL",
        seq_len=16,
    )
    # round-trips through the wire format like a subprocess agent would see
    wire = EvaluationRequest.from_dict(req.to_dict())
    assert wire.scheduler is None
    res = platform.evaluate(
        req, scheduler=SchedulerConfig(max_batch=4, batch_timeout_ms=0.0)
    )[0]
    m = res["metrics"]
    assert m["scenario"] == "offline"
    assert m["throughput_ips"] > 0
    assert m["sched_mean_batch_occupancy"] == pytest.approx(4.0)
    assert req.scheduler is not None  # threaded onto the request by dispatch
    assert EvaluationRequest.from_dict(req.to_dict()).scheduler.max_batch == 4
    recs = platform.evaldb.query(model="mamba2-130m", scenario="offline")
    spans = [Span.from_dict(d) for d in platform.evaldb.spans(recs[-1].eval_id)]
    summary = scheduler_summary(spans)
    assert summary["batches"] == 2.0
    assert summary["total_inputs"] == 8.0
    report = platform.report(model="mamba2-130m", scenario="offline")
    assert "Scheduler (queueing + micro-batching)" in report


def test_server_scenario_evaluation(platform):
    req = EvaluationRequest(
        model="mamba2-130m",
        backend="ref",
        scenario=ScenarioSpec(
            kind="server", num_requests=4, rate_hz=200.0, warmup=1, slo_ms=10_000.0
        ),
        trace_level="NONE",
        seq_len=16,
    )
    m = platform.evaluate(req)[0]["metrics"]
    assert m["scenario"] == "server"
    assert m["achieved_qps"] > 0
    assert 0.0 <= m["slo_attainment"] <= 1.0


def test_analysis_workflow_report(platform):
    report = platform.report(model="glm4-9b")
    assert "MLModelScope report" in report
    assert "glm4-9b" in report


def test_dispatch_error_for_unknown_model(platform):
    req = EvaluationRequest(model="nonexistent-model")
    with pytest.raises(DispatchError):
        platform.evaluate(req)


def test_system_requirements_filtering(platform):
    req = EvaluationRequest(
        model="glm4-9b",
        scenario=ScenarioSpec(kind="online", num_requests=1, rate_hz=1000.0, warmup=0),
        trace_level="NONE",
        seq_len=8,
    )
    with pytest.raises(DispatchError):
        platform.evaluate(req, requirements=SystemRequirements(platform="tpu"))


def test_agent_failure_failover():
    p = LocalPlatform(backends=("ref", "ref"))
    try:
        for agent in p.agents.values():
            agent.fail_next = 1
            break
        req = EvaluationRequest(
            model="mamba2-130m",
            scenario=ScenarioSpec(kind="online", num_requests=1, rate_hz=1000.0, warmup=0),
            trace_level="NONE",
            seq_len=8,
        )
        res = p.evaluate(req, policy=DispatchPolicy(max_attempts=3))
        assert res and res[0]["metrics"]["trimmed_mean_ms"] > 0
    finally:
        p.shutdown()


def test_lease_expiry_counts_as_node_failure():
    p = LocalPlatform(backends=("ref",))
    try:
        agent = next(iter(p.agents.values()))
        p.registry.deregister_agent(agent.agent_id)
        req = EvaluationRequest(
            model="mamba2-130m",
            scenario=ScenarioSpec(kind="online", num_requests=1, rate_hz=1000.0, warmup=0),
            trace_level="NONE",
            seq_len=8,
        )
        with pytest.raises(DispatchError):
            p.evaluate(req)
    finally:
        p.shutdown()


def test_all_agents_fanout():
    p = LocalPlatform(backends=("ref", "ref"))
    try:
        req = EvaluationRequest(
            model="mamba2-130m",
            scenario=ScenarioSpec(kind="online", num_requests=1, rate_hz=1000.0, warmup=0),
            trace_level="NONE",
            seq_len=8,
        )
        res = p.evaluate(req, policy=DispatchPolicy(all_agents=True))
        assert len(res) == 2
        assert len({r["agent_id"] for r in res}) == 2
    finally:
        p.shutdown()


def test_framework_level_tracing_produces_layer_spans():
    p = LocalPlatform(backends=("ref",))
    try:
        req = EvaluationRequest(
            model="mamba2-130m",
            scenario=ScenarioSpec(kind="online", num_requests=1, rate_hz=1000.0, warmup=0),
            trace_level="FRAMEWORK",
            seq_len=8,
        )
        res = p.evaluate(req)[0]
        spans = p.evaldb.spans(res["eval_id"])
        layer_spans = [s for s in spans if s["name"].startswith("layer_")]
        assert len(layer_spans) >= 3  # one per reduced layer
    finally:
        p.shutdown()
