"""SLO-aware multi-tenant scheduling: token buckets, fair dequeue,
priority tiers, SLO shedding, and the goodput analysis pipeline.

Unit layer drives RequestScheduler/TokenBucket/TenantLedger over a virtual
clock (deterministic discrete-event simulations); the property tests for
``backoff_delay`` run under hypothesis (or the offline stub in conftest).
"""
import random
import threading

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.analysis import jain_index, slo_summary
from repro.core.tracing import Tracer, TracingServer
from repro.core.workload import BurstyLoad, DiurnalLoad, MultiTenantLoad
from repro.serve.scheduler import (
    PRIORITY_TIERS,
    DeadlineExceeded,
    RequestScheduler,
    SchedulerConfig,
    TenantLedger,
    TenantSpec,
    TokenBucket,
    backoff_delay,
)


class VirtualTime:
    def __init__(self):
        self.t = 0.0
        self._lock = threading.Lock()

    def clock(self):
        with self._lock:
            return self.t

    def sleep(self, dt):
        with self._lock:
            self.t += dt


# ---------------------------------------------------------------------------
# backoff_delay properties
# ---------------------------------------------------------------------------
@given(
    st.integers(min_value=1, max_value=40),
    st.floats(min_value=1e-4, max_value=1.0),
    st.floats(min_value=1e-3, max_value=10.0),
)
@settings(max_examples=40)
def test_backoff_delay_monotone_and_capped(attempt, base, cap):
    a = backoff_delay(attempt, base, cap)
    b = backoff_delay(attempt + 1, base, cap)
    assert 0.0 <= a <= b          # non-decreasing in attempt
    assert a <= cap + 1e-12       # hard cap respected
    assert b <= cap + 1e-12


@given(
    st.integers(min_value=1, max_value=12),
    st.floats(min_value=0.0, max_value=1.0),
    st.integers(min_value=0, max_value=2**16),
)
@settings(max_examples=40)
def test_backoff_delay_jitter_bounded_and_deterministic(attempt, jitter, seed):
    base, cap = 0.01, 0.5
    nojit = backoff_delay(attempt, base, cap)
    d1 = backoff_delay(attempt, base, cap, jitter=jitter,
                       rng=random.Random(seed))
    d2 = backoff_delay(attempt, base, cap, jitter=jitter,
                       rng=random.Random(seed))
    assert d1 == d2                                    # seeded determinism
    assert abs(d1 - nojit) <= jitter * nojit + 1e-12   # bounded jitter
    assert d1 >= 0.0


def test_backoff_delay_expectation_monotone_under_jitter():
    # jitter is symmetric, so the EXPECTED delay must still be monotone
    # non-decreasing in the attempt number (cap high enough not to bind)
    base, cap, jitter = 0.01, 100.0, 0.5
    means = []
    for attempt in range(1, 8):
        rng = random.Random(123)
        xs = [backoff_delay(attempt, base, cap, jitter=jitter, rng=rng)
              for _ in range(500)]
        means.append(sum(xs) / len(xs))
    assert all(b >= a for a, b in zip(means, means[1:]))


# ---------------------------------------------------------------------------
# TokenBucket / TenantLedger
# ---------------------------------------------------------------------------
def test_token_bucket_refill_clamp_and_dry():
    with pytest.raises(ValueError):
        TokenBucket(0.0, 10.0)
    with pytest.raises(ValueError):
        TokenBucket(10.0, 0.0)
    b = TokenBucket(rate_per_s=100.0, burst=50.0)
    assert b.available(0.0) == 50.0
    b.charge(80.0, 0.0)                       # clamps at zero: no debt
    assert b.tokens == 0.0
    assert b.charged_total == 80.0
    assert b.dry(1.0, 0.0)
    assert b.available(0.25) == pytest.approx(25.0)
    assert not b.dry(20.0, 0.25)
    assert b.available(10.0) == 50.0          # refill capped at burst
    assert b.time_until(40.0, 10.0) == 0.0
    b.charge(50.0, 10.0)
    assert b.time_until(40.0, 10.0) == pytest.approx(0.4)
    # a cost above burst is satisfiable once the bucket is full again
    assert b.time_until(500.0, 10.0) == pytest.approx(0.5)


def test_tenant_ledger_weighted_vtime_and_stats():
    led = TenantLedger([
        TenantSpec("heavy", weight=2.0),
        TenantSpec("light"),
        TenantSpec("limited", rate_tokens_per_s=100.0),
    ])
    led.on_admit("heavy", 100.0, 0.0)
    led.on_admit("light", 100.0, 0.0)
    # vtime advances by cost/weight — double weight, half the advance
    assert led.vtime["heavy"] == pytest.approx(50.0)
    assert led.vtime["light"] == pytest.approx(100.0)
    # burst defaults to one second of refill when only a rate is given
    assert led.buckets["limited"].burst == pytest.approx(100.0)
    assert not led.dry("light", 1e9, 0.0)     # no bucket -> never dry
    led.note_shed("light")
    led.note_defer("limited")
    st_ = led.stats()
    assert st_["heavy"]["tokens_admitted"] == 100.0
    assert st_["light"]["shed"] == 1
    assert st_["limited"]["deferred"] == 1
    # unknown tenants auto-register with defaults
    spec = led.spec_of("walkin")
    assert spec.priority == 1 and spec.weight == 1.0


def test_tenant_spec_validation_and_tiers():
    assert TenantSpec("t", priority=0).tier == "best_effort"
    assert TenantSpec("t", priority=2).tier == "premium"
    assert PRIORITY_TIERS == ("best_effort", "standard", "premium")
    with pytest.raises(ValueError):
        TenantSpec("")
    with pytest.raises(ValueError):
        TenantSpec("t", weight=0.0)
    with pytest.raises(ValueError):
        TenantSpec("t", priority=-1)
    d = TenantSpec("t", priority=2, weight=3.0, rate_tokens_per_s=10.0,
                   burst_tokens=5.0, slo_ms=100.0).to_dict()
    assert TenantSpec.from_dict(d) == TenantSpec.from_dict({**d, "junk": 1})


# ---------------------------------------------------------------------------
# RequestScheduler: fairness policy
# ---------------------------------------------------------------------------
def _sched(vt, execute, tenants=(), **cfg_kw):
    cfg = SchedulerConfig(batch_timeout_ms=0.0, **cfg_kw)
    return RequestScheduler(execute, cfg, clock=vt.clock, sleep=vt.sleep,
                            tenants=tenants)


def test_untagged_requests_degenerate_to_exact_fifo():
    orders = {}
    for fairness in (True, False):
        vt = VirtualTime()
        served = []
        sched = _sched(vt, lambda b: served.extend(r.request_id for r in b),
                       max_batch=2, fairness=fairness)
        for i in range(8):
            sched.submit(arrival_s=0.01 * i)
        sched.run_until_idle()
        orders[fairness] = list(served)
    # fairness on with default tenant/priority is byte-identical to FIFO
    assert orders[True] == orders[False] == list(range(8))


def test_priority_tiers_dequeue_premium_first():
    vt = VirtualTime()
    served = []
    sched = _sched(vt, lambda b: served.extend(r.request_id for r in b),
                   max_batch=1,
                   tenants=[TenantSpec("be", priority=0),
                            TenantSpec("std", priority=1),
                            TenantSpec("prem", priority=2)])
    for tenant in ("be", "be", "std", "prem", "std", "prem"):
        sched.submit(arrival_s=0.0, tenant=tenant)
    sched.run_until_idle()
    # ids by tenant: be=0,1  std=2,4  prem=3,5
    assert served[:2] == [3, 5]            # premium drains first
    assert set(served[2:4]) == {2, 4}      # then standard
    assert served[4:] == [0, 1]            # best-effort last


def test_weighted_fair_share_tracks_weights():
    vt = VirtualTime()
    served = []
    sched = _sched(vt, lambda b: served.extend(r.tenant for r in b),
                   max_batch=1,
                   tenants=[TenantSpec("a", weight=2.0), TenantSpec("b")])
    for _ in range(6):
        sched.submit(arrival_s=0.0, tenant="a", cost_tokens=10.0)
    for _ in range(3):
        sched.submit(arrival_s=0.0, tenant="b", cost_tokens=10.0)
    sched.run_until_idle()
    # start-time WFQ: a's virtual time advances at half b's rate (weight
    # 2), so a is admitted twice per b admission over the whole drain
    assert served == ["a", "b", "a", "a", "b", "a", "a", "b", "a"]


def test_token_bucket_contains_noisy_neighbor():
    vt = VirtualTime()
    served = []

    def execute(batch):
        served.extend(r.tenant for r in batch)
        vt.sleep(0.01)   # 10ms service: far below the bucket refill horizon

    sched = _sched(vt, execute, max_batch=1,
                   tenants=[TenantSpec("noisy", rate_tokens_per_s=10.0,
                                       burst_tokens=10.0),
                            TenantSpec("victim")])
    for _ in range(5):
        sched.submit(arrival_s=0.0, tenant="noisy", cost_tokens=10.0)
    for _ in range(2):
        sched.submit(arrival_s=0.0, tenant="victim", cost_tokens=10.0)
    sched.run_until_idle()
    # first admission drains the noisy burst; dry tenants sink below the
    # victim, which then drains ahead of the backlog.  Work-conserving:
    # the dry tenant is still served afterwards, never starved.
    assert served[0] == "noisy"
    assert served[1:3] == ["victim", "victim"]
    assert served.count("noisy") == 5
    assert sched.deferred > 0
    assert sched.ledger.stats()["noisy"]["deferred"] > 0


def test_slo_shed_is_terminal_and_conserves_requests():
    vt = VirtualTime()

    def execute(batch):
        vt.sleep(0.05)   # measured service: 50ms per batch

    sched = _sched(vt, execute, max_batch=1)
    futs = [sched.submit(arrival_s=0.0, slo_ms=60.0) for _ in range(6)]
    sched.run_until_idle()
    statuses = [f.request.status for f in futs]
    # the first batch calibrates the EWMA; everything behind it is doomed
    # (queue position pushes est_finish past the 60ms SLO) and is shed
    # with a terminal rejected status — zero silent loss
    assert statuses.count("completed") >= 1
    assert statuses.count("rejected") >= 1
    assert statuses.count("completed") + statuses.count("rejected") == 6
    assert sched.shed == statuses.count("rejected")
    assert sched.stats()["shed"] == float(sched.shed)
    for f in futs:
        if f.request.status == "rejected":
            with pytest.raises(DeadlineExceeded, match="SLO unmeetable"):
                f.result()
        else:
            f.result()


def test_slo_shed_off_serves_everything_late():
    vt = VirtualTime()
    sched = _sched(vt, lambda b: vt.sleep(0.05), max_batch=1, slo_shed=False)
    futs = [sched.submit(arrival_s=0.0, slo_ms=60.0) for _ in range(6)]
    sched.run_until_idle()
    assert all(f.request.status == "completed" for f in futs)
    assert sched.shed == 0


# ---------------------------------------------------------------------------
# tracer events -> slo_summary / jain_index
# ---------------------------------------------------------------------------
def test_jain_index_bounds():
    assert jain_index([]) == 1.0
    assert jain_index([0.0, 0.0]) == 1.0
    assert jain_index([5.0, 5.0, 5.0]) == pytest.approx(1.0)
    # one tenant hogging everything: index -> 1/n over the active set
    assert jain_index([10.0, 10.0, 80.0]) < 0.7
    assert 0.0 < jain_index([1.0, 2.0, 3.0]) <= 1.0


def test_sched_tenant_events_feed_slo_summary():
    vt = VirtualTime()
    server = TracingServer()
    tracer = Tracer("slo-test", server, clock=vt.clock)

    def execute(batch):
        vt.sleep(0.05)

    cfg = SchedulerConfig(max_batch=1, batch_timeout_ms=0.0)
    sched = RequestScheduler(execute, cfg, clock=vt.clock, sleep=vt.sleep,
                             tracer=tracer,
                             tenants=[TenantSpec("a", slo_ms=1000.0),
                                      TenantSpec("b", slo_ms=1000.0,
                                                 rate_tokens_per_s=1.0,
                                                 burst_tokens=1.0)])
    for i in range(4):
        sched.submit(arrival_s=0.0, tenant="a" if i % 2 == 0 else "b",
                     cost_tokens=5.0)
    sched.run_until_idle()
    summary = slo_summary(server.timeline("slo-test"))
    assert summary["requests"] == 4
    assert summary["completed"] == 4
    assert summary["rejected"] == 0
    assert summary["deferred"] >= 1          # b's bucket ran dry
    assert summary["goodput_slo"] == pytest.approx(1.0)
    assert summary["tenants"] == 2.0
    assert summary["a_completed"] == 2
    assert summary["a_p99_ms"] > 0.0
    assert 0.0 < summary["jain_index"] <= 1.0


def test_slo_summary_counts_shed_and_missed_slo():
    vt = VirtualTime()
    server = TracingServer()
    tracer = Tracer("slo-shed", server, clock=vt.clock)
    cfg = SchedulerConfig(max_batch=1, batch_timeout_ms=0.0)
    sched = RequestScheduler(lambda b: vt.sleep(0.05), cfg,
                             clock=vt.clock, sleep=vt.sleep, tracer=tracer)
    futs = [sched.submit(arrival_s=0.0, slo_ms=60.0) for _ in range(5)]
    sched.run_until_idle()
    summary = slo_summary(server.timeline("slo-shed"))
    rejected = sum(1 for f in futs if f.request.status == "rejected")
    assert summary["rejected"] == rejected >= 1
    assert summary["requests"] == 5          # terminal events conserve
    assert summary["goodput_slo"] < 1.0      # shed work is not goodput
    assert slo_summary([]) == {}


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------
def test_bursty_load_is_modulated_and_deterministic():
    a = list(BurstyLoad(num_requests=200, rate_hz=50.0, burst_factor=4.0,
                        on_s=1.0, off_s=4.0, seed=3).requests())
    b = list(BurstyLoad(num_requests=200, rate_hz=50.0, burst_factor=4.0,
                        on_s=1.0, off_s=4.0, seed=3).requests())
    assert [r.arrival_s for r in a] == [r.arrival_s for r in b]
    assert all(x.arrival_s <= y.arrival_s for x, y in zip(a, a[1:]))
    on = [r for r in a if r.tags.get("burst")]
    off = [r for r in a if not r.tags.get("burst")]
    assert on and off
    # burst phases are 1s of every 5s yet carry the majority of arrivals
    assert len(on) > len(off)


def test_diurnal_load_thins_against_peak():
    reqs = list(DiurnalLoad(num_requests=300, rate_hz=20.0, period_s=10.0,
                            amplitude=0.8, seed=1).requests())
    assert len(reqs) == 300
    assert all(x.arrival_s <= y.arrival_s for x, y in zip(reqs, reqs[1:]))
    assert reqs == list(DiurnalLoad(num_requests=300, rate_hz=20.0,
                                    period_s=10.0, amplitude=0.8,
                                    seed=1).requests())


def test_multi_tenant_load_tags_and_merges():
    reqs = list(MultiTenantLoad(num_requests=60, tenants=[
        {"name": "prem", "rate_hz": 20.0, "priority": 2, "slo_ms": 100.0},
        {"name": "be", "rate_hz": 10.0, "priority": 0},
    ], seed=0).requests())
    assert len(reqs) == 60
    assert [r.request_id for r in reqs] == list(range(60))
    assert all(x.arrival_s <= y.arrival_s for x, y in zip(reqs, reqs[1:]))
    tenants = {r.tags["tenant"] for r in reqs}
    assert tenants == {"prem", "be"}
    prem = [r for r in reqs if r.tags["tenant"] == "prem"]
    assert all(r.tags["priority"] == 2 for r in prem)
    assert all(r.tags["slo_ms"] == 100.0 for r in prem)
    with pytest.raises(ValueError):
        MultiTenantLoad(num_requests=10, tenants=[{"name": "x"}])
    with pytest.raises(ValueError):
        MultiTenantLoad(num_requests=10, tenants=[])
