"""Test-suite bootstrap.

Installs the tiny ``_hypothesis_stub`` as the ``hypothesis`` module when the
real package is not installed (offline / hermetic environments), before any
test module imports it.  The real package always wins when present.
"""
import importlib.util
import sys
from pathlib import Path

if importlib.util.find_spec("hypothesis") is None:
    _stub_path = Path(__file__).with_name("_hypothesis_stub.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _stub_path)
    _mod = importlib.util.module_from_spec(_spec)
    sys.modules["hypothesis"] = _mod
    _spec.loader.exec_module(_mod)
    sys.modules["hypothesis.strategies"] = _mod.strategies
