"""Benchmarking scenarios + workload generators (F7)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.scenarios import ScenarioSpec, run_scenario
from repro.core.tracing import NullTracer
from repro.core.workload import (
    BatchedLoad,
    PoissonLoad,
    TraceReplayLoad,
    UniformLoad,
    make_generator,
    register_generator,
)


def test_batched_load():
    reqs = list(BatchedLoad(5, 8).requests())
    assert len(reqs) == 5
    assert all(r.arrival_s == 0.0 and r.batch_size == 8 for r in reqs)


@settings(max_examples=30, deadline=None)
@given(rate=st.floats(0.5, 500), n=st.integers(1, 60), seed=st.integers(0, 5))
def test_poisson_arrivals_monotone_and_rate(rate, n, seed):
    reqs = list(PoissonLoad(n, rate, seed=seed).requests())
    times = [r.arrival_s for r in reqs]
    assert all(b > a for a, b in zip(times, times[1:]))
    assert all(r.batch_size == 1 for r in reqs)


def test_poisson_mean_interarrival():
    reqs = list(PoissonLoad(5000, 10.0, seed=0).requests())
    times = np.array([r.arrival_s for r in reqs])
    gaps = np.diff(times)
    assert np.mean(gaps) == pytest.approx(0.1, rel=0.1)


def test_uniform_and_trace_loads():
    u = list(UniformLoad(3, 0.5).requests())
    assert [r.arrival_s for r in u] == [0.0, 0.5, 1.0]
    t = list(TraceReplayLoad([0.1, 0.4], [2, 3]).requests())
    assert [(r.arrival_s, r.batch_size) for r in t] == [(0.1, 2), (0.4, 3)]
    with pytest.raises(ValueError):
        TraceReplayLoad([0.1], [1, 2])


def test_generator_registry_pluggable():
    register_generator("fixed3", lambda: BatchedLoad(3, 1))
    g = make_generator("fixed3")
    assert len(list(g.requests())) == 3
    with pytest.raises(KeyError):
        make_generator("unknown-gen")


class VirtualTime:
    """Deterministic clock+sleep pair for scenario tests."""

    def __init__(self):
        self.t = 0.0

    def clock(self):
        return self.t

    def sleep(self, dt):
        self.t += dt


def test_online_scenario_metrics_deterministic():
    vt = VirtualTime()

    def predict(bs):
        vt.t += 0.010  # each call takes exactly 10 virtual ms

    spec = ScenarioSpec(kind="online", num_requests=10, rate_hz=1000.0, warmup=0)
    m = run_scenario(spec, predict, NullTracer(), clock=vt.clock, sleep=vt.sleep)
    assert m["scenario"] == "online"
    assert m["trimmed_mean_ms"] == pytest.approx(10.0)
    assert m["p90_ms"] == pytest.approx(10.0)
    assert m["num_requests"] == 10


def test_batched_scenario_picks_best_batch():
    vt = VirtualTime()

    def predict(bs):
        vt.t += 0.010 + 0.001 * bs  # sub-linear in batch -> bigger is better

    spec = ScenarioSpec(
        kind="batched", num_requests=4, batch_sizes=[1, 4, 16], warmup=0
    )
    m = run_scenario(spec, predict, NullTracer(), clock=vt.clock)
    assert m["optimal_batch_size"] == 16
    t16 = m["per_batch"]["16"]["throughput_ips"]
    t1 = m["per_batch"]["1"]["throughput_ips"]
    assert t16 > t1


def test_trace_scenario():
    vt = VirtualTime()

    def predict(bs):
        vt.t += 0.002

    spec = ScenarioSpec(kind="trace", num_requests=3, arrivals=[0.0, 0.5, 0.6], warmup=0)
    m = run_scenario(spec, predict, NullTracer(), clock=vt.clock, sleep=vt.sleep)
    assert m["num_requests"] == 3


def test_unknown_scenario_kind():
    with pytest.raises(ValueError):
        run_scenario(ScenarioSpec(kind="bogus"), lambda b: None, NullTracer())
