"""Manifest spec + versioning tests (F1/F2/F5), incl. property tests."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.manifest import (
    BackendManifest,
    ModelManifest,
    SystemRequirements,
    VersionConstraint,
    parse_version,
)

PAPER_MANIFEST = """
name: MLPerf_ResNet50_v1.5
version: 1.0.0
description: resnet50 v1.5 from MLPerf
framework:
  name: ref
  version: '>=1.0.0 <2.0'
inputs:
  - type: image
    layer_name: input_tensor
    element_type: float32
    steps:
      - decode:
          element_type: float32
      - resize:
          dimensions: [3, 224, 224]
      - normalize:
          mean: [123.68, 116.78, 103.94]
          rescale: 1.0
outputs:
  - type: probability
    layer_name: prob
    element_type: float32
    steps:
      - argsort:
          k: 5
model:
  base_path: /tmp/does-not-matter
  checksum: 7b94a2da05d
attributes:
  training_dataset: ImageNet
"""


def test_paper_listing1_roundtrip():
    m = ModelManifest.from_yaml(PAPER_MANIFEST)
    assert m.name == "MLPerf_ResNet50_v1.5"
    assert m.backend_constraint == ">=1.0.0 <2.0"
    assert [s.op for s in m.inputs[0].steps] == ["decode", "resize", "normalize"]
    assert m.outputs[0].steps[0].params["k"] == 5
    # dict -> manifest -> dict stable
    again = ModelManifest.from_dict(m.to_dict())
    assert again.to_dict() == m.to_dict()
    assert m.key == "MLPerf_ResNet50_v1.5:1.0.0"
    assert len(m.checksum()) == 16


def test_backend_manifest():
    b = BackendManifest.from_yaml(
        "name: pallas\nversion: 1.0.0\nmeshes:\n  pod: {shape: [16, 16]}\n"
    )
    assert b.key == "pallas:1.0.0"
    assert b.meshes["pod"]["shape"] == [16, 16]


@pytest.mark.parametrize(
    "spec,version,ok",
    [
        (">=1.12.0 <2.0", "1.15.0", True),
        (">=1.12.0 <2.0", "2.0.0", False),
        (">=1.12.0 <2.0", "1.11.9", False),
        ("", "0.0.1", True),            # no constraint
        ("==1.2.3", "1.2.3", True),
        ("~1.2", "1.2.9", True),
        ("~1.2", "1.3.0", False),
        (">1.0", "1.0.0", False),
    ],
)
def test_version_constraints(spec, version, ok):
    assert VersionConstraint(spec).satisfied_by(version) is ok


def test_invalid_version_rejected():
    with pytest.raises(ValueError):
        parse_version("not-a-version")
    with pytest.raises(ValueError):
        ModelManifest.from_dict({"name": "x", "version": "bogus"})


ver = st.tuples(
    st.integers(0, 20), st.integers(0, 20), st.integers(0, 20)
).map(lambda t: f"{t[0]}.{t[1]}.{t[2]}")


@settings(max_examples=60, deadline=None)
@given(a=ver, b=ver)
def test_constraint_ordering_property(a, b):
    """>= and < are consistent with tuple ordering of parsed versions."""
    ta, tb = parse_version(a), parse_version(b)
    assert VersionConstraint(f">={b}").satisfied_by(a) == (ta >= tb)
    assert VersionConstraint(f"<{b}").satisfied_by(a) == (ta < tb)


@settings(max_examples=30, deadline=None)
@given(v=ver)
def test_exact_constraint_is_reflexive(v):
    assert VersionConstraint(f"=={v}").satisfied_by(v)


def test_system_requirements():
    info = {"platform": "cpu", "num_devices": 4, "memory_bytes": 1 << 30, "mesh": "host"}
    assert SystemRequirements().satisfied_by(info)
    assert SystemRequirements(platform="cpu", min_devices=4).satisfied_by(info)
    assert not SystemRequirements(min_devices=8).satisfied_by(info)
    assert not SystemRequirements(platform="tpu").satisfied_by(info)
    assert not SystemRequirements(mesh="pod").satisfied_by(info)
