"""Training substrate: optimizer math, schedule, checkpointing, data."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models import build_model
from repro.models.params import P, init_params
from repro.train.checkpoint import CheckpointManager
from repro.train.data import (
    RecordIOReader,
    RecordIOWriter,
    SyntheticTokenDataset,
    make_loader,
)
from repro.train.optimizer import (
    OptimizerConfig,
    adamw_update,
    clip_by_global_norm,
    init_opt_state,
    lr_at,
    opt_state_defs,
    quantize_int8,
)
from repro.train.step import make_loss_fn, make_train_step


def test_adamw_first_step_matches_manual():
    cfg = OptimizerConfig(lr=0.1, beta1=0.9, beta2=0.99, eps=1e-8,
                          weight_decay=0.0, warmup_steps=0, total_steps=10**6,
                          clip_norm=1e9)
    params = {"w": jnp.asarray([1.0, -2.0])}
    grads = {"w": jnp.asarray([0.5, 0.5])}
    defs = {"w": P((2,))}
    state = init_opt_state(defs, cfg)
    new_params, new_state, _ = adamw_update(params, grads, state, cfg)
    # bias-corrected first step == -lr * sign-ish update
    m_hat = 0.1 * 0.5 / (1 - 0.9)
    v_hat = 0.01 * 0.25 / (1 - 0.99)
    expected = 1.0 - 0.1 * (m_hat / 0.1 / (np.sqrt(v_hat) + 1e-8)) * 0.1  # structure check below
    step_delta = float(params["w"][0] - new_params["w"][0])
    manual = 0.1 * ((0.5 / 1.0) / (np.sqrt(0.25 / 1.0) + 1e-8))
    assert step_delta == pytest.approx(manual, rel=1e-5)
    assert int(new_state["step"]) == 1


def test_weight_decay_pulls_to_zero():
    cfg = OptimizerConfig(lr=0.1, weight_decay=0.5, warmup_steps=0,
                          total_steps=100, clip_norm=1e9)
    params = {"w": jnp.asarray([4.0])}
    grads = {"w": jnp.asarray([0.0])}
    state = init_opt_state({"w": P((1,))}, cfg)
    new_params, _, _ = adamw_update(params, grads, state, cfg)
    assert float(new_params["w"][0]) < 4.0


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_frac=0.1)
    assert float(lr_at(0, cfg)) == 0.0
    assert float(lr_at(5, cfg)) == pytest.approx(0.5)
    assert float(lr_at(10, cfg)) == pytest.approx(1.0, rel=1e-3)
    assert float(lr_at(110, cfg)) == pytest.approx(0.1, rel=1e-3)
    mid = float(lr_at(60, cfg))
    assert 0.1 < mid < 1.0


def test_clip_by_global_norm():
    tree = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(5.0)
    total = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


@settings(max_examples=20, deadline=None)
@given(scale=st.floats(1e-3, 1e3))
def test_int8_compression_bounded_error(scale):
    g = jnp.asarray(np.random.default_rng(0).normal(size=(64,)) * scale, jnp.float32)
    gq = quantize_int8(g, jax.random.PRNGKey(0))
    # error bounded by one quantization step (max|g|/127)
    step = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.max(jnp.abs(gq - g))) <= step + 1e-6


def test_microbatched_grads_match_full_batch():
    cfg = get_config("glm4-9b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab_size, (4, 12)), jnp.int32
        )
    }
    loss_fn = make_loss_fn(model)
    _, g_full = jax.value_and_grad(lambda p: loss_fn(p, batch)[0])(params)
    opt_cfg = OptimizerConfig(lr=0.0, warmup_steps=0, total_steps=10, clip_norm=1e9,
                              weight_decay=0.0)
    opt_state = init_opt_state(model.param_defs(), opt_cfg)

    # lr=0 so params unchanged; compare reported grad_norm across microbatchings
    step1 = make_train_step(model, opt_cfg, microbatches=1, remat=False)
    step4 = make_train_step(model, opt_cfg, microbatches=4, remat=True)
    _, _, m1 = jax.jit(step1)(params, opt_state, batch)
    _, _, m4 = jax.jit(step4)(params, opt_state, batch)
    assert float(m1["grad_norm"]) == pytest.approx(float(m4["grad_norm"]), rel=1e-3)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-4)


def test_loss_decreases_over_steps():
    cfg = get_config("mamba2-130m", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = OptimizerConfig(lr=3e-3, warmup_steps=2, total_steps=50)
    opt_state = init_opt_state(model.param_defs(), opt_cfg)
    step = jax.jit(make_train_step(model, opt_cfg, microbatches=1, remat=False))
    data = SyntheticTokenDataset(cfg.vocab_size, 16, seed=0)
    first = last = None
    for i in range(8):
        batch = {"tokens": jnp.asarray(data.batch(0, 4))}  # same batch: must overfit
        params, opt_state, metrics = step(params, opt_state, batch)
        if first is None:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert last < first, (first, last)


# ---------------------------------------------------------------------------
# Checkpointing (fault tolerance)
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    params = {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
              "nested": {"b": np.ones(3, np.float32)}}
    opt = {"step": np.int32(7), "m": {"w": np.zeros((2, 3), np.float32),
                                      "nested": {"b": np.zeros(3, np.float32)}}}
    for step in (10, 20, 30):
        mgr.save(step, params, opt, extra={"data_cursor": step * 100})
    assert mgr.all_steps() == [20, 30]  # retention pruned step 10
    restored, opt2, meta = mgr.restore(params_template=params, opt_template=opt)
    np.testing.assert_array_equal(restored["w"], params["w"])
    np.testing.assert_array_equal(opt2["m"]["nested"]["b"], np.zeros(3))
    assert meta["step"] == 30 and meta["data_cursor"] == 3000


def test_checkpoint_detects_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"w": np.ones(4, np.float32)})
    cdir = os.path.join(str(tmp_path), "ckpt-000000001")
    shard = [f for f in os.listdir(cdir) if f.startswith("shard")][0]
    with open(os.path.join(cdir, shard), "ab") as f:
        f.write(b"CORRUPT")
    with pytest.raises(ValueError, match="checksum"):
        mgr.restore(params_template={"w": None})


def test_checkpoint_atomic_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, {"w": np.ones(2, np.float32)})
    entries = [e for e in os.listdir(str(tmp_path)) if e.startswith(".tmp")]
    assert entries == []


def test_restore_without_template(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, {"w": np.ones(2, np.float32)})
    params, opt, meta = mgr.restore()
    assert meta["step"] == 3
    np.testing.assert_array_equal(params["w"], np.ones(2, np.float32))


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------
def test_recordio_roundtrip(tmp_path):
    path = str(tmp_path / "tokens.rio")
    w = RecordIOWriter(path, seq_len=8)
    recs = [np.arange(i, i + 8, dtype=np.int32) for i in range(5)]
    for r in recs:
        w.append(r)
    w.close()
    r = RecordIOReader(path)
    assert len(r) == 5 and r.seq_len == 8
    np.testing.assert_array_equal(r.record(3), recs[3])
    np.testing.assert_array_equal(r.batch(1, 2), np.stack(recs[1:3]))
    # wraparound
    wrap = r.batch(4, 2)
    np.testing.assert_array_equal(wrap[0], recs[4])
    np.testing.assert_array_equal(wrap[1], recs[0])


def test_recordio_rejects_bad_magic(tmp_path):
    path = str(tmp_path / "bad.rio")
    with open(path, "wb") as f:
        f.write(b"NOPE" + b"\x00" * 16)
    with pytest.raises(ValueError, match="magic"):
        RecordIOReader(path)


def test_loader_resume_from_cursor(tmp_path):
    ds = SyntheticTokenDataset(vocab_size=97, seq_len=4, seed=1)
    it = make_loader(ds, batch_size=2)
    cursor1, b1 = next(it)
    cursor2, b2 = next(it)
    assert cursor1 == 2 and cursor2 == 4
    # resume: skipping cursor1 records reproduces the second batch exactly
    it2 = make_loader(ds, batch_size=2, skip=cursor1)
    cursor2b, b2b = next(it2)
    assert cursor2b == cursor2
    np.testing.assert_array_equal(b2["tokens"], b2b["tokens"])


def test_synthetic_to_recordio(tmp_path):
    ds = SyntheticTokenDataset(vocab_size=31, seq_len=6, seed=0)
    path = str(tmp_path / "synth.rio")
    ds.write_recordio(path, 4)
    r = RecordIOReader(path)
    assert len(r) == 4
    assert r.batch(0, 4).max() < 31
