"""Request scheduler + new scenario kinds (continuous-batching tentpole).

All timing uses injected fake clocks — no real sleeps — so every test is a
deterministic discrete-event simulation.
"""
import threading

import pytest

from repro.core.scenarios import ScenarioSpec, run_scenario, scenario_kinds
from repro.core.tracing import NullTracer, Tracer, TracingServer
from repro.core.analysis import scheduler_summary, slo_attainment
from repro.serve.scheduler import (
    DeadlineExceeded,
    RequestScheduler,
    RetriesExhausted,
    SchedulerConfig,
    SchedulerQueueFull,
    SlotPool,
    backoff_delay,
)


class VirtualTime:
    """Deterministic clock+sleep pair."""

    def __init__(self):
        self.t = 0.0

    def clock(self):
        return self.t

    def sleep(self, dt):
        self.t += dt


# ---------------------------------------------------------------------------
# RequestScheduler
# ---------------------------------------------------------------------------
def test_fifo_order_under_concurrent_submitters():
    vt = VirtualTime()
    served = []

    def execute(batch):
        served.extend(r.request_id for r in batch)

    sched = RequestScheduler(
        execute, SchedulerConfig(max_batch=1, batch_timeout_ms=0.0),
        clock=vt.clock, sleep=vt.sleep,
    )
    barrier = threading.Barrier(4)
    ids = [[] for _ in range(4)]

    def submitter(k):
        barrier.wait()
        for _ in range(8):
            ids[k].append(sched.submit().request.request_id)

    threads = [threading.Thread(target=submitter, args=(k,)) for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert sched.run_until_idle() == 32
    # FIFO: execution order == submission (request-id) order
    assert served == sorted(served)
    # every submitter saw its own ids in increasing order
    for k in range(4):
        assert ids[k] == sorted(ids[k])


def test_microbatch_coalescing_respects_max_batch_and_timeout():
    vt = VirtualTime()
    batches = []

    def execute(batch):
        batches.append([r.request_id for r in batch])

    sched = RequestScheduler(
        execute, SchedulerConfig(max_batch=4, batch_timeout_ms=5.0),
        clock=vt.clock, sleep=vt.sleep,
    )
    arrivals = [0.000, 0.001, 0.002, 0.010, 0.011, 0.030]
    for a in arrivals:
        sched.submit(arrival_s=a)
    sched.run_until_idle()
    # requests 0-2 coalesce inside the 5 ms window; 3 (10 ms) starts a new
    # batch joined by 4 (11 ms); 5 (30 ms) is alone — and never > max_batch
    assert batches == [[0, 1, 2], [3, 4], [5]]
    assert all(len(b) <= 4 for b in batches)


def test_microbatch_coalescing_caps_at_max_batch():
    vt = VirtualTime()
    batches = []
    sched = RequestScheduler(
        lambda b: batches.append([r.request_id for r in b]),
        SchedulerConfig(max_batch=2, batch_timeout_ms=5.0),
        clock=vt.clock, sleep=vt.sleep,
    )
    for a in [0.000, 0.001, 0.002, 0.003]:
        sched.submit(arrival_s=a)
    sched.run_until_idle()
    assert batches == [[0, 1], [2, 3]]


def test_zero_timeout_batches_only_already_arrived():
    vt = VirtualTime()
    batches = []
    sched = RequestScheduler(
        lambda b: batches.append([r.request_id for r in b]),
        SchedulerConfig(max_batch=8, batch_timeout_ms=0.0),
        clock=vt.clock, sleep=vt.sleep,
    )
    sched.submit(arrival_s=0.0)
    sched.submit(arrival_s=0.0)
    sched.submit(arrival_s=1.0)   # future arrival: not coalesced with t=0
    sched.run_until_idle()
    assert batches == [[0, 1], [2]]


def test_bounded_queue_rejects_when_full():
    vt = VirtualTime()
    sched = RequestScheduler(
        lambda b: None, SchedulerConfig(max_batch=1, queue_depth=2),
        clock=vt.clock, sleep=vt.sleep,
    )
    sched.submit(block=False)
    sched.submit(block=False)
    with pytest.raises(SchedulerQueueFull):
        sched.submit(block=False)
    assert sched.rejected == 1
    sched.run_until_idle()
    assert sched.completed == 2


def test_future_results_and_errors_propagate():
    vt = VirtualTime()

    def execute(batch):
        if any(r.payload == "boom" for r in batch):
            raise RuntimeError("kaboom")
        return [r.payload * 2 for r in batch]

    sched = RequestScheduler(
        execute, SchedulerConfig(max_batch=1), clock=vt.clock, sleep=vt.sleep
    )
    ok = sched.submit(payload=21)
    bad = sched.submit(payload="boom")
    assert ok.result() == 42
    with pytest.raises(RuntimeError, match="kaboom"):
        bad.result()


def test_request_latency_accounting_with_fake_clock():
    vt = VirtualTime()

    def execute(batch):
        vt.t += 0.010  # each micro-batch takes exactly 10 virtual ms

    sched = RequestScheduler(
        execute, SchedulerConfig(max_batch=1, batch_timeout_ms=0.0),
        clock=vt.clock, sleep=vt.sleep,
    )
    # two requests arriving at t=0: the second queues behind the first
    f1 = sched.submit(arrival_s=0.0)
    f2 = sched.submit(arrival_s=0.0)
    sched.run_until_idle()
    assert f1.request.service_s == pytest.approx(0.010)
    assert f1.request.queue_s == pytest.approx(0.0)
    assert f2.request.queue_s == pytest.approx(0.010)
    assert f2.request.latency_s == pytest.approx(0.020)


def test_threaded_mode_coalesces_and_completes():
    done = threading.Event()
    batches = []

    def execute(batch):
        batches.append(len(batch))
        if sum(batches) == 8:
            done.set()

    sched = RequestScheduler(
        execute, SchedulerConfig(max_batch=4, batch_timeout_ms=20.0)
    ).start()
    try:
        futs = [sched.submit() for _ in range(8)]
        for f in futs:
            f.result(timeout=5.0)
        assert done.wait(5.0)
    finally:
        sched.stop()
    assert sum(batches) == 8
    assert max(batches) <= 4


# ---------------------------------------------------------------------------
# SlotPool (continuous-batching slot bookkeeping)
# ---------------------------------------------------------------------------
def test_slot_pool_reuse_and_admission_order():
    pool = SlotPool(2)
    s0 = pool.admit("r0", step=0)
    s1 = pool.admit("r1", step=0)
    assert (s0, s1) == (0, 1)
    assert pool.admit("r2", step=0) is None      # full: r2 must wait
    assert pool.release(s0) == "r0"              # r0 finishes
    s2 = pool.admit("r2", step=3)
    assert s2 == s0                              # freed slot is reused
    assert pool.admissions[-1] == (3, s0, "r2")
    assert pool.num_active == 2
    with pytest.raises(KeyError):
        pool.release(99)


# ---------------------------------------------------------------------------
# New scenario kinds through run_scenario (deterministic fake clocks)
# ---------------------------------------------------------------------------
def test_all_six_scenario_kinds_run():
    assert scenario_kinds() == [
        "batched", "offline", "online", "server", "single_stream", "trace"
    ]
    for kind in scenario_kinds():
        vt = VirtualTime()

        def predict(bs):
            vt.t += 0.001 * max(bs, 1)

        spec = ScenarioSpec(
            kind=kind, num_requests=6, rate_hz=100.0, warmup=0,
            arrivals=[0.0, 0.01, 0.02], batch_sizes=[1, 2],
        )
        m = run_scenario(spec, predict, NullTracer(), clock=vt.clock, sleep=vt.sleep)
        assert m["scenario"] == kind


def test_single_stream_metrics():
    vt = VirtualTime()

    def predict(bs):
        vt.t += 0.004

    spec = ScenarioSpec(kind="single_stream", num_requests=10, warmup=0)
    m = run_scenario(spec, predict, NullTracer(), clock=vt.clock, sleep=vt.sleep)
    assert m["num_requests"] == 10
    assert m["trimmed_mean_ms"] == pytest.approx(4.0)
    assert m["p99_ms"] == pytest.approx(4.0)
    assert m["streams_per_s"] == pytest.approx(250.0)


def test_server_scenario_slo_accounting_no_queueing():
    vt = VirtualTime()

    def predict(bs):
        vt.t += 0.010

    # arrivals ~1 s apart >> 10 ms service: no queueing, every request meets
    # a 25 ms SLO exactly at its 10 ms service latency
    spec = ScenarioSpec(
        kind="server", num_requests=12, rate_hz=1.0, warmup=0, slo_ms=25.0, seed=0
    )
    m = run_scenario(
        spec, predict, NullTracer(), clock=vt.clock, sleep=vt.sleep,
        scheduler=SchedulerConfig(max_batch=4, batch_timeout_ms=2.0),
    )
    assert m["scenario"] == "server"
    assert m["num_requests"] == 12
    # most gaps >> service time: the trimmed mean sees pure 10 ms service
    assert m["trimmed_mean_ms"] == pytest.approx(10.0)
    assert m["p99_ms"] < 25.0
    assert m["slo_violations"] == 0
    assert m["slo_attainment"] == pytest.approx(1.0)
    assert m["slo_met"]
    assert m["achieved_qps"] > 0
    # seed-0 arrivals contain exactly one gap inside the 2 ms window, so one
    # pair coalesces: 11 micro-batches for 12 requests
    assert m["sched_batches"] == 11.0
    assert m["sched_completed"] == 12.0


def test_server_scenario_slo_accounting_overload():
    vt = VirtualTime()

    def predict(bs):
        vt.t += 0.050  # 50 ms service vs 25 ms SLO at 1000 rps: all violate

    spec = ScenarioSpec(
        kind="server", num_requests=10, rate_hz=1000.0, warmup=0, slo_ms=25.0
    )
    m = run_scenario(
        spec, predict, NullTracer(), clock=vt.clock, sleep=vt.sleep,
        scheduler=SchedulerConfig(max_batch=1, batch_timeout_ms=0.0),
    )
    assert m["slo_violations"] == 10
    assert m["slo_attainment"] == pytest.approx(0.0)
    assert not m["slo_met"]
    assert m["mean_queue_s"] > 0


def test_offline_scenario_coalescing_beats_sequential():
    def make_predict(vt):
        # fixed dispatch overhead + per-input cost: batching amortizes the 5 ms
        def predict(bs):
            vt.t += 0.005 + 0.001 * bs
        return predict

    vt1 = VirtualTime()
    seq = run_scenario(
        ScenarioSpec(kind="offline", num_requests=16, warmup=0),
        make_predict(vt1), NullTracer(), clock=vt1.clock, sleep=vt1.sleep,
        scheduler=SchedulerConfig(max_batch=1, batch_timeout_ms=0.0),
    )
    vt2 = VirtualTime()
    coal = run_scenario(
        ScenarioSpec(kind="offline", num_requests=16, warmup=0),
        make_predict(vt2), NullTracer(), clock=vt2.clock, sleep=vt2.sleep,
        scheduler=SchedulerConfig(max_batch=8, batch_timeout_ms=0.0),
    )
    assert coal["sched_mean_batch_occupancy"] == pytest.approx(8.0)
    assert coal["throughput_ips"] > 2.0 * seq["throughput_ips"]


def test_scheduler_events_flow_to_tracer_and_analysis():
    vt = VirtualTime()
    server = TracingServer()
    tracer = Tracer("t-sched", server)

    def predict(bs):
        vt.t += 0.002

    spec = ScenarioSpec(kind="offline", num_requests=8, warmup=0)
    run_scenario(
        spec, predict, tracer, clock=vt.clock, sleep=vt.sleep,
        scheduler=SchedulerConfig(max_batch=4, batch_timeout_ms=0.0),
    )
    spans = server.timeline("t-sched")
    summary = scheduler_summary(spans)
    assert summary["batches"] == 2.0
    assert summary["mean_batch_occupancy"] == pytest.approx(4.0)
    assert summary["total_inputs"] == 8.0


def test_slo_attainment_helper():
    out = slo_attainment([0.01, 0.02, 0.05], slo_ms=25.0)
    assert out["slo_violations"] == 1.0
    assert out["slo_attainment"] == pytest.approx(2.0 / 3.0)


def test_server_scenario_shared_prefix_mix():
    """prefix_len > 0 swaps the server arrival process for a shared-prefix
    mix: submitted requests carry prompt-composition tags (group / prefix
    length) through the scheduler, the metrics report the realized share,
    and the trace kind stamps the same mix onto replayed arrivals."""
    vt = VirtualTime()

    def predict(bs):
        vt.t += 0.001

    spec = ScenarioSpec(
        kind="server", num_requests=24, rate_hz=100.0, warmup=0, seed=0,
        prefix_len=32, prefix_share=0.75, prefix_groups=2, suffix_len=8,
    )
    m = run_scenario(
        spec, predict, NullTracer(), clock=vt.clock, sleep=vt.sleep,
        scheduler=SchedulerConfig(max_batch=4, batch_timeout_ms=2.0),
    )
    assert m["scenario"] == "server"
    assert m["prefix_len"] == 32
    assert m["shared_prefix_requests"] > 0
    assert 0.5 <= m["shared_prefix_fraction"] <= 1.0
    assert m["sched_completed"] == 24.0

    # the trace kind replays recorded arrivals with the same composition
    vt2 = VirtualTime()
    spec_tr = ScenarioSpec(
        kind="trace", num_requests=10, warmup=0, seed=0,
        arrivals=[i * 0.01 for i in range(10)],
        prefix_len=16, prefix_share=0.5, prefix_groups=1,
    )
    m2 = run_scenario(spec_tr, predict, NullTracer(), clock=vt2.clock,
                      sleep=vt2.sleep)
    assert m2["scenario"] == "trace"
    assert m2["prefix_len"] == 16
    assert 0 <= m2["shared_prefix_requests"] <= 10
    assert m2["num_requests"] == 10


# ---------------------------------------------------------------------------
# Deadlines, retries, backoff, shedding (fault-tolerance satellite)
# ---------------------------------------------------------------------------
def test_backoff_delay_caps_and_is_deterministic():
    import random

    assert [backoff_delay(a, 0.01, 0.05) for a in (1, 2, 3, 4)] == [
        0.01, 0.02, 0.04, 0.05
    ]
    with pytest.raises(ValueError):
        backoff_delay(0, 0.01, 0.05)
    # seeded jitter: same rng seed -> same schedule, bounded by ±jitter
    a = [backoff_delay(1, 0.01, 1.0, jitter=0.5, rng=random.Random(42))
         for _ in range(3)]
    assert a[0] == a[1] == a[2]
    assert 0.005 <= a[0] <= 0.015


def test_retry_budget_recovers_transient_failures():
    vt = VirtualTime()
    calls = []

    def execute(batch):
        calls.append(vt.t)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return [r.payload for r in batch]

    sched = RequestScheduler(
        execute,
        SchedulerConfig(max_batch=1, max_retries=3, backoff_base_ms=10.0,
                        backoff_cap_ms=1000.0),
        clock=vt.clock, sleep=vt.sleep,
    )
    fut = sched.submit(payload=7)
    sched.run_until_idle()
    assert fut.result() == 7
    assert sched.retries == 2
    # deterministic backoff schedule (no jitter): retry arrivals land at
    # +base, then +2x base after the failed-attempt times
    assert calls == pytest.approx([0.0, 0.010, 0.030])


def test_retries_exhausted_future_never_hangs():
    vt = VirtualTime()
    attempts = []

    def execute(batch):
        attempts.append(vt.t)
        raise RuntimeError("kaboom")

    sched = RequestScheduler(
        execute,
        SchedulerConfig(max_batch=1, max_retries=2, backoff_base_ms=10.0),
        clock=vt.clock, sleep=vt.sleep,
    )
    fut = sched.submit()
    sched.run_until_idle()                      # returns: the future is set
    with pytest.raises(RetriesExhausted) as ei:
        fut.result()
    assert isinstance(ei.value.__cause__, RuntimeError)
    assert len(attempts) == 3                   # initial + 2 retries
    assert sched.stats()["retry_failures"] == 1.0
    assert sched.stats()["retries"] == 2.0


def test_deadline_exceeded_is_terminal_not_silent():
    vt = VirtualTime()

    def execute(batch):
        vt.t += 0.100                           # each batch takes 100 ms

    sched = RequestScheduler(
        execute, SchedulerConfig(max_batch=1, batch_timeout_ms=0.0),
        clock=vt.clock, sleep=vt.sleep,
    )
    ok = sched.submit(arrival_s=0.0)
    late = sched.submit(arrival_s=0.0, deadline_s=0.050)  # dies in queue
    sched.run_until_idle()
    assert ok.result() is None                  # executed fine
    with pytest.raises(DeadlineExceeded):
        late.result()
    assert sched.stats()["deadline_failures"] == 1.0


def test_config_deadline_ms_applies_to_submissions():
    vt = VirtualTime()

    def execute(batch):
        vt.t += 0.100

    sched = RequestScheduler(
        execute,
        SchedulerConfig(max_batch=1, batch_timeout_ms=0.0, deadline_ms=50.0),
        clock=vt.clock, sleep=vt.sleep,
    )
    first = sched.submit(arrival_s=0.0)
    second = sched.submit(arrival_s=0.0)        # queues behind the first
    sched.run_until_idle()
    assert first.request.status == "completed"
    with pytest.raises(DeadlineExceeded):
        second.result()


def test_shedding_rejects_new_admissions_but_drains_queued():
    vt = VirtualTime()
    served = []
    sched = RequestScheduler(
        lambda b: served.extend(r.request_id for r in b),
        SchedulerConfig(max_batch=1),
        clock=vt.clock, sleep=vt.sleep,
    )
    queued = sched.submit()
    sched.shedding = True
    with pytest.raises(SchedulerQueueFull, match="shed"):
        sched.submit()
    assert sched.rejected == 1
    sched.run_until_idle()                      # queued work still drains
    assert queued.request.status == "completed"
    assert served == [0]
    sched.shedding = False
    assert sched.submit() is not None


def test_prefix_cache_scheduler_config_roundtrip():
    cfg = SchedulerConfig(prefix_cache=True)
    assert SchedulerConfig.from_dict(cfg.to_dict()).prefix_cache is True
    assert SchedulerConfig.from_dict({"max_batch": 2}).prefix_cache is False
