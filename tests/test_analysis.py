"""Analysis metrics (F8): trimmed mean, percentile, scalability, layers."""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.analysis import (
    comparison_table,
    critical_path,
    latency_summary,
    layer_breakdown,
    percentile,
    throughput_scalability,
    top_layers,
    trimmed_mean,
)
from repro.core.tracing import Span, TraceLevel


def test_trimmed_mean_matches_paper_definition():
    # TrimmedMean(list) = Mean(Sort(list)[floor(0.2*len):-floor(0.2*len)])
    data = [100.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 0.0]
    s = sorted(data)
    k = math.floor(0.2 * len(s))
    expected = np.mean(s[k:-k])
    assert trimmed_mean(data) == pytest.approx(expected)


@settings(max_examples=60, deadline=None)
@given(xs=st.lists(st.floats(0, 1e6, allow_nan=False), min_size=1, max_size=50))
def test_trimmed_mean_bounded_by_min_max(xs):
    tm = trimmed_mean(xs)
    assert min(xs) - 1e-9 <= tm <= max(xs) + 1e-9


@settings(max_examples=60, deadline=None)
@given(
    xs=st.lists(st.floats(0, 1e6, allow_nan=False), min_size=1, max_size=50),
    pct=st.floats(0, 100),
)
def test_percentile_is_an_element(xs, pct):
    assert percentile(xs, pct) in xs


def test_percentile_nearest_rank():
    xs = list(range(1, 101))
    assert percentile(xs, 90) == 90
    assert percentile(xs, 100) == 100
    assert percentile(xs, 1) == 1


def test_trimmed_mean_robust_to_outliers():
    base = [1.0] * 8
    assert trimmed_mean(base + [1000.0, 0.0]) == pytest.approx(1.0)


def test_latency_summary_keys():
    out = latency_summary([0.001, 0.002, 0.003])
    assert set(out) == {"trimmed_mean_ms", "p90_ms", "min_ms", "max_ms"}
    assert out["min_ms"] == pytest.approx(1.0)


def test_throughput_scalability_figure6():
    per_batch = {1: 100.0, 2: 180.0, 4: 300.0}
    speedups = throughput_scalability(per_batch)
    assert speedups[1] == pytest.approx(1.0)
    assert speedups[4] == pytest.approx(3.0)


def _span(name, level, begin, end, parent=None):
    s = Span(name=name, level=level, trace_id="t", begin=begin, end=end)
    if parent is not None:
        s.parent_id = parent
    return s


def test_layer_breakdown_table3():
    spans = [
        _span("conv2d_48", TraceLevel.FRAMEWORK, 0, 7.59),
        _span("conv2d_48", TraceLevel.FRAMEWORK, 8, 8 + 7.57),
        _span("conv2d_45", TraceLevel.FRAMEWORK, 16, 16 + 5.67),
        _span("ignored_model_span", TraceLevel.MODEL, 0, 100),
    ]
    stats = layer_breakdown(spans)
    assert stats[0].name == "conv2d_48"
    assert stats[0].count == 2
    assert stats[0].total_s == pytest.approx(15.16)
    assert top_layers(spans, k=1)[0].name == "conv2d_48"


def test_critical_path_zoom_in():
    root = _span("evaluation", TraceLevel.MODEL, 0, 100)
    child = _span("inference", TraceLevel.MODEL, 10, 90, parent=root.span_id)
    small = _span("preprocess", TraceLevel.MODEL, 0, 5, parent=root.span_id)
    leaf = _span("fc6_copy", TraceLevel.FRAMEWORK, 20, 80, parent=child.span_id)
    path = critical_path([root, child, small, leaf])
    assert [s.name for s in path] == ["evaluation", "inference", "fc6_copy"]


def test_comparison_table_renders():
    rows = [{"model": "a", "ms": 1.25}, {"model": "b", "ms": 0.5}]
    txt = comparison_table(rows, ["model", "ms"], sort_by="ms")
    lines = txt.splitlines()
    assert lines[0].split() == ["model", "ms"]
    assert "a" in lines[2] and "b" in lines[3]


def test_empty_inputs_raise():
    with pytest.raises(ValueError):
        trimmed_mean([])
    with pytest.raises(ValueError):
        percentile([], 50)
