"""HLO cost model: trip-count correction, collective accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo import HloCostModel, _shape_bytes, parse_hlo


def _xla_flops(compiled):
    # jax version compat: cost_analysis() returns a dict or a 1-list of dicts
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return ca["flops"]


def test_scan_trip_count_corrected():
    """XLA cost_analysis counts while bodies once; ours multiplies by trips."""

    def f(x, ws):
        def body(c, w):
            return jnp.maximum(c @ w, 0.0), None

        out, _ = jax.lax.scan(body, x, ws)
        return out.sum()

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((12, 64, 64), jnp.float32)
    c = jax.jit(f).lower(x, ws).compile()
    xla_flops = _xla_flops(c)
    mine = HloCostModel(c.as_text()).entry_costs()
    expected = 12 * 2 * 64**3
    assert mine.flops == pytest.approx(expected, rel=0.01)
    assert xla_flops < expected  # demonstrates the undercount we fix


def test_nested_scan_trip_counts():
    def f(x, ws):
        def outer(c, wpair):
            def inner(c2, w):
                return c2 @ w, None

            c2, _ = jax.lax.scan(inner, c, wpair)
            return c2, None

        out, _ = jax.lax.scan(outer, x, ws)
        return out.sum()

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 4, 32, 32), jnp.float32)   # 3 outer × 4 inner
    c = jax.jit(f).lower(x, ws).compile()
    mine = HloCostModel(c.as_text()).entry_costs()
    expected = 12 * 2 * 32**3
    assert mine.flops == pytest.approx(expected, rel=0.02)


def test_unrolled_matches_xla():
    def g(x, ws):
        for i in range(6):
            x = x @ ws[i]
        return x

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)
    c = jax.jit(g).lower(x, ws).compile()
    mine = HloCostModel(c.as_text()).entry_costs()
    assert mine.flops == pytest.approx(_xla_flops(c), rel=0.01)


def test_shape_bytes():
    assert _shape_bytes("f32[2,3]{1,0}") == 24
    assert _shape_bytes("bf16[128]") == 256
    assert _shape_bytes("(f32[2], s32[4])") == 24
    assert _shape_bytes("pred[]") == 1


SYNTH = """
HloModule synth

ENTRY %main (p0: f32[64,64]) -> f32[64,64] {
  %p0 = f32[64,64]{1,0} parameter(0)
  %ag = f32[64,64]{1,0} all-gather(%p0), channel_id=1, replica_groups=[4,4]<=[16], dimensions={0}
  %ar = f32[64,64]{1,0} all-reduce(%ag), channel_id=2, replica_groups={{0,1},{2,3}}, to_apply=%add
  ROOT %cp = f32[64,64]{1,0} collective-permute(%ar), channel_id=3, source_target_pairs={{0,1},{1,0}}
}
"""


def test_synthetic_collectives_both_group_formats():
    cm = HloCostModel(SYNTH)
    costs = cm.entry_costs()
    size = 64 * 64 * 4
    # all-gather v2 groups [4,4]: (4-1)/4 * out
    # all-reduce v1 groups {{0,1},{2,3}}: 2*(2-1)/2 * out
    # collective-permute: 1 * out
    expected = size * (3 / 4) + size * 1.0 + size * 1.0
    assert costs.collective_bytes == pytest.approx(expected)
    assert costs.collective_count == {
        "all-gather": 1, "all-reduce": 1, "collective-permute": 1
    }


def test_dynamic_slice_refinement():
    """A scan reading one layer's weights per step must not charge the
    full stacked array every iteration."""

    def f(x, ws):
        def body(c, w):
            return c @ w, None

        out, _ = jax.lax.scan(body, x, ws)
        return out.sum()

    L = 16
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, 64, 64), jnp.float32)
    c = jax.jit(f).lower(x, ws).compile()
    mine = HloCostModel(c.as_text()).entry_costs()
    stack_bytes = L * 64 * 64 * 4
    # memory should be ~L * (one layer read + activations) ~= a few stacks,
    # NOT L * stack_bytes (charging the whole stack every iteration)
    assert mine.memory_bytes < (L / 2) * stack_bytes
