"""Streaming pipeline executor (F6): ordering, threading, built-in ops."""
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.manifest import ProcessingStep
from repro.core.pipeline import Pipeline, build_steps, register_op
from repro.core.tracing import Tracer, TraceLevel, TracingServer


def test_order_preserved():
    pipe = Pipeline([("double", lambda x, m: x * 2), ("inc", lambda x, m: x + 1)])
    assert pipe.run(range(10)) == [2 * i + 1 for i in range(10)]


@settings(max_examples=20, deadline=None)
@given(xs=st.lists(st.integers(-1000, 1000), max_size=40))
def test_order_preserved_property(xs):
    pipe = Pipeline([("id", lambda x, m: x), ("neg", lambda x, m: -x)])
    assert pipe.run(xs) == [-x for x in xs]


def test_stages_overlap_on_threads():
    """Producer/consumer stages run concurrently (I/O overlaps compute)."""
    active = {"a": 0, "b": 0}
    overlap = []
    lock = threading.Lock()

    def stage(name):
        def fn(x, m):
            with lock:
                active[name] += 1
                overlap.append(sum(active.values()))
            time.sleep(0.005)
            with lock:
                active[name] -= 1
            return x

        return fn

    pipe = Pipeline([("a", stage("a")), ("b", stage("b"))], channel_capacity=4)
    pipe.run(range(16))
    assert max(overlap) >= 2  # both stages were simultaneously busy


def test_error_propagates():
    def boom(x, m):
        if x == 3:
            raise ValueError("boom")
        return x

    pipe = Pipeline([("boom", boom)])
    with pytest.raises(ValueError, match="boom"):
        pipe.run(range(5))


def test_tracer_records_operator_spans():
    server = TracingServer()
    tr = Tracer("t", server, TraceLevel.MODEL)
    pipe = Pipeline([("op1", lambda x, m: x)], tracer=tr)
    pipe.run([1, 2])
    spans = [s for s in server.timeline("t") if s.name == "op:op1"]
    assert len(spans) == 2


def test_builtin_image_ops_match_manifest_order():
    steps = [
        ProcessingStep("decode", {"element_type": "uint8"}),
        ProcessingStep("resize", {"dimensions": [3, 8, 8]}),
        ProcessingStep("normalize", {"mean": 127.0, "rescale": 1.0}),
    ]
    ops = build_steps(steps)
    pipe = Pipeline(ops)
    img = np.arange(16 * 16 * 3, dtype=np.uint8).reshape(16, 16, 3)
    (out,) = pipe.run([img])
    assert out.shape == (8, 8, 3)
    assert out.dtype == np.float32


def test_tokenize_and_argsort_ops():
    ops = build_steps([ProcessingStep("tokenize", {"vocab_size": 50, "max_len": 8})])
    (out,) = Pipeline(ops).run(["hello world"])
    assert out.shape == (8,) and out.max() < 50
    ops2 = build_steps([ProcessingStep("argsort", {"k": 3})])
    (top,) = Pipeline(ops2).run([np.array([0.1, 0.5, 0.2, 0.9])])
    assert [i for i, _ in top] == [3, 1, 2]


def test_unknown_op_raises():
    with pytest.raises(KeyError):
        build_steps([ProcessingStep("nonexistent-op")])


def test_register_custom_op():
    register_op("plus_n", lambda params: (lambda x, m: x + params.get("n", 0)))
    ops = build_steps([ProcessingStep("plus_n", {"n": 5})])
    assert Pipeline(ops).run([1, 2]) == [6, 7]
