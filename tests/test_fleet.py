"""Fault-tolerant serving fleet: fault injection, requeue-on-death,
idempotent commits, graceful degradation.

Two layers:

* unit tests drive the FleetRouter over stub engines with a virtual clock —
  deterministic discrete-event simulations of deaths, retries, deadlines
  and the degrade ladder;
* integration tests run the full fault matrix {crash, stall, pressure} x
  {spec_k 0/2} x {prefix cache on/off} over real paged engines and require
  every completed request to be BIT-IDENTICAL to the fault-free oracle
  (greedy decoding is deterministic, so replay-from-prompt on a survivor
  must reproduce the same tokens).
"""
import threading
from types import SimpleNamespace

import numpy as np
import pytest

from repro.core.analysis import fleet_summary
from repro.core.tracing import Tracer, TracingServer
from repro.serve.engine import ServeRequest
from repro.serve.faults import FaultContext, FaultPlan, FaultSpec, WorkerCrash
from repro.serve.fleet import (
    DEGRADE_LEVELS,
    DegradeLadder,
    FleetConfig,
    FleetRouter,
)


class VirtualTime:
    def __init__(self):
        self.t = 0.0
        self._lock = threading.Lock()

    def clock(self):
        with self._lock:
            return self.t

    def sleep(self, dt):
        with self._lock:
            self.t += dt


class StubEngine:
    """A serve_paged stand-in: one request finishes per boundary, the fault
    hook runs at every boundary, and a crash carries the same resumable
    snapshot the real engine attaches (finished results + pending
    requests)."""

    def __init__(self, vt, max_seq=64, max_batch=4, page_size=8,
                 boundary_s=0.0):
        self.vt = vt
        self.max_seq = max_seq
        self.max_batch = max_batch
        self.page_size = page_size
        self.boundary_s = boundary_s
        self.calls = 0

    @staticmethod
    def tokens_for(req):
        # deterministic per request: the bit-identity oracle for stubs
        return np.arange(req.max_new_tokens, dtype=np.int32) + req.request_id

    def serve_paged(self, reqs, clock=None, tracer=None, fault_hook=None,
                    **kwargs):
        self.calls += 1
        finished = []
        pending = list(reqs)
        step = 0
        while pending:
            if self.boundary_s:
                self.vt.sleep(self.boundary_s)
            if fault_hook is not None:
                try:
                    fault_hook(FaultContext(step=step, clock=self.vt.clock,
                                            tracer=tracer))
                except WorkerCrash as crash:
                    crash.results = list(finished)
                    crash.pending = list(pending)
                    if hasattr(fault_hook, "release"):
                        fault_hook.release()
                    raise
            req = pending.pop(0)
            finished.append(SimpleNamespace(
                request_id=req.request_id, tokens=self.tokens_for(req)
            ))
            step += 1
        if fault_hook is not None and hasattr(fault_hook, "release"):
            fault_hook.release()
        return SimpleNamespace(results=finished)


def _reqs(n, prompt_len=16, gen=6):
    return [
        ServeRequest(request_id=i,
                     prompt=np.zeros((prompt_len,), np.int32),
                     max_new_tokens=gen)
        for i in range(n)
    ]


def _router(vt, n_workers, plan=None, cfg=None, **stub_kw):
    engines = [StubEngine(vt, **stub_kw) for _ in range(n_workers)]
    return FleetRouter(
        engines, cfg or FleetConfig(), fault_plan=plan,
        clock=vt.clock, sleep=vt.sleep,
    )


# ---------------------------------------------------------------------------
# FaultPlan / FaultSpec
# ---------------------------------------------------------------------------
def test_fault_plan_parse_describe_roundtrip():
    text = "crash@1:6,stall@0:3:0.05,pressure@2:4:6x2"
    plan = FaultPlan.parse(text)
    assert len(plan.specs) == 3
    assert FaultPlan.parse(plan.describe()).describe() == plan.describe()
    assert not FaultPlan.parse("")
    assert not FaultPlan.parse("none")
    with pytest.raises(ValueError, match="bad fault-plan item"):
        FaultPlan.parse("explode@0:1")
    with pytest.raises(ValueError, match="bad fault-plan item"):
        FaultPlan.parse("crash@0")


def test_fault_spec_validation():
    with pytest.raises(ValueError):
        FaultSpec("explode", 0, 1)
    with pytest.raises(ValueError):
        FaultSpec("crash", -1, 1)
    with pytest.raises(ValueError):
        FaultSpec("pressure", 0, 1, pages=0)


def test_fault_plan_generate_is_seed_deterministic():
    a = FaultPlan.generate(4, seed=7, crashes=2, stalls=1, pressures=1)
    b = FaultPlan.generate(4, seed=7, crashes=2, stalls=1, pressures=1)
    c = FaultPlan.generate(4, seed=8, crashes=2, stalls=1, pressures=1)
    assert a.describe() == b.describe()
    assert a.describe() != c.describe()


def test_hook_fires_once_and_only_for_its_worker():
    plan = FaultPlan([FaultSpec("stall", 1, 2, duration_s=0.5)])
    assert plan.hook_for(0) is None        # untouched workers keep the
    vt = VirtualTime()                     # zero-cost default path
    hook = plan.hook_for(1, sleep=vt.sleep)
    for step in range(6):
        hook(FaultContext(step=step, clock=vt.clock))
    assert [s.step for s in hook.fired] == [2]
    assert vt.t == pytest.approx(0.5)      # slept exactly once


# ---------------------------------------------------------------------------
# DegradeLadder
# ---------------------------------------------------------------------------
def test_degrade_ladder_hysteresis():
    vt = VirtualTime()
    ladder = DegradeLadder(high=0.8, low=0.5, clock=vt.clock)
    seq = [ladder.update(p) for p in
           (0.9, 0.9, 0.9, 0.9, 0.7, 0.4, 0.4, 0.4)]
    # one step per crossing, hold inside the band (0.7), one step down per
    # reading below low — and the top level saturates
    assert seq == [1, 2, 3, 3, 3, 2, 1, 0]
    assert ladder.max_level == 3
    assert DEGRADE_LEVELS[3] == "shed"
    assert len(ladder.transitions) == 6
    with pytest.raises(ValueError):
        DegradeLadder(high=0.4, low=0.5)


# ---------------------------------------------------------------------------
# FleetRouter over stub engines (virtual clock)
# ---------------------------------------------------------------------------
def test_fault_free_fleet_completes_everything():
    vt = VirtualTime()
    router = _router(vt, 3)
    stats = router.serve(_reqs(9))
    assert stats.completed == 9
    assert stats.failed == stats.rejected == stats.deaths == 0
    assert stats.goodput == 1.0
    for r in stats.results:
        assert np.array_equal(r.tokens, StubEngine.tokens_for(
            SimpleNamespace(request_id=r.request_id, max_new_tokens=6)))


def test_requeue_on_death_replays_on_survivors():
    vt = VirtualTime()
    plan = FaultPlan([FaultSpec("crash", 1, 1)])
    router = _router(vt, 3, plan=plan)
    stats = router.serve(_reqs(9))
    assert stats.deaths == 1
    assert stats.requeued > 0
    assert stats.completed == 9          # survivors replayed the orphans
    assert stats.failed == stats.rejected == 0
    assert len(stats.recovery_s) == 1    # the death drained
    # requeued requests consumed extra attempts; tokens identical anyway
    assert any(r.attempts == 2 for r in stats.results)
    crashed = [w for w in router.workers if not w.alive]
    assert [w.index for w in crashed] == [1]
    # the crash committed what worker 1 finished pre-crash (step >= 1 means
    # one request retired before the boundary fired)
    assert all(np.array_equal(
        r.tokens,
        StubEngine.tokens_for(
            SimpleNamespace(request_id=r.request_id, max_new_tokens=6))
    ) for r in stats.results)


def test_all_workers_dead_fails_attributed_not_hangs():
    vt = VirtualTime()
    plan = FaultPlan([FaultSpec("crash", 0, 0)])
    router = _router(vt, 1, plan=plan)
    stats = router.serve(_reqs(4))
    assert stats.deaths == 1
    assert stats.completed + stats.failed == 4
    reasons = {r.reason for r in stats.results if r.status == "failed"}
    assert reasons <= {"no-workers-left"}
    assert stats.failed > 0


def test_retries_exhausted_is_attributed():
    vt = VirtualTime()
    plan = FaultPlan([FaultSpec("crash", 0, 0), FaultSpec("crash", 1, 0)])
    router = _router(vt, 3, plan=plan,
                     cfg=FleetConfig(max_retries=1))
    stats = router.serve(_reqs(1))
    # dispatch 1: worker 0 crashes at once; requeue consumes the only retry;
    # dispatch 2: worker 1 crashes too -> budget spent -> attributed failure
    r = stats.results[0]
    assert r.status == "failed"
    assert r.reason == "retries-exhausted"
    assert r.attempts == 2
    assert stats.deaths == 2


def test_deadline_enforced_and_goodput_accounted():
    vt = VirtualTime()
    router = _router(vt, 1, cfg=FleetConfig(deadline_s=1.5),
                     max_batch=1, boundary_s=0.5)
    # 1 slot -> 2 requests per round (2x num_slots queue bound); each
    # boundary takes 0.5 virtual seconds and finishes one request
    stats = router.serve(_reqs(5, gen=4))
    assert stats.completed + stats.failed == 5
    by_status = {}
    for r in stats.results:
        by_status.setdefault(r.status, []).append(r)
    assert all(r.reason == "deadline" for r in by_status.get("failed", []))
    assert len(by_status["failed"]) >= 1
    late = [r for r in by_status["completed"] if not r.within_deadline]
    assert late                            # finished but past TTL: counted
    assert 0.0 < stats.goodput < 1.0       # out of goodput, not hidden


def test_oversize_request_fails_up_front():
    vt = VirtualTime()
    router = _router(vt, 2, max_seq=32)
    reqs = _reqs(3, prompt_len=16, gen=6)
    reqs[1] = ServeRequest(request_id=1,
                           prompt=np.zeros((40,), np.int32),
                           max_new_tokens=8)
    stats = router.serve(reqs)
    assert stats.result_of(1).status == "failed"
    assert stats.result_of(1).reason == "oversize"
    assert stats.completed == 2


def test_duplicate_request_ids_rejected():
    vt = VirtualTime()
    router = _router(vt, 1)
    reqs = _reqs(2)
    reqs[1] = ServeRequest(request_id=0, prompt=reqs[1].prompt,
                           max_new_tokens=6)
    with pytest.raises(ValueError, match="duplicate request_id"):
        router.serve(reqs)


def test_commit_is_idempotent():
    vt = VirtualTime()
    router = _router(vt, 1)
    stats = router.serve(_reqs(2))
    assert stats.duplicate_commits == 0
    # a late straggler re-committing a terminal request dedupes: first
    # commit wins, the duplicate is counted, tokens/worker never change
    t = router._by_id[0]
    before = (t.result.tokens, t.result.worker)
    assert router._commit(t, np.zeros((6,), np.int32), worker=0,
                          now=vt.clock()) is False
    assert router._dups == 1
    assert t.result.tokens is before[0]
    assert t.result.worker == before[1]


def test_sustained_overload_sheds_explicitly():
    vt = VirtualTime()
    # one worker, 6 allocatable pages, 3 worst-case pages per request ->
    # 2 requests per round; 10 queued keeps pressure over the high
    # watermark for 3 rounds, walking the ladder to the shed level
    engines = [StubEngine(vt)]
    router = FleetRouter(
        engines, FleetConfig(),
        engine_kwargs={"num_pages": 7, "num_slots": 1, "page_size": 8},
        clock=vt.clock, sleep=vt.sleep,
    )
    stats = router.serve(_reqs(10))
    assert stats.max_degrade_level == 3
    assert stats.rejected > 0
    shed = [r for r in stats.results if r.status == "rejected"]
    assert all(r.reason == "shed" for r in shed)
    # no silent loss: every request is terminal with a status
    assert stats.completed + stats.failed + stats.rejected == 10
    # the ladder walked up one level per round (hysteresis audit trail:
    # (time, from_level, to_level, pressure) tuples)
    assert [(frm, to) for _, frm, to, _ in stats.degrade_transitions] == \
        [(0, 1), (1, 2), (2, 3)]


def test_fleet_events_flow_to_analysis():
    vt = VirtualTime()
    server = TracingServer()
    tracer = Tracer("t-fleet", server)
    plan = FaultPlan([FaultSpec("crash", 1, 1)])
    engines = [StubEngine(vt) for _ in range(3)]
    router = FleetRouter(engines, FleetConfig(), fault_plan=plan,
                         clock=vt.clock, sleep=vt.sleep, tracer=tracer)
    stats = router.serve(_reqs(9))
    summary = fleet_summary(server.timeline("t-fleet"))
    assert summary["deaths"] == 1.0
    assert summary["completed"] == float(stats.completed)
    assert summary["requeued"] == float(stats.requeued)
    assert summary["faults_crash"] == 1.0
    assert summary["goodput"] == 1.0
    assert summary["recoveries"] == 1.0
    assert summary["rounds"] == float(stats.rounds)


# ---------------------------------------------------------------------------
# Integration: real paged engines, full fault matrix, bit-identity
# ---------------------------------------------------------------------------
NUM_SLOTS, PAGE_SIZE, MAX_SEQ = 4, 8, 64
N_REQS, PROMPT_LEN, GEN = 6, 12, 5

FAULT_PLANS = {
    "crash": "crash@1:1",
    "stall": "stall@1:1:0.02",
    "pressure": "pressure@1:1:4x2",
}


@pytest.fixture(scope="module")
def engines():
    import jax

    from repro.configs import get_config
    from repro.models import build_model
    from repro.serve.engine import ServingEngine

    cfg = get_config("glm4-9b", reduced=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engines = [
        ServingEngine(model, params, max_batch=NUM_SLOTS, max_seq=MAX_SEQ,
                      page_size=PAGE_SIZE)
        for _ in range(3)
    ]
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, (8,)).astype(np.int32)
    prompts = [
        np.concatenate([
            shared,
            rng.integers(0, cfg.vocab_size,
                         (PROMPT_LEN - len(shared),)).astype(np.int32),
        ])
        for _ in range(N_REQS)
    ]
    return engines, prompts


_oracles = {}


def _fleet_serve(engines, prompts, plan_text, spec_k, prefix):
    reqs = [
        ServeRequest(request_id=i, prompt=p, max_new_tokens=GEN)
        for i, p in enumerate(prompts)
    ]
    router = FleetRouter(
        engines, FleetConfig(),
        engine_kwargs=dict(num_slots=NUM_SLOTS, page_size=PAGE_SIZE,
                           spec_k=spec_k, prefix_cache=prefix),
        fault_plan=FaultPlan.parse(plan_text) if plan_text else None,
    )
    return router.serve(reqs)


@pytest.mark.parametrize("prefix", [True, False], ids=["prefix", "noprefix"])
@pytest.mark.parametrize("spec_k", [0, 2], ids=["spec0", "spec2"])
@pytest.mark.parametrize("kind", sorted(FAULT_PLANS))
def test_fault_matrix_bit_identity(engines, kind, spec_k, prefix):
    engs, prompts = engines
    key = (spec_k, prefix)
    if key not in _oracles:
        base = _fleet_serve(engs, prompts, "", spec_k, prefix)
        assert base.completed == N_REQS
        _oracles[key] = {r.request_id: r.tokens for r in base.results}
    oracle = _oracles[key]

    stats = _fleet_serve(engs, prompts, FAULT_PLANS[kind], spec_k, prefix)
    # zero silent loss: every submitted request is terminal
    assert stats.completed + stats.failed + stats.rejected == N_REQS
    # this matrix has survivors and no deadline: everything completes
    assert stats.completed == N_REQS, (
        f"{kind}/spec{spec_k}/prefix={prefix}: "
        f"{[(r.request_id, r.status, r.reason) for r in stats.results]}"
    )
    for r in stats.results:
        assert np.array_equal(r.tokens, oracle[r.request_id]), (
            f"{kind}/spec{spec_k}/prefix={prefix}: request {r.request_id} "
            f"diverged after replay"
        )
    if kind == "crash":
        assert stats.deaths == 1 and stats.requeued > 0
        assert len(stats.recovery_s) == 1
    else:
        assert stats.deaths == 0      # stall < TTL and pressure never kill
    assert stats.duplicate_commits == 0   # sequential mode cannot duplicate


def test_parallel_hedge_duplicates_dedupe(engines):
    """A stall longer than the lease TTL in parallel mode: the router
    detaches the straggler, re-dispatches its uncommitted work immediately,
    and the straggler's late results dedupe at the idempotent commit."""
    engs, prompts = engines
    gens = [2, 2, 8, 2, 8, 2]     # worker 1 gets one short + one long req
    reqs = [
        ServeRequest(request_id=i, prompt=p, max_new_tokens=g)
        for i, (p, g) in enumerate(zip(prompts, gens))
    ]
    base = FleetRouter(
        engs, FleetConfig(),
        engine_kwargs=dict(num_slots=NUM_SLOTS, page_size=PAGE_SIZE),
    ).serve([ServeRequest(request_id=r.request_id, prompt=r.prompt,
                          max_new_tokens=r.max_new_tokens) for r in reqs])
    oracle = {r.request_id: r.tokens for r in base.results}

    router = FleetRouter(
        engs,
        FleetConfig(parallel=True, hedge=True, lease_ttl_s=0.4),
        engine_kwargs=dict(num_slots=NUM_SLOTS, page_size=PAGE_SIZE),
        fault_plan=FaultPlan.parse("stall@1:4:1.5"),
    )
    stats = router.serve([
        ServeRequest(request_id=r.request_id, prompt=r.prompt,
                     max_new_tokens=r.max_new_tokens) for r in reqs
    ])
    assert stats.completed == len(reqs)
    assert stats.hedged > 0               # the straggler was detached
    # the stalled worker either self-crashed on its expired lease (its
    # pre-stall results arrive as late commits) or returned late — both
    # paths dedupe instead of double-committing
    assert stats.duplicate_commits >= 1
    assert stats.completed + stats.failed + stats.rejected == len(reqs)
    for r in stats.results:
        assert np.array_equal(r.tokens, oracle[r.request_id])


# ---------------------------------------------------------------------------
# DegradeLadder x priority-aware shedding
# ---------------------------------------------------------------------------
def test_degrade_hysteresis_never_oscillates_within_a_crossing():
    vt = VirtualTime()
    ladder = DegradeLadder(high=0.85, low=0.60, clock=vt.clock)
    assert ladder.update(0.9) == 1        # ONE crossing of the high mark
    # pressure now oscillates anywhere inside the [low, high) band: the
    # hysteresis must hold the level — zero additional transitions until
    # the signal actually crosses a watermark again
    for p in (0.84, 0.61, 0.70, 0.84, 0.60, 0.75) * 5:
        vt.sleep(0.01)
        ladder.update(p)
    assert ladder.level == 1
    assert len(ladder.transitions) == 1
    assert ladder.update(0.59) == 0       # and one crossing steps back down
    assert len(ladder.transitions) == 2


def test_priority_aware_shed_drops_best_effort_tier_first():
    vt = VirtualTime()
    # same sustained-overload setup that walks the ladder to the shed
    # level, but with a mixed-priority population: ids 0-4 best-effort
    # (tier 0), ids 5-9 premium (tier 2)
    reqs = _reqs(10)
    for r in reqs[:5]:
        r.priority = 0
    for r in reqs[5:]:
        r.priority = 2
    engines = [StubEngine(vt)]
    router = FleetRouter(
        engines, FleetConfig(),
        engine_kwargs={"num_pages": 7, "num_slots": 1, "page_size": 8},
        clock=vt.clock, sleep=vt.sleep,
    )
    stats = router.serve(reqs)
    assert stats.max_degrade_level == 3
    assert stats.completed + stats.failed + stats.rejected == 10
    shed = [r for r in stats.results if r.status == "rejected"]
    assert shed and all(r.reason == "shed" for r in shed)
    best_effort = {r.request_id for r in reqs if r.priority == 0}
    premium = {r.request_id for r in reqs if r.priority == 2}
    # shedding only ever drops the lowest tier present: best-effort
    # absorbs the whole overload, premium never loses a request
    assert {r.request_id for r in shed} <= best_effort
    done = {r.request_id for r in stats.results if r.status == "completed"}
    assert premium <= done
    assert router.tenant_ledger.stats()["default"]["shed"] == len(shed)


def test_fleet_fairness_off_keeps_fifo_packing():
    vt = VirtualTime()
    reqs = _reqs(6)
    for r in reqs[:3]:
        r.priority = 0                    # tags present but fairness off
    router = FleetRouter(
        [StubEngine(vt) for _ in range(2)],
        FleetConfig(fairness=False),
        clock=vt.clock, sleep=vt.sleep,
    )
    stats = router.serve(reqs)
    assert stats.completed == 6
    assert router.tenant_ledger.stats() == {}   # no admissions charged
