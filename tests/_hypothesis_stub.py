"""Tiny offline fallback for the ``hypothesis`` API used by this suite.

Installed as ``sys.modules["hypothesis"]`` by ``conftest.py`` ONLY when the
real package is absent, so the suite collects and passes in hermetic
environments.  It is not a property-based tester: each strategy yields a
deterministic stream of examples (boundary values first, then seeded
pseudo-random draws) and ``@given`` simply replays ``max_examples`` of them
through the test function.
"""
from __future__ import annotations

import random
import types
from typing import Any, Callable, List, Sequence

DEFAULT_MAX_EXAMPLES = 25


class Strategy:
    """A deterministic example stream; ``example(rng, i)`` yields draw i."""

    def __init__(self, draw: Callable[[random.Random, int], Any]) -> None:
        self._draw = draw

    def example(self, rng: random.Random, i: int) -> Any:
        return self._draw(rng, i)

    def map(self, fn: Callable[[Any], Any]) -> "Strategy":
        return Strategy(lambda rng, i: fn(self._draw(rng, i)))

    def filter(self, pred: Callable[[Any], bool]) -> "Strategy":
        def draw(rng: random.Random, i: int) -> Any:
            for attempt in range(100):
                v = self._draw(rng, i + attempt)
                if pred(v):
                    return v
            raise _Unsatisfied()

        return Strategy(draw)

    def flatmap(self, fn: Callable[[Any], "Strategy"]) -> "Strategy":
        return Strategy(lambda rng, i: fn(self._draw(rng, i)).example(rng, i))


def integers(min_value: int = -(2**31), max_value: int = 2**31) -> Strategy:
    bounds = [min_value, max_value, min(min_value + 1, max_value)]

    def draw(rng: random.Random, i: int) -> int:
        if i < len(bounds):
            return bounds[i]
        return rng.randint(min_value, max_value)

    return Strategy(draw)


def floats(
    min_value: float = 0.0,
    max_value: float = 1.0,
    allow_nan: bool = False,
    allow_infinity: bool = False,
) -> Strategy:
    bounds = [min_value, max_value, (min_value + max_value) / 2.0]

    def draw(rng: random.Random, i: int) -> float:
        if i < len(bounds):
            return float(bounds[i])
        return rng.uniform(min_value, max_value)

    return Strategy(draw)


def booleans() -> Strategy:
    return Strategy(lambda rng, i: i % 2 == 0)


def just(value: Any) -> Strategy:
    return Strategy(lambda rng, i: value)


def sampled_from(elements: Sequence[Any]) -> Strategy:
    elements = list(elements)

    def draw(rng: random.Random, i: int) -> Any:
        if i < len(elements):
            return elements[i]
        return rng.choice(elements)

    return Strategy(draw)


def lists(elements: Strategy, min_size: int = 0, max_size: int = 10) -> Strategy:
    sizes = [min_size, max_size, max(min_size, min(max_size, 1))]

    def draw(rng: random.Random, i: int) -> List[Any]:
        size = sizes[i] if i < len(sizes) else rng.randint(min_size, max_size)
        return [elements.example(rng, rng.randint(3, 1 << 20)) for _ in range(size)]

    return Strategy(draw)


def tuples(*strategies: Strategy) -> Strategy:
    def draw(rng: random.Random, i: int) -> tuple:
        return tuple(s.example(rng, i) for s in strategies)

    return Strategy(draw)


strategies = types.ModuleType("hypothesis.strategies")
for _name in ("integers", "floats", "booleans", "just", "sampled_from", "lists", "tuples"):
    setattr(strategies, _name, globals()[_name])


def settings(*args, max_examples: int = DEFAULT_MAX_EXAMPLES, **kwargs):
    """Decorator recording max_examples on the (given-wrapped) test."""

    def apply(fn):
        fn._stub_max_examples = max_examples
        return fn

    if args and callable(args[0]):
        return apply(args[0])
    return apply


def assume(condition: bool) -> bool:
    """Best-effort: a failed assumption just skips the remaining body."""
    if not condition:
        raise _Unsatisfied()
    return True


class _Unsatisfied(Exception):
    pass


def given(*arg_strategies: Strategy, **kw_strategies: Strategy):
    def decorate(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", DEFAULT_MAX_EXAMPLES)
            rng = random.Random(0)
            for i in range(n):
                drawn_args = tuple(s.example(rng, i) for s in arg_strategies)
                drawn_kw = {k: s.example(rng, i) for k, s in kw_strategies.items()}
                try:
                    fn(*args, *drawn_args, **kwargs, **drawn_kw)
                except _Unsatisfied:
                    continue
            return None

        # copy identity but NOT the signature (functools.wraps would set
        # __wrapped__ and pytest would then demand fixtures for the drawn
        # parameters); plugins (e.g. anyio) introspect `.hypothesis.inner_test`
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper

    return decorate


class HealthCheck:
    all = staticmethod(lambda: [])
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"
